"""Trip-count-aware HLO cost analysis (the roofline's measurement layer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hloanalysis import analyze_hlo, top_costs

X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
W = jax.ShapeDtypeStruct((256, 256), jnp.float32)
MM_FLOPS = 2 * 256**3


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    r = analyze_hlo(_compile(lambda x, w: x @ w, X, W))
    assert abs(r.flops - MM_FLOPS) / MM_FLOPS < 0.05
    assert r.unknown_loops == 0


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    r = analyze_hlo(_compile(f, X, W))
    assert abs(r.flops - 7 * MM_FLOPS) / (7 * MM_FLOPS) < 0.05
    assert r.unknown_loops == 0


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    r = analyze_hlo(_compile(f, X, W))
    want = 15 * MM_FLOPS
    assert abs(r.flops - want) / want < 0.05


def test_fori_loop_trip_count():
    def f(x, w):
        return jax.lax.fori_loop(0, 9, lambda i, c: c @ w, x)

    r = analyze_hlo(_compile(f, X, W))
    want = 9 * MM_FLOPS
    assert abs(r.flops - want) / want < 0.05


def test_remat_counts_recompute():
    """Remat never REDUCES counted flops. (XLA's CSE may merge the
    recompute with the forward on CPU, so we assert the weaker direction;
    the scan trip-count tests cover the multiplication that matters.)"""
    def deep(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return jnp.sum(x)

    g_plain = analyze_hlo(_compile(jax.grad(deep), X, W))
    g_remat = analyze_hlo(
        _compile(jax.grad(jax.checkpoint(deep)), X, W)
    )
    assert g_remat.flops >= g_plain.flops * 0.95


def test_bytes_scale_with_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    r1 = analyze_hlo(_compile(lambda x, w: jnp.tanh(x @ w), X, W))
    r8 = analyze_hlo(_compile(f, X, W))
    assert r8.bytes > 4 * r1.bytes  # roughly 8x modulo loop plumbing


def test_top_costs_structure():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    rows = top_costs(_compile(f, X, W), 10)
    assert rows, "no cost rows"
    assert any(r["trips"] == 6 for r in rows)
    top = rows[0]
    assert set(top) >= {"bytes", "flops", "trips", "opcode", "name"}


def test_collectives_counted_inside_loops():
    import os
    # only meaningful with >1 device; on 1 CPU device GSPMD elides
    # collectives — assert the parse doesn't crash and finds none
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(y)

    r = analyze_hlo(_compile(f, X, W))
    assert r.wire_bytes == 0.0
    assert r.collective_count == 0
