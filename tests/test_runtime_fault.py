"""Fault injection: SIGKILL a live worker process mid-epoch.

The supervisor must detect the death, respawn the worker from its newest
``checkpoint.store`` snapshot, and the respawned process must replay
forward deterministically (bit-identical re-publishes; the broker counts
any mismatch) until it catches the pool — with the ISP conservation
invariant ``sent + residual' == residual + update`` holding pool-wide
through the crash and recovery.

(The dual fault — SIGKILL of a *broker shard* — lives in
``test_runtime_sharded.py``, where the sharded topology it exercises is
introduced.)
"""

from __future__ import annotations

from runtime_harness import SMALL_P as P, run_small_pmf

STEPS = 14
KILL_WORKER = 2
KILL_AT = 6  # after the step-4 checkpoint exists
CKPT_EVERY = 4


def test_sigkill_mid_epoch_respawns_from_checkpoint(tmp_path):
    res = run_small_pmf(
        tmp_path,
        total_steps=STEPS,
        checkpoint_every=CKPT_EVERY,
        lr=0.08,
        kill_worker_at_step=(KILL_WORKER, KILL_AT),
        deadline_s=240.0,
    )
    # the kill really happened and was recovered
    assert res["n_respawns"] >= 1
    ev = res["respawns"][0]
    assert ev["worker"] == KILL_WORKER
    assert ev["exit_code"] == -9  # SIGKILL
    # respawned from the last checkpoint, not from scratch and not from
    # beyond the crash point
    assert 0 < ev["restored_step"] <= ev["at_frontier"]
    assert ev["restored_step"] % CKPT_EVERY == 0

    # the job still completed every step with the full pool
    assert res["steps"] == STEPS
    assert res["final_pool"] == P
    assert len(res["history"]) == STEPS

    # deterministic replay: any step the dead worker had already published
    # must be re-published bit-identically
    assert res["dup_mismatches"] == 0

    # ISP conservation invariant pool-wide, through crash + recovery
    assert res["invariant_max_err"] == 0.0

    # progress was not lost
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]
