"""repro.wire — codec round-trips, byte-accounting invariants, cross-checks.

The load-bearing invariant (DESIGN.md §10): every layer that sizes an
update — the live runtime's encoder, the compressed pod collective's
traced accounting, and the simulator's cost model — reads the SAME
``leaf_nbytes`` formula, so simulated bytes == measured bytes by
construction.  These tests hold that line:

* bit-exact decode across schemes x dtypes x edge shapes;
* ``len(payload) == meta nbytes == predicted nbytes`` everywhere;
* broker-measured bytes == simulator-accounted bytes per scheme;
* ``dist.compression``'s traced ``wire_bytes`` == real encoded bytes;
* the int32 flat-index overflow guard (>= 2**31-element leaves widen).
"""

from __future__ import annotations

import numpy as np
import pytest

import ml_dtypes

from repro import wire
from repro.wire import codec

F32 = np.float32
SCHEMES = ("dense", "sparse", "bitmap")
DTYPES = {
    "f32": np.dtype(np.float32),
    "f16": np.dtype(np.float16),
    "bf16": np.dtype(ml_dtypes.bfloat16),
    "i32": np.dtype(np.int32),
}
# edge shapes: scalar, singleton, non-multiple-of-128, exactly 128, odd 129
SHAPES = ((), (1,), (5,), (128,), (129,), (16, 4), (3, 5, 7))


def _leaf(shape, dtype, density, seed=0):
    dtype = np.dtype(dtype)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(shape or ())
    mask = rng.random(shape or ()) < density
    a = np.where(mask, a, 0.0)
    if dtype.kind == "i":
        return (a * 10).astype(dtype)
    return a.astype(dtype)


# -- round trips --------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES + ("auto",))
@pytest.mark.parametrize("dtype", sorted(DTYPES))
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("density", (0.0, 0.15, 1.0))
def test_roundtrip_bit_exact_with_exact_accounting(
    scheme, dtype, shape, density
):
    dt = DTYPES[dtype]
    a = _leaf(shape, dt, density)
    meta, parts, _ = codec.encode_leaf(a, scheme=scheme)
    blob = b"".join(bytes(p) for p in parts)
    # exact accounting: produced == recorded == predicted
    assert len(blob) == meta["nbytes"]
    assert meta["nbytes"] == codec.leaf_nbytes(
        meta["enc"], int(a.size), int(np.count_nonzero(a)), dt.itemsize
    )
    out = codec.decode_leaf(meta, blob)
    assert out.dtype == a.dtype and out.shape == a.shape
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a))


def test_non_contiguous_leaf_encodes_its_logical_order():
    base = _leaf((8, 6), F32, 0.3, seed=3)
    nc = base.T  # non-contiguous view
    assert not nc.flags["C_CONTIGUOUS"]
    for scheme in SCHEMES:
        meta, parts, _ = codec.encode_leaf(nc, scheme=scheme)
        out = codec.decode_leaf(meta, b"".join(bytes(p) for p in parts))
        np.testing.assert_array_equal(out, nc)


def test_tree_encode_decode_and_predict_agree():
    tree = {
        "U": _leaf((40, 8), F32, 0.1, seed=1),
        "M": _leaf((30, 8), F32, 1.0, seed=2),
        "b": _leaf((), F32, 1.0, seed=3),
    }
    for scheme in SCHEMES + ("auto",):
        meta, payload = wire.encode_tree(tree, scheme=scheme)
        assert wire.tree_nbytes(meta) == len(payload)
        assert wire.predict_tree_nbytes(tree, scheme=scheme) == len(payload)
        out = wire.decode_tree(meta, payload, tree)
        for k in tree:
            np.testing.assert_array_equal(out[k], tree[k])


def test_auto_picks_the_smallest_encoding_per_leaf():
    sparse_leaf = _leaf((256,), F32, 0.02, seed=4)
    dense_leaf = _leaf((256,), F32, 1.0, seed=5)
    m1, _, _ = codec.encode_leaf(sparse_leaf, scheme="auto")
    m2, _, _ = codec.encode_leaf(dense_leaf, scheme="auto")
    n, i = 256, 4
    for m, a in ((m1, sparse_leaf), (m2, dense_leaf)):
        best = min(
            codec.leaf_nbytes(s, n, int(np.count_nonzero(a)), i)
            for s in SCHEMES
        )
        assert m["nbytes"] == best
    assert m1["enc"] in ("sparse", "bitmap") and m2["enc"] == "dense"


# -- quantization -------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("quant", ("fp16", "bf16"))
def test_quantized_roundtrip_and_error_feedback_residual(scheme, quant):
    a = _leaf((67,), F32, 0.4, seed=6)
    qdt = codec.quant_dtype(a.dtype, quant)
    meta, parts, res = codec.encode_leaf(
        a, scheme=scheme, quant=quant, with_residual=True
    )
    blob = b"".join(bytes(p) for p in parts)
    # half-width values shrink the wire by construction
    assert meta["q"] == quant
    assert meta["nbytes"] == codec.leaf_nbytes(
        scheme, a.size, int(np.count_nonzero(a)), 2
    )
    out = codec.decode_leaf(meta, blob)
    np.testing.assert_array_equal(out, a.astype(qdt).astype(F32))
    # error feedback: decoded + residual reconstructs the original exactly
    np.testing.assert_array_equal(out + res, a)


def test_quantization_passes_integer_leaves_through():
    a = _leaf((33,), DTYPES["i32"], 0.5, seed=7)
    meta, parts, res = codec.encode_leaf(
        a, scheme="dense", quant="fp16", with_residual=True
    )
    assert "q" not in meta and meta["nbytes"] == a.size * 4
    np.testing.assert_array_equal(
        codec.decode_leaf(meta, b"".join(bytes(p) for p in parts)), a
    )
    assert not np.any(res)


# -- int32 flat-index overflow guard ------------------------------------------


def test_index_dtype_widens_at_2_31():
    assert codec.index_dtype(codec.INT32_MAX) == np.int32
    assert codec.index_dtype(codec.INT32_MAX + 1) == np.int64
    assert codec.index_itemsize(codec.INT32_MAX) == 4
    assert codec.index_itemsize(codec.INT32_MAX + 1) == 8


def test_sparse_accounting_charges_8B_indices_above_2_31():
    n = codec.INT32_MAX + 1
    assert codec.leaf_nbytes("sparse", n, 10, 4) == 10 * (8 + 4)
    assert codec.leaf_nbytes("sparse", n - 1, 10, 4) == 10 * (4 + 4)


def test_decode_honors_int64_index_meta():
    # a huge-leaf message decodes through the int64 branch; exercise it on
    # a small one by building the message the way the encoder would for
    # n >= 2**31 (the decoder trusts meta['idx'], not the leaf size)
    vals = np.asarray([1.5, -2.0], np.float32)
    idx = np.asarray([3, 7], np.int64)
    meta = {
        "k": "w", "shape": [9], "dtype": "float32", "enc": "sparse",
        "nnz": 2, "idx": "int64", "nbytes": 2 * (8 + 4),
    }
    out = codec.decode_leaf(meta, idx.tobytes() + vals.tobytes())
    want = np.zeros(9, np.float32)
    want[idx] = vals
    np.testing.assert_array_equal(out, want)


# -- cross-layer byte equality ------------------------------------------------


def test_broker_measured_equals_simulator_accounted_per_scheme():
    """The acceptance-criteria cross-check: publish one update through a
    REAL broker under every scheme and require the broker's measured
    telemetry bytes to equal the simulator-side accounting
    (``predict_tree_nbytes`` -> ``leaf_nbytes``) for the same update."""
    from repro.runtime import protocol
    from repro.runtime.broker import Broker

    tree = {
        "U": _leaf((50, 4), F32, 0.08, seed=8),
        "M": _leaf((20, 4), F32, 0.5, seed=9),
    }
    for step, scheme in enumerate(SCHEMES + ("auto",), start=1):
        broker = Broker(
            {"n_workers": 1, "total_steps": 4, "n_batches": 1}
        )
        broker.start()
        try:
            meta, payload = protocol.encode_tree(tree, scheme=scheme)
            conn = protocol.Connection(broker.addr)
            conn.request(
                {"t": "publish", "worker": 0, "step": 1, "meta": meta,
                 "loss": 0.0, "sent_fraction": 0.0, "inv_err": 0.0},
                payload,
            )
            conn.close()
            measured = broker.core.telemetry[(1, 0)]["wire_bytes"]
            accounted = wire.predict_tree_nbytes(tree, scheme=scheme)
            assert measured == accounted == len(payload), scheme
        finally:
            broker.stop()


def test_simulator_bytes_out_reads_the_codec_formula():
    """core.simulator._bytes_out == leaf_nbytes for every scheme (the cost
    model and the runtime share one sizing function)."""
    import jax

    from repro import optim
    from repro.core import consistency as cons
    from repro.core.simulator import (
        Platform, ServerlessSimulator, SimulatorConfig,
    )

    params = {"w": np.zeros((100,), F32)}

    def grad_fn(p, b):
        return np.float32(0.0), jax.tree.map(np.zeros_like, p)

    for scheme in ("dense", "sparse", "bitmap", "auto"):
        sim = ServerlessSimulator(
            SimulatorConfig(
                n_workers=2,
                platform=Platform.MLLESS,
                consistency=cons.ConsistencyConfig(model=cons.Model.ISP),
                wire_scheme=scheme,
            ),
            grad_fn=grad_fn,
            optimizer=optim.make("sgd", 0.1),
            params=params,
            flops_per_sample=1.0,
        )
        frac = 0.13
        got = sim._bytes_out(frac, batch_size=8)
        nnz = 100 * frac
        if scheme == "auto":
            want = min(
                codec.leaf_nbytes(s, 100, nnz, 4) for s in SCHEMES
            )
        else:
            want = codec.leaf_nbytes(scheme, 100, nnz, 4)
        assert got == float(want), scheme
    # serverful: dense bytes come from the same codec via billing
    sim = ServerlessSimulator(
        SimulatorConfig(n_workers=2, platform=Platform.SERVERFUL),
        grad_fn=grad_fn,
        optimizer=optim.make("sgd", 0.1),
        params=params,
        flops_per_sample=1.0,
    )
    assert sim._bytes_out(1.0, 8) == codec.leaf_nbytes("dense", 100, 100, 4)


def test_dist_compression_accounts_real_encoded_bytes():
    """The traced pod-collective ``wire_bytes`` stat equals the bytes the
    shared codec ACTUALLY produces for the same sent tensors, per scheme
    — exactly, no tolerance: simulated bytes ARE measured bytes."""
    import jax
    import jax.numpy as jnp

    from repro.dist.compression import (
        CompressionConfig,
        _block_topk_mask,
        isp_compressed_step,
        split_significant,
    )

    P = 3
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = {"w": jax.random.normal(ks[0], (11, 129), jnp.float32)}
    u = {"w": 0.1 * jax.random.normal(ks[1], (P, 11, 129), jnp.float32)}
    r = {"w": 0.01 * jax.random.normal(ks[2], (P, 11, 129), jnp.float32)}
    v = jnp.float32(0.9)

    for scheme in ("dense", "topk", "bitmap"):
        cfg = CompressionConfig(scheme=scheme)
        _, _, stats = isp_compressed_step(cfg, u, x, r, v)
        # what each pod put on the wire, via the module's own split
        sig, _ = split_significant(u["w"], x["w"], r["w"], v)
        if scheme == "topk":
            keep = jax.vmap(lambda s: _block_topk_mask(s, cfg))(sig)
            sent = jnp.where(keep, sig, jnp.zeros_like(sig))
        else:
            sent = sig
        measured = 0
        arr = np.asarray(sent)
        for p in range(P):
            m, _, _ = codec.encode_leaf(arr[p], scheme=cfg.wire_scheme)
            measured += m["nbytes"]
        assert int(float(stats["wire_bytes"])) == measured, scheme


# -- framing / transport ------------------------------------------------------


def test_vectored_send_msg_matches_joined_payload():
    import socket

    a, b = socket.socketpair()
    try:
        parts = [memoryview(b"abc"), b"", bytearray(b"defg")]
        n = wire.send_msg(a, {"t": "x"}, parts)
        h, p = wire.recv_msg(b)
        assert h == {"t": "x"} and p == b"abcdefg"
        assert n == 8 + len(b'{"t":"x"}') + 7
    finally:
        a.close()
        b.close()


def test_vectored_send_chunks_past_iov_max():
    """A payload with more buffer views than the kernel's IOV_MAX (deep
    pytrees: 2 views per sparse leaf) must still go out in one message."""
    import socket
    import threading

    n_bufs = 3000  # > IOV_MAX (1024) by a comfortable margin
    parts = [memoryview(bytes([i % 251])) for i in range(n_bufs)]
    a, b = socket.socketpair()
    got = {}

    def reader():
        got["msg"] = wire.recv_msg(b)

    t = threading.Thread(target=reader)
    t.start()
    try:
        wire.send_msg(a, {"t": "big"}, parts)
        t.join(timeout=10.0)
        assert not t.is_alive()
        h, p = got["msg"]
        assert h == {"t": "big"}
        assert p == bytes(i % 251 for i in range(n_bufs))
    finally:
        a.close()
        b.close()


def test_pack_parts_vectored_and_unpack():
    meta, parts, _ = codec.encode_leaf(_leaf((17,), F32, 0.3), scheme="sparse")
    descs, bufs = wire.pack_parts(
        [({"worker": 0}, parts), ({"worker": 1}, b"xyz")]
    )
    out = wire.unpack_parts(descs, bufs)
    assert bytes(out[0][1]) == b"".join(bytes(p) for p in parts)
    assert bytes(out[1][1]) == b"xyz"
    assert out[0][0]["nbytes"] == meta["nbytes"]
