"""Scale-in auto-tuner: curve fitting, knee detection, decisions (§4.2)."""

import numpy as np
import pytest

from repro.core import curves
from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner


def synthetic_loss(t: np.ndarray, theta=(0.05, 0.9, 0.5, 0.35)) -> np.ndarray:
    """Paper Eq. 2 shape: 1/(a t^b + c) + d."""
    a, b, c, d = theta
    return 1.0 / (a * np.power(t, b) + c) + d


def test_ewma_smooths_outliers():
    y = np.ones(50)
    y[25] = 100.0
    sm = curves.ewma(list(y), 0.3)
    assert sm[30] < 15.0  # spike heavily damped a few steps later
    assert abs(sm[0] - 1.0) < 1e-9


def test_fit_reference_recovers_shape():
    t = np.arange(1, 200, dtype=np.float64)
    y = synthetic_loss(t)
    fit = curves.fit_reference(t, y)
    pred = fit(np.array([250.0, 300.0]))
    true = synthetic_loss(np.array([250.0, 300.0]))
    # paper Fig. 3c: < 1.5% error predicting 100+ steps ahead
    assert np.all(np.abs(pred - true) / true < 0.015)


def test_fit_slow_curve():
    t = np.arange(100, 200, dtype=np.float64)
    y = 1.0 / (1e-4 * t**2 + 0.01 * t + 1.0) + 0.4
    fit = curves.fit_slow(t, y)
    pred = fit(np.array([220.0]))
    true = 1.0 / (1e-4 * 220**2 + 0.01 * 220 + 1.0) + 0.4
    assert abs(float(pred[0]) - true) / true < 0.05


def test_knee_detection_on_flattening_curve():
    t = np.arange(1, 300, dtype=np.float64)
    y = synthetic_loss(t)
    idx = curves.detect_knee(y, slope_threshold=0.05, window=5)
    assert idx is not None
    # knee is where |dy/dt| falls below threshold*initial — must be past
    # the steep region
    assert 3 < idx < 200


def test_no_knee_on_steep_curve():
    y = 10.0 - 0.5 * np.arange(20)  # constant steep slope
    assert curves.detect_knee(y, slope_threshold=0.01, window=3) is None


def _drive(tuner: ScaleInAutoTuner, losses, dur=1.0):
    decisions = []
    for i, l in enumerate(losses, start=1):
        tuner.observe(i, float(l), dur)
        decisions.append(tuner.decide())
    return decisions


def test_tuner_waits_for_knee():
    cfg = AutoTunerConfig(sched_interval_s=2.0, delta_s=1.0)
    tuner = ScaleInAutoTuner(cfg, initial_workers=8)
    steep = 10.0 * np.exp(-0.5 * np.arange(10))  # still dropping fast
    decisions = _drive(tuner, steep)
    assert all(not d.remove_worker for d in decisions)
    assert tuner.pool == 8


def test_tuner_scales_in_after_plateau():
    cfg = AutoTunerConfig(sched_interval_s=2.0, delta_s=1.0,
                          knee_slope_threshold=0.05, min_points_for_fit=6)
    tuner = ScaleInAutoTuner(cfg, initial_workers=8)
    t = np.arange(1, 120, dtype=np.float64)
    _drive(tuner, synthetic_loss(t))
    assert tuner.knee_step is not None
    assert tuner.pool < 8  # at least the knee-initial eviction fired


def test_tuner_respects_min_workers():
    cfg = AutoTunerConfig(sched_interval_s=0.5, delta_s=0.25, min_workers=3,
                          min_points_for_fit=4)
    tuner = ScaleInAutoTuner(cfg, initial_workers=4)
    t = np.arange(1, 400, dtype=np.float64)
    flat = 0.5 + 1e-4 * np.exp(-t)  # totally flat: always scale-in
    _drive(tuner, flat)
    assert tuner.pool >= 3


def test_s_delta_formula():
    """Decision uses s_D(t) = (L_P(h) - l_p(h')) / L_P(h) < S (Eq. 1)."""
    cfg = AutoTunerConfig(sched_interval_s=1.0, delta_s=1.0, threshold_S=0.05,
                          min_points_for_fit=5)
    tuner = ScaleInAutoTuner(cfg, initial_workers=4)
    t = np.arange(1, 200, dtype=np.float64)
    y = synthetic_loss(t)
    decisions = _drive(tuner, y)
    scored = [d for d in decisions if d.s_delta is not None]
    assert scored, "tuner never reached the decision phase"
    # on a curve matching the reference exactly, s_delta ~ 0 < S
    assert any(abs(d.s_delta) < 0.05 for d in scored)


def test_d_p_excludes_compile_warmup():
    """The reference step duration d_P must drop the first observation: it
    carries the XLA-compile warm-up and would skew the s_Delta horizon."""
    cfg = AutoTunerConfig(sched_interval_s=2.0, delta_s=1.0,
                          knee_slope_threshold=0.05, min_points_for_fit=6)
    tuner = ScaleInAutoTuner(cfg, initial_workers=8)
    t = np.arange(1, 120, dtype=np.float64)
    losses = synthetic_loss(t)
    for i, l in enumerate(losses, start=1):
        tuner.observe(i, float(l), 10.0 if i == 1 else 1.0)
        tuner.decide()
    assert tuner.knee_step is not None
    assert tuner.d_P == pytest.approx(1.0)


def test_under_observed_consumes_interval():
    """Post-knee, an 'under-observed' decide() must advance the pacing clock
    like every other outcome — not re-fire the fit on every call."""
    cfg = AutoTunerConfig(sched_interval_s=5.0, delta_s=2.5,
                          knee_slope_threshold=0.05, min_points_for_fit=50)
    tuner = ScaleInAutoTuner(cfg, initial_workers=8)
    t = np.arange(1, 120, dtype=np.float64)
    _drive(tuner, synthetic_loss(t))
    assert tuner.knee_step is not None
    assert tuner.pool < 8  # knee-initial eviction has fired
    # keep observing with too few points since the removal for a fit: each
    # elapsed interval yields exactly one 'under-observed', never back-to-back
    reasons = []
    start = len(t)
    for j in range(12):
        i = start + 1 + j
        tuner.observe(i, float(synthetic_loss(np.asarray([i], float))[0]), 1.0)
        reasons.append(tuner.decide().reason)
    assert "under-observed" in reasons
    for a, b in zip(reasons, reasons[1:]):
        assert not (a == b == "under-observed"), reasons


def test_eviction_reintegration_average():
    import jax.numpy as jnp

    from repro.core.autotuner import evict_and_reintegrate

    replicas = {"w": jnp.stack([jnp.full((3,), float(i)) for i in range(4)])}
    mask = jnp.asarray([True, True, True, False])  # worker 3 leaves
    out = evict_and_reintegrate(replicas, 3, mask)
    # active workers average with the leaving replica (value 3.0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), 1.5)
    np.testing.assert_allclose(np.asarray(out["w"][1]), 2.0)
    np.testing.assert_allclose(np.asarray(out["w"][2]), 2.5)
    np.testing.assert_allclose(np.asarray(out["w"][3]), 3.0)  # inert
