"""Wire protocol + broker unit tests (repro.runtime.protocol / .broker).

Covers the framing/encoding layer the multi-process runtime stands on, and
the broker's barrier/membership/accounting semantics via real sockets (the
broker threads are the production server, spun up through the shared
``BrokerCluster`` harness; only the workers are stubbed).
"""

from __future__ import annotations

import socket
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import protocol
from repro.runtime.broker import WriteAheadLog

from runtime_harness import BrokerCluster


# -- framing ------------------------------------------------------------------


def test_framing_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        header = {"t": "publish", "worker": 3, "nested": {"x": [1, 2]}}
        payload = bytes(range(256)) * 17
        n = protocol.send_msg(a, header, payload)
        got_h, got_p = protocol.recv_msg(b)
        assert got_h == header
        assert got_p == payload
        assert n == 8 + len(payload) + len(
            __import__("json").dumps(header, separators=(",", ":"))
        )
    finally:
        a.close()
        b.close()


def test_framing_empty_payload():
    a, b = socket.socketpair()
    try:
        protocol.send_msg(a, {"t": "poll"})
        h, p = protocol.recv_msg(b)
        assert h == {"t": "poll"} and p == b""
    finally:
        a.close()
        b.close()


# -- pytree encoding ----------------------------------------------------------


def _tree():
    return {
        "U": jnp.zeros((16, 4), jnp.float32),
        "M": jnp.ones((4, 8), jnp.float32),
    }


def test_encode_decode_dense_and_sparse():
    tree = _tree()
    # mostly-zero leaf -> sparse; dense leaf stays dense
    tree["U"] = tree["U"].at[3, 2].set(1.5).at[7, 0].set(-2.0)
    meta, payload = protocol.encode_tree(tree)
    by_key = {m["k"]: m for m in meta}
    assert by_key["M"]["enc"] == "dense"
    assert by_key["U"]["enc"] == "sparse" and by_key["U"]["nnz"] == 2
    out = protocol.decode_tree(meta, payload, tree)
    for a, b in zip(
        np.asarray(tree["U"]), np.asarray(out["U"])
    ):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(tree["M"]), out["M"])
    # sparse wire bytes: nnz * (4B index + 4B fp32 value)
    assert by_key["U"]["nbytes"] == 2 * 8
    assert protocol.wire_bytes(meta) == 2 * 8 + 4 * 8 * 4


def test_decode_rejects_wrong_template():
    meta, payload = protocol.encode_tree(_tree())
    with pytest.raises(ValueError):
        protocol.decode_tree(meta, payload, {"only": jnp.zeros(3)})


def test_pack_unpack_parts():
    parts = [({"worker": 0}, b"abc"), ({"worker": 1}, b"defgh")]
    descs, blob = protocol.pack_parts(parts)
    out = protocol.unpack_parts(descs, blob)
    assert [p[1] for p in out] == [b"abc", b"defgh"]
    assert [p[0]["worker"] for p in out] == [0, 1]


# -- broker over real sockets -------------------------------------------------


JOB = {
    "workload": "pmf",
    "workload_cfg": {},
    "n_workers": 2,
    "total_steps": 10,
    "n_batches": 5,
}


@pytest.fixture()
def cluster():
    with BrokerCluster(dict(JOB)) as c:
        yield c


@pytest.fixture()
def broker(cluster):
    return cluster.coordinator


def test_broker_hello_and_batch_keys(cluster):
    resp, _ = cluster.rpc({"t": "hello", "worker": 0})
    assert resp["ok"] and resp["job"]["n_workers"] == 2
    assert resp["shard_id"] == 0 and resp["n_shards"] == 1
    # deterministic round-robin minibatch keys: (step-1)*P + worker mod n
    keys = [
        cluster.rpc({"t": "batch", "worker": w, "step": s})[0]["key"]
        for s in (1, 2) for w in (0, 1)
    ]
    assert keys == [0, 1, 2, 3]


def test_broker_barrier_blocks_until_all_publish(cluster):
    meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
    cluster.rpc(
        {"t": "publish", "worker": 0, "step": 1, "meta": meta,
         "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
        payload,
    )
    resp, _ = cluster.rpc(
        {"t": "pull", "worker": 0, "step": 1, "timeout_s": 0.1}
    )
    assert resp["ready"] is False  # worker 1 hasn't published
    done = {}

    def late_publish():
        cluster.rpc(
            {"t": "publish", "worker": 1, "step": 1, "meta": meta,
             "loss": 2.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        done["published"] = True

    t = threading.Thread(target=late_publish)
    t.start()
    resp, blob = cluster.rpc(
        {"t": "pull", "worker": 0, "step": 1, "timeout_s": 5.0}
    )
    t.join()
    assert resp["ready"] is True
    parts = protocol.unpack_parts(resp["parts"], blob)
    assert [p[0]["worker"] for p in parts] == [1]
    got = protocol.decode_tree(
        parts[0][0]["meta"], parts[0][1], {"x": jnp.zeros(4)}
    )
    np.testing.assert_array_equal(got["x"], np.ones(4))


def test_broker_duplicate_publish_is_idempotent(cluster, broker):
    meta, payload = protocol.encode_tree({"x": jnp.arange(4.0)})
    h = {"t": "publish", "worker": 0, "step": 2, "meta": meta,
         "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0}
    r1, _ = cluster.rpc(h, payload)
    r2, _ = cluster.rpc(h, payload)  # bit-identical replay
    assert (r1["dup"], r2["dup"]) == (False, True)
    assert broker.core.dup_mismatches == 0
    # a dup does not double-count the shard's update-byte meter
    assert broker.core.update_bytes == protocol.wire_bytes(meta)
    # a diverging replay is counted (the determinism tripwire)
    meta2, payload2 = protocol.encode_tree({"x": jnp.arange(4.0) + 1})
    cluster.rpc({**h, "meta": meta2}, payload2)
    assert broker.core.dup_mismatches == 1


def test_broker_evict_step_is_safely_in_the_future(cluster, broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        cluster.rpc(
            {"t": "publish", "worker": w, "step": 3, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    resp, _ = cluster.rpc({"t": "evict", "worker": 1})
    assert resp["granted"] and resp["evict_step"] == 5  # max_published + 2
    assert broker.core.active_at(4) == [0, 1]
    assert broker.core.active_at(5) == [0]
    # idempotent
    again, _ = cluster.rpc({"t": "evict", "worker": 1})
    assert again["granted"] and again["evict_step"] == 5
    # a second eviction granted back-to-back gets a DISTINCT effective step:
    # one leaver per step keeps the survivors' sequential mean-preserving
    # pulls exact
    other, _ = cluster.rpc({"t": "evict", "worker": 0})
    assert other["granted"] and other["evict_step"] == 6


def test_broker_refuses_eviction_past_job_end(cluster, broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        cluster.rpc(
            {"t": "publish", "worker": w, "step": 9, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    # effective step would be 11 > total_steps=10: the pool finishes before
    # the eviction could land, so granting it would strand the flush
    resp, _ = cluster.rpc({"t": "evict", "worker": 1})
    assert resp["granted"] is False and resp["reason"] == "past-end"
    assert broker.core.evictions == {}


def test_persistent_connection_many_round_trips(cluster, broker):
    """One TCP connection, many framed request/response round trips — the
    coalesced data path (DESIGN.md §10.3)."""
    with protocol.Connection(cluster.addrs[0]) as conn:
        for s in (1, 2, 3):
            resp, _ = conn.request({"t": "batch", "worker": 0, "step": s})
            assert resp["ok"] and resp["key"] == ((s - 1) * 2) % 5
        # a tensor publish and a poll ride the same socket
        meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
        resp, _ = conn.request(
            {"t": "publish", "worker": 0, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        assert resp["ok"]
    # exactly one connection's worth of batch traffic was accounted
    assert broker.core.stats["batch"]["count"] == 3


def test_connection_survives_reconnect(cluster):
    conn = protocol.Connection(cluster.addrs[0])
    resp, _ = conn.request({"t": "batch", "worker": 0, "step": 1})
    assert resp["ok"]
    conn._sock.close()  # simulate a dropped connection mid-invocation
    resp, _ = conn.request({"t": "batch", "worker": 0, "step": 2})
    assert resp["ok"]  # transparently reconnected and replayed
    conn.close()


def test_pull_piggybacks_next_batch_key(cluster):
    """The ready pull response carries the NEXT step's minibatch key, so
    the steady-state worker loop is publish + pull only."""
    meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
    for w in (0, 1):
        cluster.rpc(
            {"t": "publish", "worker": w, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    resp, _ = cluster.rpc(
        {"t": "pull", "worker": 1, "step": 1, "timeout_s": 5.0}
    )
    assert resp["ready"] is True
    # key for (step=2, worker=1): ((2-1)*P + 1) % n_batches = 3
    assert resp["key_next"] == 3


def test_poll_with_since_cursor_is_idempotent(cluster):
    """A cursor-carrying poll re-serves the same rows on replay — the
    supervisor's retrying Connection must not lose telemetry when a poll
    response is dropped mid-flight."""
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        cluster.rpc(
            {"t": "publish", "worker": w, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        cluster.rpc({"t": "report", "worker": w, "step": 1, "dur_s": 0.5})
    r1, _ = cluster.rpc({"t": "poll", "since": 1})
    r2, _ = cluster.rpc({"t": "poll", "since": 1})  # replay
    assert [r["step"] for r in r1["rows"]] == [1]
    assert r1["rows"] == r2["rows"]
    # and the server-side cursor of legacy polls was not advanced by them
    r3, _ = cluster.rpc({"t": "poll"})
    assert [r["step"] for r in r3["rows"]] == [1]


def test_broker_accounts_bytes_per_message_type(cluster):
    meta, payload = protocol.encode_tree({"x": jnp.ones(8)})
    cluster.rpc(
        {"t": "publish", "worker": 0, "step": 1, "meta": meta,
         "loss": 0.0, "sent_fraction": 1.0, "inv_err": 0.0},
        payload,
    )
    cluster.rpc({"t": "batch", "worker": 0, "step": 1})
    stats, _ = cluster.rpc({"t": "stats"})
    s = stats["stats"]
    assert stats["update_bytes"] == protocol.wire_bytes(meta)
    assert s["publish"]["count"] == 1
    assert s["publish"]["bytes_in"] >= len(payload)
    assert s["batch"]["count"] == 1 and s["batch"]["bytes_out"] > 0


# -- sharded coordinator semantics --------------------------------------------


@pytest.fixture()
def sharded():
    with BrokerCluster(dict(JOB), n_shards=2) as c:
        yield c


def test_noncoordinator_refuses_evict_but_applies_sync(sharded):
    """Membership is minted on shard 0 only; other shards install the
    granted (worker, step) via evict_apply — the supervisor's sync."""
    resp, _ = sharded.rpc({"t": "evict", "worker": 1}, shard=1)
    assert resp["ok"] is False and "coordinator" in resp["error"]
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        sharded.rpc(
            {"t": "publish", "worker": w, "step": 3, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    grant, _ = sharded.rpc({"t": "evict", "worker": 1})
    assert grant["granted"]
    sync, _ = sharded.rpc(
        {"t": "evict_apply", "worker": 1, "step": grant["evict_step"]},
        shard=1,
    )
    assert sync["ok"]
    assert sharded.brokers[1].core.evictions == {1: grant["evict_step"]}
    # a conflicting re-install is rejected, idempotent one accepted
    bad, _ = sharded.rpc(
        {"t": "evict_apply", "worker": 1, "step": grant["evict_step"] + 1},
        shard=1,
    )
    assert bad["ok"] is False
    ok, _ = sharded.rpc(
        {"t": "evict_apply", "worker": 1, "step": grant["evict_step"]},
        shard=1,
    )
    assert ok["ok"]


def test_noncoordinator_pull_has_no_key_next(sharded):
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        for s in (0, 1):
            sharded.rpc(
                {"t": "publish", "worker": w, "step": 1, "meta": meta},
                payload, shard=s,
            )
    r0, _ = sharded.rpc(
        {"t": "pull", "worker": 0, "step": 1, "timeout_s": 5.0}
    )
    r1, _ = sharded.rpc(
        {"t": "pull", "worker": 0, "step": 1, "timeout_s": 5.0}, shard=1
    )
    assert r0["ready"] and "key_next" in r0
    assert r1["ready"] and "key_next" not in r1


# -- write-ahead log ----------------------------------------------------------


def test_wal_replay_restores_broker_state(tmp_path):
    """A respawned shard replays its WAL and resumes bit-identically: the
    stored update survives, a retried publish dup-checks clean, and the
    granted eviction is still installed."""
    meta, payload = protocol.encode_tree({"x": jnp.arange(6.0)})
    pub = {"t": "publish", "worker": 0, "step": 1, "meta": meta,
           "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0}
    with BrokerCluster(dict(JOB), wal_dir=str(tmp_path)) as c1:
        c1.rpc(pub, payload)
        c1.rpc(
            {"t": "publish", "worker": 1, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        c1.rpc({"t": "evict", "worker": 1})
    # "respawn": a fresh cluster over the same WAL directory
    with BrokerCluster(dict(JOB), wal_dir=str(tmp_path)) as c2:
        core = c2.coordinator.core
        assert core.max_published == 1
        assert core.evictions == {1: 3}
        assert core.update_bytes == 2 * protocol.wire_bytes(meta)
        # the worker's retried publish is a bit-identical dup
        r, _ = c2.rpc(pub, payload)
        assert r["dup"] is True and core.dup_mismatches == 0
        # and the barrier over the replayed store still serves pulls
        r, blob = c2.rpc(
            {"t": "pull", "worker": 0, "step": 1, "timeout_s": 5.0}
        )
        assert r["ready"] is True
        parts = protocol.unpack_parts(r["parts"], blob)
        got = protocol.decode_tree(
            parts[0][0]["meta"], parts[0][1], {"x": jnp.zeros(6)}
        )
        np.testing.assert_array_equal(got["x"], np.arange(6.0))


def test_wal_tolerates_torn_tail(tmp_path):
    """A SIGKILL can truncate the final record; replay must stop there
    instead of exploding (the op was never acked, so it gets retried)."""
    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    wal.append({"t": "report", "worker": 0, "step": 1, "dur_s": 0.5}, b"")
    wal.append({"t": "bye", "worker": 0, "reason": "done"}, b"xyz")
    wal.close()
    with open(path, "rb") as f:
        raw = f.read()
    with open(path, "wb") as f:
        f.write(raw[:-2])  # tear the tail
    records = list(WriteAheadLog.iter_records(path))
    assert len(records) == 1
    assert records[0][0]["t"] == "report"


def test_wal_persists_dup_mismatch_counter(tmp_path):
    """A detected replay divergence must survive a shard respawn: the
    determinism tripwire is logged as a payload-free marker and restored
    by WAL replay (a crashed shard must not launder a real divergence)."""
    from repro.runtime.broker import BrokerCore

    path = str(tmp_path / "w.wal")
    meta, payload = protocol.encode_tree({"x": jnp.arange(4.0)})
    meta2, payload2 = protocol.encode_tree({"x": jnp.arange(4.0) + 1})
    core = BrokerCore(dict(JOB))
    core.attach_wal(path)
    h = {"t": "publish", "worker": 0, "step": 1, "meta": meta}
    core.handle(h, payload)
    core.handle({**h, "meta": meta2}, payload2)  # diverging replay
    assert core.dup_mismatches == 1
    core._wal.close()
    core2 = BrokerCore(dict(JOB))
    core2.attach_wal(path)
    assert core2.dup_mismatches == 1  # survived the "respawn"


def test_wal_truncates_torn_tail_before_appending(tmp_path):
    """The torn tail must be CUT before new records are appended —
    otherwise a record written after the garbage is unreachable to the
    next replay, and a second crash silently loses acked mutations."""
    from repro.runtime.broker import BrokerCore

    path = str(tmp_path / "w.wal")
    wal = WriteAheadLog(path)
    wal.append({"t": "report", "worker": 0, "step": 1, "dur_s": 0.5}, b"")
    wal.append({"t": "bye", "worker": 0, "reason": "done"}, b"xyz")
    wal.close()
    with open(path, "rb") as f:
        raw = f.read()
    with open(path, "wb") as f:
        f.write(raw[:-2])  # first crash: torn tail
    core = BrokerCore(dict(JOB))
    assert core.attach_wal(path) == 1  # replays up to the tear
    # an acked mutation after the respawn...
    core.handle({"t": "report", "worker": 1, "step": 2, "dur_s": 0.1}, b"")
    core._wal.close()
    # ...survives the SECOND crash/replay
    core2 = BrokerCore(dict(JOB))
    assert core2.attach_wal(path) == 2
    assert (2, 1) in core2.telemetry
