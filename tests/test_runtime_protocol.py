"""Wire protocol + broker unit tests (repro.runtime.protocol / .broker).

Covers the framing/encoding layer the multi-process runtime stands on, and
the broker's barrier/membership/accounting semantics via real sockets (the
broker thread is the production server; only the workers are stubbed).
"""

from __future__ import annotations

import socket
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import protocol
from repro.runtime.broker import Broker


# -- framing ------------------------------------------------------------------


def test_framing_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        header = {"t": "publish", "worker": 3, "nested": {"x": [1, 2]}}
        payload = bytes(range(256)) * 17
        n = protocol.send_msg(a, header, payload)
        got_h, got_p = protocol.recv_msg(b)
        assert got_h == header
        assert got_p == payload
        assert n == 8 + len(payload) + len(
            __import__("json").dumps(header, separators=(",", ":"))
        )
    finally:
        a.close()
        b.close()


def test_framing_empty_payload():
    a, b = socket.socketpair()
    try:
        protocol.send_msg(a, {"t": "poll"})
        h, p = protocol.recv_msg(b)
        assert h == {"t": "poll"} and p == b""
    finally:
        a.close()
        b.close()


# -- pytree encoding ----------------------------------------------------------


def _tree():
    return {
        "U": jnp.zeros((16, 4), jnp.float32),
        "M": jnp.ones((4, 8), jnp.float32),
    }


def test_encode_decode_dense_and_sparse():
    tree = _tree()
    # mostly-zero leaf -> sparse; dense leaf stays dense
    tree["U"] = tree["U"].at[3, 2].set(1.5).at[7, 0].set(-2.0)
    meta, payload = protocol.encode_tree(tree)
    by_key = {m["k"]: m for m in meta}
    assert by_key["M"]["enc"] == "dense"
    assert by_key["U"]["enc"] == "sparse" and by_key["U"]["nnz"] == 2
    out = protocol.decode_tree(meta, payload, tree)
    for a, b in zip(
        np.asarray(tree["U"]), np.asarray(out["U"])
    ):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(tree["M"]), out["M"])
    # sparse wire bytes: nnz * (4B index + 4B fp32 value)
    assert by_key["U"]["nbytes"] == 2 * 8
    assert protocol.wire_bytes(meta) == 2 * 8 + 4 * 8 * 4


def test_decode_rejects_wrong_template():
    meta, payload = protocol.encode_tree(_tree())
    with pytest.raises(ValueError):
        protocol.decode_tree(meta, payload, {"only": jnp.zeros(3)})


def test_pack_unpack_parts():
    parts = [({"worker": 0}, b"abc"), ({"worker": 1}, b"defgh")]
    descs, blob = protocol.pack_parts(parts)
    out = protocol.unpack_parts(descs, blob)
    assert [p[1] for p in out] == [b"abc", b"defgh"]
    assert [p[0]["worker"] for p in out] == [0, 1]


# -- broker over real sockets -------------------------------------------------


JOB = {
    "workload": "pmf",
    "workload_cfg": {},
    "n_workers": 2,
    "total_steps": 10,
    "n_batches": 5,
}


@pytest.fixture()
def broker():
    b = Broker(dict(JOB))
    b.start()
    yield b
    b.stop()


def _rpc(broker, header, payload=b""):
    return protocol.request(broker.addr, header, payload, timeout=10.0)


def test_broker_hello_and_batch_keys(broker):
    resp, _ = _rpc(broker, {"t": "hello", "worker": 0})
    assert resp["ok"] and resp["job"]["n_workers"] == 2
    # deterministic round-robin minibatch keys: (step-1)*P + worker mod n
    keys = [
        _rpc(broker, {"t": "batch", "worker": w, "step": s})[0]["key"]
        for s in (1, 2) for w in (0, 1)
    ]
    assert keys == [0, 1, 2, 3]


def test_broker_barrier_blocks_until_all_publish(broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
    _rpc(
        broker,
        {"t": "publish", "worker": 0, "step": 1, "meta": meta,
         "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
        payload,
    )
    resp, _ = _rpc(
        broker, {"t": "pull", "worker": 0, "step": 1, "timeout_s": 0.1}
    )
    assert resp["ready"] is False  # worker 1 hasn't published
    done = {}

    def late_publish():
        _rpc(
            broker,
            {"t": "publish", "worker": 1, "step": 1, "meta": meta,
             "loss": 2.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        done["published"] = True

    t = threading.Thread(target=late_publish)
    t.start()
    resp, blob = _rpc(
        broker, {"t": "pull", "worker": 0, "step": 1, "timeout_s": 5.0}
    )
    t.join()
    assert resp["ready"] is True
    parts = protocol.unpack_parts(resp["parts"], blob)
    assert [p[0]["worker"] for p in parts] == [1]
    got = protocol.decode_tree(
        parts[0][0]["meta"], parts[0][1], {"x": jnp.zeros(4)}
    )
    np.testing.assert_array_equal(got["x"], np.ones(4))


def test_broker_duplicate_publish_is_idempotent(broker):
    meta, payload = protocol.encode_tree({"x": jnp.arange(4.0)})
    h = {"t": "publish", "worker": 0, "step": 2, "meta": meta,
         "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0}
    r1, _ = _rpc(broker, h, payload)
    r2, _ = _rpc(broker, h, payload)  # bit-identical replay
    assert (r1["dup"], r2["dup"]) == (False, True)
    assert broker.core.dup_mismatches == 0
    # a diverging replay is counted (the determinism tripwire)
    meta2, payload2 = protocol.encode_tree({"x": jnp.arange(4.0) + 1})
    _rpc(broker, {**h, "meta": meta2}, payload2)
    assert broker.core.dup_mismatches == 1


def test_broker_evict_step_is_safely_in_the_future(broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        _rpc(
            broker,
            {"t": "publish", "worker": w, "step": 3, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    resp, _ = _rpc(broker, {"t": "evict", "worker": 1})
    assert resp["granted"] and resp["evict_step"] == 5  # max_published + 2
    assert broker.core.active_at(4) == [0, 1]
    assert broker.core.active_at(5) == [0]
    # idempotent
    again, _ = _rpc(broker, {"t": "evict", "worker": 1})
    assert again["granted"] and again["evict_step"] == 5
    # a second eviction granted back-to-back gets a DISTINCT effective step:
    # one leaver per step keeps the survivors' sequential mean-preserving
    # pulls exact
    other, _ = _rpc(broker, {"t": "evict", "worker": 0})
    assert other["granted"] and other["evict_step"] == 6


def test_broker_refuses_eviction_past_job_end(broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        _rpc(
            broker,
            {"t": "publish", "worker": w, "step": 9, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    # effective step would be 11 > total_steps=10: the pool finishes before
    # the eviction could land, so granting it would strand the flush
    resp, _ = _rpc(broker, {"t": "evict", "worker": 1})
    assert resp["granted"] is False and resp["reason"] == "past-end"
    assert broker.core.evictions == {}


def test_persistent_connection_many_round_trips(broker):
    """One TCP connection, many framed request/response round trips — the
    coalesced data path (DESIGN.md §10.3)."""
    with protocol.Connection(broker.addr) as conn:
        for s in (1, 2, 3):
            resp, _ = conn.request({"t": "batch", "worker": 0, "step": s})
            assert resp["ok"] and resp["key"] == ((s - 1) * 2) % 5
        # a tensor publish and a poll ride the same socket
        meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
        resp, _ = conn.request(
            {"t": "publish", "worker": 0, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        assert resp["ok"]
    # exactly one connection's worth of batch traffic was accounted
    assert broker.core.stats["batch"]["count"] == 3


def test_connection_survives_reconnect(broker):
    conn = protocol.Connection(broker.addr)
    resp, _ = conn.request({"t": "batch", "worker": 0, "step": 1})
    assert resp["ok"]
    conn._sock.close()  # simulate a dropped connection mid-invocation
    resp, _ = conn.request({"t": "batch", "worker": 0, "step": 2})
    assert resp["ok"]  # transparently reconnected and replayed
    conn.close()


def test_pull_piggybacks_next_batch_key(broker):
    """The ready pull response carries the NEXT step's minibatch key, so
    the steady-state worker loop is publish + pull only."""
    meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
    for w in (0, 1):
        _rpc(
            broker,
            {"t": "publish", "worker": w, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
    resp, _ = _rpc(
        broker, {"t": "pull", "worker": 1, "step": 1, "timeout_s": 5.0}
    )
    assert resp["ready"] is True
    # key for (step=2, worker=1): ((2-1)*P + 1) % n_batches = 3
    assert resp["key_next"] == 3


def test_poll_with_since_cursor_is_idempotent(broker):
    """A cursor-carrying poll re-serves the same rows on replay — the
    supervisor's retrying Connection must not lose telemetry when a poll
    response is dropped mid-flight."""
    meta, payload = protocol.encode_tree({"x": jnp.ones(2)})
    for w in (0, 1):
        _rpc(
            broker,
            {"t": "publish", "worker": w, "step": 1, "meta": meta,
             "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
            payload,
        )
        _rpc(broker, {"t": "report", "worker": w, "step": 1, "dur_s": 0.5})
    r1, _ = _rpc(broker, {"t": "poll", "since": 1})
    r2, _ = _rpc(broker, {"t": "poll", "since": 1})  # replay
    assert [r["step"] for r in r1["rows"]] == [1]
    assert r1["rows"] == r2["rows"]
    # and the server-side cursor of legacy polls was not advanced by them
    r3, _ = _rpc(broker, {"t": "poll"})
    assert [r["step"] for r in r3["rows"]] == [1]


def test_broker_accounts_bytes_per_message_type(broker):
    meta, payload = protocol.encode_tree({"x": jnp.ones(8)})
    _rpc(
        broker,
        {"t": "publish", "worker": 0, "step": 1, "meta": meta,
         "loss": 0.0, "sent_fraction": 1.0, "inv_err": 0.0},
        payload,
    )
    _rpc(broker, {"t": "batch", "worker": 0, "step": 1})
    stats, _ = _rpc(broker, {"t": "stats"})
    s = stats["stats"]
    assert s["publish"]["count"] == 1
    assert s["publish"]["bytes_in"] >= len(payload)
    assert s["batch"]["count"] == 1 and s["batch"]["bytes_out"] > 0
