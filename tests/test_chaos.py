"""Chaos-plane tests (repro.runtime.faults, DESIGN.md §17).

Three tiers, cheapest first:

* pure-function tests of the seeded ``FaultPlan`` expansion, event
  validation, ``--chaos`` parsing, the ``RetryPolicy`` backoff math and
  the worker-side event arming (no processes, no filesystem);
* integrity tests of the hardened stores: the WAL single-byte-flip
  property (ANY flipped byte yields a bit-identical valid prefix plus a
  clean quarantine/truncate — never wrong state) and the checkpoint
  content-digest fallback (a corrupt newest generation is skipped, an
  injected ENOSPC never installs a partial snapshot);
* end-to-end runs on the real multi-process runtime: a multi-fault plan
  (worker SIGKILL + every transport fault + straggler + ckpt ENOSPC)
  must finish bit-identical to the fault-free ``core.isp`` reference,
  and a ``supervisor_kill`` driven through ``run_job_resilient`` must
  journal-resume, re-adopt the pool and land on the same bits.
"""

from __future__ import annotations

import os
import shutil

import numpy as np
import pytest

from repro.checkpoint import store as ckpt
from repro.runtime.broker import Broker, WriteAheadLog, replay_wal
from repro.runtime.faults import (
    FaultEvent,
    FaultPlan,
    RetryPolicy,
    WorkerFaults,
    parse_chaos_arg,
    run_job_resilient,
)

from runtime_harness import (
    SMALL_P as P,
    final_params,
    reference_updates,
    small_pmf_cfg,
)


# -- seeded plan expansion ----------------------------------------------------


def test_randomized_plan_is_pure_function_of_seed():
    a = FaultPlan.randomized(1013, n_workers=3, n_shards=2, total_steps=24)
    b = FaultPlan.randomized(1013, n_workers=3, n_shards=2, total_steps=24)
    assert a == b  # same seed -> identical schedule, always
    assert a != FaultPlan.randomized(
        1014, n_workers=3, n_shards=2, total_steps=24
    )
    # one event of every default kind, victims in range, steps leaving
    # room to recover on both sides
    counts = a.counts()
    for kind in ("worker_kill", "broker_kill", "wal_corrupt",
                 "transport_stall", "supervisor_kill"):
        assert counts.get(kind, 0) >= 1
    for e in a.events:
        assert 3 <= e.step <= 24 - 6
        if e.worker is not None:
            assert 0 <= e.worker < 3
        if e.shard is not None:
            assert 0 <= e.shard < 2


def test_randomized_plan_requires_room_to_recover():
    with pytest.raises(ValueError, match="total_steps"):
        FaultPlan.randomized(1, n_workers=2, n_shards=1, total_steps=11)


def test_event_validation_rejects_malformed_events():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor_strike", 3).validate()
    with pytest.raises(ValueError, match="step"):
        FaultEvent("worker_kill", -1, worker=0).validate()
    with pytest.raises(ValueError, match="worker="):
        FaultEvent("worker_kill", 3).validate()
    with pytest.raises(ValueError, match="shard="):
        FaultEvent("broker_kill", 3).validate()
    with pytest.raises(ValueError, match="delay_s"):
        FaultEvent("transport_stall", 3, worker=0).validate()


def test_plan_spec_roundtrip():
    plan = FaultPlan.randomized(7, n_workers=3, n_shards=2, total_steps=20)
    assert FaultPlan.from_spec(plan.to_spec()) == plan
    assert FaultPlan.from_spec(None) is None


def test_parse_chaos_arg():
    auto = parse_chaos_arg("7:auto", n_workers=3, n_shards=2,
                           total_steps=24)
    assert auto == FaultPlan.randomized(7, 3, 2, 24)
    explicit = parse_chaos_arg(
        '5:[{"kind": "worker_kill", "step": 4, "worker": 1}]',
        n_workers=3, n_shards=1, total_steps=8,
    )
    assert explicit.seed == 5
    assert explicit.events == (FaultEvent("worker_kill", 4, worker=1),)
    for bad in ("x:auto", "7:", '7:[{"kind": "nope", "step": 1}]'):
        with pytest.raises(SystemExit, match="--chaos"):
            parse_chaos_arg(bad, n_workers=3, n_shards=1, total_steps=24)


def test_legacy_knobs_compile_into_the_plan(tmp_path):
    cfg = small_pmf_cfg(
        tmp_path / "job",
        kill_worker_at_step=(1, 3),
        straggler={"worker": 0, "delay_s": 0.1, "every": 2},
    )
    plan = cfg.compiled_chaos_plan()
    assert plan.counts() == {"worker_kill": 1, "compute_delay": 1}
    kill = next(e for e in plan.events if e.kind == "worker_kill")
    assert (kill.worker, kill.step) == (1, 3)
    # the compiled plan ships to workers through job_dict
    assert cfg.job_dict(n_batches=5)["chaos"] == plan.to_spec()


def test_no_knobs_means_no_plan_and_no_wire_key(tmp_path):
    cfg = small_pmf_cfg(tmp_path / "job")
    assert cfg.compiled_chaos_plan() is None
    # dormancy at the wire level: the hello bytes carry no chaos/rpc keys
    # unless set, so default runs stay byte-identical to pre-chaos builds
    d = cfg.job_dict(n_batches=5)
    assert "chaos" not in d and "rpc" not in d


def test_config_roundtrips_through_json(tmp_path):
    import json

    cfg = small_pmf_cfg(
        tmp_path / "job",
        chaos={"seed": 3, "events": [
            {"kind": "worker_kill", "step": 4, "worker": 0}]},
        rpc={"timeout_s": 5.0, "tries": 3},
    )
    from repro.runtime.supervisor import FaaSJobConfig

    back = FaaSJobConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg


# -- retry policy -------------------------------------------------------------


def test_retry_policy_backoff_is_deterministic_and_bounded():
    p = RetryPolicy(backoff_s=0.25, backoff_cap_s=2.0, seed=7)
    again = RetryPolicy(backoff_s=0.25, backoff_cap_s=2.0, seed=7)
    for i in range(8):
        b = p.backoff(i)
        assert b == again.backoff(i)  # same seed -> same jitter
        base = min(2.0, 0.25 * 2.0 ** i)
        assert 0.5 * base <= b <= base  # full jitter in [0.5, 1.0] * base


def test_retry_policy_attempts_bounded_by_tries():
    p = RetryPolicy(tries=3, backoff_s=0.001, backoff_cap_s=0.002,
                    deadline_s=10.0)
    assert list(p.attempts()) == [0, 1, 2]


def test_retry_policy_deadline_stops_the_loop():
    # first pause (>= 0.1s) would already blow the 50 ms deadline
    p = RetryPolicy(tries=50, backoff_s=0.2, backoff_cap_s=0.2,
                    deadline_s=0.05)
    assert list(p.attempts()) == [0]


def test_retry_policy_reseed_decorrelates_callers():
    p = RetryPolicy(seed=1)
    assert len({p.seed, p.reseed(3).seed, p.reseed(4).seed}) == 3


def test_rpc_policy_flows_from_job_config(tmp_path):
    from repro.runtime.supervisor import Supervisor

    cfg = small_pmf_cfg(tmp_path / "job",
                        rpc={"timeout_s": 5.0, "tries": 3})
    sup = Supervisor(cfg)
    assert sup.rpc_policy.timeout_s == 5.0
    assert sup.rpc_policy.tries == 3
    assert sup.rpc_policy.deadline_s == 120.0  # unset fields keep defaults


# -- worker-side event arming -------------------------------------------------


def test_worker_faults_compute_delay_schedule():
    plan = FaultPlan(events=(
        FaultEvent("compute_delay", 4, worker=0, delay_s=0.5, every=3),
        FaultEvent("compute_delay", 2, worker=0, delay_s=0.25),
    ))
    wf = WorkerFaults(plan, worker_id=0)
    assert wf.compute_delay_s(1) == 0.0
    assert wf.compute_delay_s(2) == 0.25  # one-shot fires exactly once
    assert wf.compute_delay_s(3) == 0.0
    assert wf.compute_delay_s(4) == 0.5  # every=3: steps 4, 7, 10, ...
    assert wf.compute_delay_s(5) == 0.0
    assert wf.compute_delay_s(7) == 0.5
    # another worker's view of the same plan is empty
    assert WorkerFaults(plan, worker_id=1).compute_delay_s(4) == 0.0


def test_worker_faults_ckpt_enospc_fires_once():
    plan = FaultPlan(events=(FaultEvent("ckpt_enospc", 6, worker=2),))
    wf = WorkerFaults(plan, worker_id=2)
    assert not wf.ckpt_should_fail(4)  # not armed yet
    assert wf.ckpt_should_fail(8)  # first checkpoint at/after the step
    assert not wf.ckpt_should_fail(8)  # one-shot


# -- WAL integrity: the single-byte-flip property -----------------------------


def _write_wal(path: str) -> list:
    wal = WriteAheadLog(path)
    records = []
    for i in range(6):
        header = {"t": "publish", "worker": i % 3, "step": i}
        payload = bytes((i * 37 + j) % 256 for j in range(48 + 16 * i))
        wal.append(header, payload)
        records.append((header, payload))
    wal.close()
    return records


def test_wal_any_single_byte_flip_yields_prefix_or_quarantine(tmp_path):
    """Flip EVERY byte of a WAL, one at a time: replay must always yield a
    bit-identical strict prefix of the original records — via CRC
    quarantine or torn-tail truncation — and never a wrong record."""
    src = tmp_path / "src.wal"
    records = _write_wal(str(src))
    blob = src.read_bytes()
    path = str(tmp_path / "flip.wal")
    qpath = path + ".quarantine"
    for off in range(len(blob)):
        corrupted = bytearray(blob)
        corrupted[off] ^= 0xFF
        with open(path, "wb") as f:
            f.write(bytes(corrupted))
        if os.path.exists(qpath):
            os.unlink(qpath)
        out = []
        replayed, quarantined = replay_wal(
            path, lambda h, p: out.append((h, p)))
        assert replayed == len(out) < len(records), f"offset {off}"
        assert out == records[:len(out)], f"offset {off}: wrong state"
        if quarantined:
            assert os.path.getsize(qpath) == quarantined
        # the live log was truncated to its valid prefix: a second replay
        # is clean and identical (what a respawned shard actually sees)
        out2 = []
        assert replay_wal(path, lambda h, p: out2.append((h, p))) == (
            replayed, 0)
        assert out2 == out


def test_wal_clean_log_replays_fully(tmp_path):
    path = str(tmp_path / "ok.wal")
    records = _write_wal(path)
    out = []
    assert replay_wal(path, lambda h, p: out.append((h, p))) == (
        len(records), 0)
    assert out == records
    assert not os.path.exists(path + ".quarantine")


# -- checkpoint integrity -----------------------------------------------------


def _corrupt_newest_arrays(directory: str, step: int) -> None:
    """Flip one stored value inside the npz WITHOUT touching the embedded
    manifest — the digest-mismatch case (a torn/garbled npz would fail
    the load itself; this is the nastier silent-bit-rot shape)."""
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    data = dict(np.load(path))
    key = next(k for k in sorted(data) if k != "__manifest__")
    arr = data[key].copy()
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF
    data[key] = arr
    np.savez(path, **data)


def test_ckpt_digest_mismatch_falls_back_to_previous_generation(tmp_path):
    d = str(tmp_path / "ck")
    t2 = {"a": np.arange(32, dtype=np.float32),
          "b": np.ones((4, 4), np.float32)}
    t4 = {"a": t2["a"] * 2.0, "b": t2["b"] * 3.0}
    ckpt.save(d, 2, t2)
    ckpt.save(d, 4, t4)
    _corrupt_newest_arrays(d, 4)
    with pytest.raises(ckpt.CheckpointCorruption):
        ckpt.restore(d, 4, t4)
    step, got = ckpt.restore_latest_valid(d, t2)
    assert step == 2  # the corrupt generation was skipped, not served
    for k in t2:
        np.testing.assert_array_equal(got[k], t2[k])


def test_ckpt_restore_latest_valid_cold_start(tmp_path):
    assert ckpt.restore_latest_valid(str(tmp_path / "none"), {}) == (
        None, None)


def test_ckpt_enospc_never_installs_a_partial_snapshot(tmp_path):
    d = str(tmp_path / "ck")
    t = {"a": np.arange(16, dtype=np.float32)}
    ckpt.save(d, 1, t)

    def boom(tmp_dir):
        raise OSError(28, "No space left on device")

    ckpt.install_write_fault_hook(boom)
    try:
        with pytest.raises(OSError):
            ckpt.save(d, 2, t)
    finally:
        ckpt.clear_write_fault_hook()
    # the failed write is invisible: no new generation, no staging litter
    assert ckpt.latest_step(d) == 1
    assert not [e for e in os.listdir(d) if ".tmp-" in e]
    # and the store still works once space is back
    ckpt.save(d, 2, t)
    assert ckpt.latest_step(d) == 2


# -- broker shutdown ----------------------------------------------------------


def test_broker_stop_reports_no_wedged_threads_on_clean_shutdown():
    b = Broker({
        "workload": "pmf",
        "workload_cfg": {},
        "n_workers": 2,
        "total_steps": 10,
        "n_batches": 5,
    })
    b.start()
    assert b.stop(timeout=5.0) == []


# -- guardrails ---------------------------------------------------------------


def test_supervisor_kill_refused_in_process(tmp_path):
    from repro.runtime import run_job

    cfg = small_pmf_cfg(
        tmp_path / "job",
        chaos={"seed": 1, "events": [{"kind": "supervisor_kill",
                                      "step": 3}]},
    )
    with pytest.raises(ValueError, match="supervisor_kill"):
        run_job(cfg)


def test_fleet_scheduler_rejects_chaos_plans(tmp_path):
    from repro.runtime.scheduler import FleetConfig, FleetScheduler

    cfg = small_pmf_cfg(
        tmp_path / "jobs" / "a",
        chaos={"seed": 1, "events": [{"kind": "worker_kill", "step": 3,
                                      "worker": 0}]},
    )
    with pytest.raises(ValueError, match="chaos"):
        FleetScheduler(FleetConfig(run_dir=str(tmp_path),
                                   jobs={"a": cfg}))


# -- end-to-end: multi-fault plan on the live runtime -------------------------

CHAOS_STEPS = 12
CHAOS_CKPT_EVERY = 4
# one event on every worker-side seam plus a real SIGKILL, all recoverable
CHAOS_EVENTS = [
    {"kind": "compute_delay", "step": 2, "worker": 1, "delay_s": 0.05,
     "every": 3},
    {"kind": "transport_stall", "step": 3, "worker": 0, "delay_s": 0.3},
    {"kind": "transport_delay", "step": 4, "worker": 2, "delay_s": 0.2},
    {"kind": "worker_kill", "step": 5, "worker": 1},
    {"kind": "transport_reset", "step": 6, "worker": 2},
    {"kind": "ckpt_enospc", "step": 6, "worker": 0},
]


@pytest.fixture(scope="module")
def chaos_run(tmp_path_factory):
    """One shared multi-fault run (real processes are expensive)."""
    from repro.runtime import run_job

    tmp = tmp_path_factory.mktemp("chaos_e2e")
    cfg = small_pmf_cfg(
        tmp / "job",
        total_steps=CHAOS_STEPS,
        checkpoint_every=CHAOS_CKPT_EVERY,
        deadline_s=240.0,
        chaos={"seed": 11, "events": CHAOS_EVENTS},
    )
    return cfg, run_job(cfg)


def test_chaos_run_completes_every_step(chaos_run):
    _, res = chaos_run
    assert res["steps"] == CHAOS_STEPS
    assert res["final_pool"] == P
    assert res["dup_mismatches"] == 0
    assert res["invariant_max_err"] == 0.0


def test_chaos_worker_kill_fired_and_recovered(chaos_run):
    _, res = chaos_run
    kills = [e for e in res["chaos_events"] if e["kind"] == "worker_kill"]
    assert len(kills) == 1 and kills[0]["worker"] == 1
    assert kills[0]["recovery_s"] is not None  # settled before job end
    assert res["n_respawns"] >= 1
    ev = res["respawns"][0]
    assert ev["worker"] == 1 and ev["exit_code"] == -9
    assert ev["restored_step"] % CHAOS_CKPT_EVERY == 0


def test_chaos_final_params_bit_identical_to_reference(chaos_run):
    """The whole point of the plane: a run under every injected fault
    lands on the SAME bits as the fault-free core.isp reference."""
    import jax

    cfg, _ = chaos_run
    _, ref = reference_updates(steps=CHAOS_STEPS)
    for w in range(P):
        _, params = final_params(cfg, w)
        got = jax.tree_util.tree_leaves(params)
        want = jax.tree_util.tree_leaves(ref[w])
        assert len(got) == len(want)
        for g, x in zip(got, want):
            assert np.array_equal(np.asarray(g), np.asarray(x)), (
                f"worker {w} diverged from the reference replay")


def test_ckpt_fallback_on_real_run_artifacts(chaos_run):
    """Corrupt the newest checkpoint generation a real worker wrote
    (copied aside) and require the restore walk to serve the previous
    generation — the path a respawned worker takes after silent rot."""
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.runtime import build_workload

    cfg, _ = chaos_run
    src = os.path.join(cfg.run_dir, "ckpt", "w002")
    steps = ckpt.all_steps(src)
    assert len(steps) >= 2  # periodic + final generations are retained
    d = os.path.join(src + ".copy")
    shutil.copytree(src, d)
    _corrupt_newest_arrays(d, steps[-1])

    wl = build_workload(cfg.workload, cfg.workload_cfg)
    opt = optim.make(cfg.optimizer, cfg.lr)
    like = {
        "params": wl.params0,
        "opt": opt.init(wl.params0),
        "residual": jax.tree.map(jnp.zeros_like, wl.params0),
    }
    step, tree = ckpt.restore_latest_valid(d, like)
    assert step == steps[-2] and tree is not None


# -- end-to-end: supervisor self-kill + journal resume ------------------------


def test_supervisor_kill_resumes_and_stays_bit_identical(tmp_path):
    import jax

    cfg = small_pmf_cfg(
        tmp_path / "job",
        checkpoint_every=2,
        deadline_s=240.0,
        chaos={"seed": 5, "events": [{"kind": "supervisor_kill",
                                      "step": 3}]},
    )
    res = run_job_resilient(cfg)
    assert res["supervisor_restarts"] >= 1
    assert res["supervisor_resumed"] >= 1
    assert res["steps"] == cfg.total_steps
    assert res["dup_mismatches"] == 0
    kills = [e for e in res["chaos_events"]
             if e["kind"] == "supervisor_kill"]
    assert len(kills) == 1
    assert kills[0]["recovery_s"] is not None
    assert "readopted" in kills[0]
    # the journal-resumed run still lands on the reference bits
    _, ref = reference_updates(steps=cfg.total_steps)
    for w in range(P):
        _, params = final_params(cfg, w)
        for g, x in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(ref[w])):
            assert np.array_equal(np.asarray(g), np.asarray(x))
