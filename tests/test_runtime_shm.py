"""Runtime-level shared-memory transport tests (DESIGN.md §12): the live
FaaS job over ``transport='shm'`` must be indistinguishable from TCP in
every accounted byte and every parameter bit — through worker SIGKILL
(fresh segments per respawned invocation) and broker-shard SIGKILL (WAL
replay + segment re-serve) — plus the oversized-leaf splitting that keeps
high shard counts from degenerating (``runtime.sharding``)."""

from __future__ import annotations

import platform
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.wire import shm as wire_shm
from runtime_harness import (
    SMALL_PMF_WCFG,
    final_params,
    reference_updates,
    run_small_pmf,
    small_pmf_cfg,
)

pytestmark = pytest.mark.skipif(
    not sys.platform.startswith("linux")
    or platform.machine() not in wire_shm.SHM_MACHINES,
    reason="shm transport targets same-host Linux on TSO machines",
)


def _leaves(tree):
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def test_shm_bit_identical_to_tcp(tmp_path):
    """Same job, both transports, 2 broker shards: accounted bytes,
    per-shard splits, and final parameters must match bit-for-bit."""
    from repro.runtime import run_job

    runs = {}
    cfgs = {}
    for transport in ("tcp", "shm"):
        cfg = small_pmf_cfg(
            tmp_path / transport, transport=transport, n_brokers=2
        )
        runs[transport] = run_job(cfg)
        cfgs[transport] = cfg
    tcp, shm_run = runs["tcp"], runs["shm"]
    assert shm_run["steps"] == tcp["steps"]
    assert shm_run["wire_bytes_total"] == tcp["wire_bytes_total"]
    assert (
        shm_run["broker_update_bytes_per_shard"]
        == tcp["broker_update_bytes_per_shard"]
    )
    assert shm_run["dup_mismatches"] == 0 and tcp["dup_mismatches"] == 0
    assert shm_run["invariant_max_err"] == 0.0
    for w in range(cfgs["tcp"].n_workers):
        _, p_tcp = final_params(cfgs["tcp"], w)
        _, p_shm = final_params(cfgs["shm"], w)
        for a, b in zip(_leaves(p_tcp), _leaves(p_shm)):
            assert np.array_equal(a, b)


def test_shm_worker_sigkill_respawns_bit_exact(tmp_path):
    """SIGKILL a worker mid-job under shm: the supervisor tears its
    segments down, allocates fresh ones for the respawned invocation, and
    the deterministic replay converges to the reference bit-exactly."""
    res = run_small_pmf(
        tmp_path,
        transport="shm",
        n_brokers=2,
        kill_worker_at_step=(1, 3),
        checkpoint_every=2,
    )
    assert res["steps"] == 8
    assert res["n_respawns"] >= 1
    assert res["dup_mismatches"] == 0
    _, ref_params = reference_updates()
    cfg = small_pmf_cfg(
        tmp_path / "job", transport="shm", n_brokers=2,
        kill_worker_at_step=(1, 3), checkpoint_every=2,
    )
    _, p0 = final_params(cfg, 0)
    for a, b in zip(_leaves(ref_params[0]), _leaves(p0)):
        assert np.array_equal(a, b)


def test_shm_broker_sigkill_wal_respawn(tmp_path):
    """SIGKILL a broker shard mid-job under shm: WAL replay restores the
    store, the supervisor re-serves every live worker's segment (ring
    reset + generation bump), and the workers replay through the same
    idempotent retry window TCP uses."""
    res = run_small_pmf(
        tmp_path,
        transport="shm",
        n_brokers=2,
        kill_broker_at_step=(1, 3),
    )
    assert res["steps"] == 8
    assert len(res["broker_respawns"]) >= 1
    assert res["dup_mismatches"] == 0
    assert res["invariant_max_err"] == 0.0


def test_shm_eviction_flush_and_split(tmp_path):
    """Eviction flush + oversized-leaf splitting over shm at 4 shards:
    every shard owns bytes (the degenerate-partition fix) and the final
    pool shrinks through the mean-preserving hand-off."""
    # evict early in a longer job: the coordinator grants the eviction at
    # max_published + 2, so a loaded host that lets the pool race ahead
    # before the supervisor's next poll must still land it before the end
    res = run_small_pmf(
        tmp_path,
        transport="shm",
        n_brokers=4,
        shard_split_bytes=1024,
        total_steps=16,
        scripted_evict_steps=(2,),
    )
    assert res["steps"] == 16
    assert res["final_pool"] == 2
    assert res["dup_mismatches"] == 0
    assert all(b > 0 for b in res["broker_update_bytes_per_shard"])


# -- oversized-leaf splitting (pure, no processes) ----------------------------


def _toy_tree(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "U": rng.normal(size=(300, 16)).astype(np.float32),
        "M": rng.normal(size=(16, 500)).astype(np.float32),
        "b": rng.normal(size=(7,)).astype(np.float32),
    }


def test_zero_byte_shard_warns():
    from repro.runtime import sharding

    with pytest.warns(UserWarning, match="zero update bytes"):
        sharding.tree_assignment(_toy_tree(), 8)


def test_split_removes_zero_byte_shards():
    from repro.runtime import sharding

    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any warning fails the test
        a = sharding.tree_assignment(_toy_tree(), 8, split_bytes=4096)
    per = sharding.predict_shard_nbytes(
        _toy_tree(), a, 8, scheme="dense", split_bytes=4096
    )
    assert all(b > 0 for b in per)


@settings(max_examples=10)
@given(
    n_shards=st.integers(min_value=1, max_value=6),
    split_kib=st.integers(min_value=1, max_value=64),
    scheme=st.sampled_from(["dense", "bitmap", "sparse"]),
)
def test_split_bytes_topology_invariant(n_shards, split_kib, scheme):
    """The chunking is a function of (template, threshold) only — total
    wire bytes are identical for every shard count AND identical to the
    unsplit encoding for every fixed-size scheme (chunk boundaries are
    multiples of 8 elements, so even bitmap masks pack to the same
    total)."""
    from repro.runtime import sharding

    rng = np.random.default_rng(split_kib)
    tree = {
        k: np.where(rng.random(v.shape) < 0.2, v, 0)
        for k, v in _toy_tree(1).items()
    }
    split = split_kib * 1024
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a1 = sharding.tree_assignment(tree, 1)
        unsplit = sum(sharding.predict_shard_nbytes(tree, a1, 1, scheme))
        an = sharding.tree_assignment(tree, n_shards, split_bytes=split)
    per = sharding.predict_shard_nbytes(
        tree, an, n_shards, scheme, split_bytes=split
    )
    assert sum(per) == unsplit


@settings(max_examples=8)
@given(
    n_shards=st.integers(min_value=1, max_value=5),
    split_bytes=st.sampled_from([0, 1024, 4096, 1 << 20]),
)
def test_split_encode_decode_roundtrip_bit_exact(n_shards, split_bytes):
    """encode_tree_sharded -> iter_part_leaves -> LeafBuffers reassembles
    the exact tree for any (shard count, threshold) — including the
    degenerate no-split and everything-splits corners."""
    import warnings

    from repro.runtime import sharding
    from repro.wire.framing import pack_parts

    rng = np.random.default_rng(n_shards * 131 + split_bytes % 97)
    tree = {
        k: np.where(rng.random(v.shape) < 0.3, v, 0)
        for k, v in _toy_tree(2).items()
    }
    leaf_like = {k: (v.shape, v.dtype) for k, v in tree.items()}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a = sharding.tree_assignment(tree, n_shards, split_bytes=split_bytes)
    per_shard, _ = sharding.encode_tree_sharded(
        tree, a, n_shards, scheme="auto", split_bytes=split_bytes
    )
    bufs = sharding.LeafBuffers(leaf_like)
    for metas, parts in per_shard:
        descs, payload = pack_parts([({"worker": 0, "meta": metas}, parts)])
        blob = b"".join(bytes(p) for p in payload)
        for _desc, m, leaf in sharding.iter_part_leaves(descs, blob):
            bufs.add(m, leaf)
    for k, v in tree.items():
        assert np.array_equal(bufs[k], v), k


def test_split_quant_residual_assembles_full_leaves():
    """fp16 quantization with splitting: the error-feedback residual must
    reassemble to full leaf shape with the exact per-chunk errors."""
    import warnings

    from repro.runtime import sharding
    from repro.wire import codec

    tree = _toy_tree(3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a = sharding.tree_assignment(tree, 3, split_bytes=2048)
    _, res = sharding.encode_tree_sharded(
        tree, a, 3, scheme="dense", quant="fp16", with_residual=True,
        split_bytes=2048,
    )
    for k, v in tree.items():
        expect = v.astype(np.float32) - v.astype(np.float16).astype(
            np.float32
        )
        assert res[k].shape == v.shape
        assert np.array_equal(res[k], expect), k
    # unsplit reference: identical residual
    a1 = sharding.tree_assignment(tree, 1)
    _, res1 = sharding.encode_tree_sharded(
        tree, a1, 1, scheme="dense", quant="fp16", with_residual=True
    )
    for k in tree:
        assert np.array_equal(res[k], res1[k])
    assert codec.predict_tree_nbytes(tree, "dense", "fp16") == sum(
        sharding.predict_shard_nbytes(
            tree, a, 3, "dense", "fp16", split_bytes=2048
        )
    )
