"""End-to-end system tests: the train and serve drivers, ISP + autotuner."""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_driver_isp_end_to_end(tmp_path):
    """Train lm-8m for a few steps under ISP with checkpointing; loss is
    finite, the filter communicates < 100%, a checkpoint exists, restore
    continues."""
    from repro.launch import train as T

    ns = argparse.Namespace(
        arch="lm-8m", smoke=False, steps=6, workers=3, per_worker_batch=2,
        seq=64, mode="isp", isp_v=0.7, optimizer="adam", lr=3e-4,
        autotune=False, sched_interval=20.0,
        checkpoint_dir=str(tmp_path), checkpoint_every=3, restore=False,
        log_every=100, seed=0, out=None,
    )
    res = T.train(ns)
    assert np.isfinite(res["final_loss"])
    assert 0.0 < res["mean_sent_fraction"] < 1.0
    assert res["faas_cost_usd"] > 0
    from repro.checkpoint import store as ckpt

    assert ckpt.latest_step(str(tmp_path)) == 6

    # restore and continue (fault-tolerance path)
    ns2 = argparse.Namespace(**{**vars(ns), "restore": True, "steps": 8})
    res2 = T.train(ns2)
    assert res2["steps"] == 8


def test_train_driver_autotuner_scales_in():
    from repro.launch import train as T

    ns = argparse.Namespace(
        arch="lm-8m", smoke=False, steps=15, workers=3, per_worker_batch=2,
        seq=64, mode="bsp", isp_v=0.7, optimizer="adam", lr=3e-4,
        autotune=True, sched_interval=0.1,  # aggressive for the test
        checkpoint_dir=None, checkpoint_every=50, restore=False,
        log_every=100, seed=0, out=None,
    )
    res = T.train(ns)
    # with a flat-ish loss and an aggressive schedule, the pool must shrink
    assert res["final_pool"] <= 3


def test_serve_driver_end_to_end():
    from repro.launch import serve as S

    ns = argparse.Namespace(
        arch="xlstm-1.3b", smoke=True, requests=4, slots=2, prompt_len=16,
        gen_len=4, seed=0, out=None,
    )
    res = S.serve(ns)
    assert res["new_tokens"] > 0
    assert res["decode_tokens_per_s"] > 0


def test_isp_step_matches_bsp_at_v0():
    """launch.steps.make_isp_train_step with v=0 must track plain BSP
    params after one step (Corollary 1, pod form; n_pods=1 degenerate)."""
    from repro import optim
    from repro.core.isp import ISPConfig
    from repro.dist.compression import CompressionConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_isp_train_step, make_train_step
    from repro.launch.train import LM_8M
    from repro.models.transformer import LM

    cfg = dataclasses.replace(
        LM_8M, name="tiny", d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512,
    )
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    optimizer = optim.make("sgd", 0.1)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 512),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, 512),
    }
    # BSP reference
    bsp = make_train_step(lm, optimizer, clip_norm=0.0)
    p_bsp, *_ = jax.jit(bsp)(params, optimizer.init(params), batch)

    mesh = make_mesh((1,), ("pod",))
    isp = make_isp_train_step(
        lm, optimizer, mesh, ISPConfig(v=0.0, decay=False),
        CompressionConfig(scheme="dense"), clip_norm=0.0,
    )
    lift = lambda t: jax.tree.map(lambda x: x[None], t)
    p_isp, *_ = jax.jit(isp)(
        params, lift(optimizer.init(params)),
        lift(jax.tree.map(jnp.zeros_like, params)), batch,
    )
    for a, b in zip(jax.tree.leaves(p_bsp), jax.tree.leaves(p_isp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2,
                                   atol=2e-3)


def test_topk_combine_moves_only_budgeted_entries():
    from repro.dist.compression import CompressionConfig
    from repro.launch.steps import _topk_combine

    cfg = CompressionConfig(scheme="topk", budget=0.01, block=128)
    sig = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 1024))}
    out = _topk_combine(cfg, sig, 2)
    nz = int(jnp.sum(out["w"] != 0))
    # per pod: one 1024-row x k=10 -> at most 20 nonzeros after the combine
    assert nz <= 20
    assert np.isfinite(np.asarray(out["w"])).all()
    # the kept entries are each pod's row maxima
    a = np.asarray(sig["w"])
    want_top = np.abs(a[0]).max()
    assert np.abs(np.asarray(out["w"])).max() >= want_top * 0.5
