"""Pallas kernel sweeps: shapes x dtypes, interpret=True vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_adam import adam_sig_update, adam_update
from repro.kernels.significance import significance_filter
from repro.kernels import ops


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---- significance filter -------------------------------------------------------


@pytest.mark.parametrize("shape", [(17,), (128,), (1000,), (256, 384),
                                   (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("v_t", [0.0, 0.3, 2.0])
def test_significance_kernel_matches_ref(shape, dtype, v_t):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    u = _rand(k1, shape, dtype)
    x = _rand(k2, shape, dtype)
    r = _rand(k3, shape, dtype)
    sig_k, res_k = significance_filter(
        u, x, r, jnp.float32(v_t), interpret=True
    )
    sig_r, res_r = ref.significance_ref(u, x, r, v_t)
    np.testing.assert_allclose(np.asarray(sig_k, np.float32),
                               np.asarray(sig_r, np.float32), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_k, np.float32),
                               np.asarray(res_r, np.float32), rtol=1e-6,
                               atol=1e-6)


def test_significance_conservation():
    """sig + res == r + u exactly (the filter never loses mass)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    u = _rand(k1, (513,), jnp.float32)
    x = _rand(k2, (513,), jnp.float32)
    r = _rand(k3, (513,), jnp.float32)
    sig, res = significance_filter(u, x, r, jnp.float32(0.5), interpret=True)
    np.testing.assert_allclose(np.asarray(sig + res), np.asarray(r + u),
                               rtol=1e-6)


def test_significance_v0_sends_everything():
    """v = 0 reduces ISP to BSP (Corollary 1): all mass is significant."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    u = _rand(k1, (200,), jnp.float32) + 0.1  # bounded away from 0
    x = _rand(k2, (200,), jnp.float32)
    r = jnp.zeros((200,), jnp.float32)
    sig, res = significance_filter(u, x, r, jnp.float32(0.0), interpret=True)
    np.testing.assert_allclose(np.asarray(sig), np.asarray(u), rtol=1e-6)
    assert float(jnp.max(jnp.abs(res))) == 0.0


@pytest.mark.parametrize("n", [1, 127, 129, 1000, 128 * 256 + 3])
@pytest.mark.parametrize("scheme", ["dense", "topk"])
def test_significance_kernel_through_dist_compression(n, scheme):
    """The fused Pallas split driven the way production drives it — via
    ``dist.compression.isp_compressed_step`` with ``fused=True`` (interpret
    mode on CPU; the same kernel runs compiled on TPU) — must match the
    jnp-reference path bit-for-bit on flattened sizes that are NOT
    multiples of the 128-lane tile (the pad-and-strip path)."""
    from repro.dist.compression import CompressionConfig, isp_compressed_step

    n_pods = 2
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    x = jax.random.normal(ks[0], (n,), jnp.float32)
    u = 0.1 * jax.random.normal(ks[1], (n_pods, n), jnp.float32)
    r = 0.01 * jax.random.normal(ks[2], (n_pods, n), jnp.float32)
    out = {}
    for fused in (False, True):
        cfg = CompressionConfig(scheme=scheme, budget=0.1, block=128,
                                fused=fused, interpret=fused)
        out[fused] = isp_compressed_step(
            cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(0.7)
        )
    for a, b in zip(jax.tree.leaves(out[False][:2]),
                    jax.tree.leaves(out[True][:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    # conservation survives the padded kernel path: sent + res' == r + u
    res_k = out[True][1]["w"]
    sent_k = jnp.sum(r + u - res_k, axis=0)
    np.testing.assert_allclose(np.asarray(sent_k),
                               np.asarray(out[True][0]["w"]),
                               rtol=1e-5, atol=1e-6)


# ---- flash attention --------------------------------------------------------------


@pytest.mark.parametrize("seq,dh", [(128, 128), (256, 128), (384, 256)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(seq, dh, causal, dtype):
    b, h = 2, 2
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(k1, (b, seq, h, dh), dtype)
    k = _rand(k2, (b, seq, h, dh), dtype)
    v = _rand(k3, (b, seq, h, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    b, h, seq, dh = 1, 2, 256, 128
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(k1, (b, seq, h, dh), jnp.float32)
    k = _rand(k2, (b, seq, h, dh), jnp.float32)
    v = _rand(k3, (b, seq, h, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_unpadded_head_dim():
    """Dh=64 (whisper) exercises the wrapper's pad-to-128 path."""
    b, h, seq, dh = 1, 2, 128, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(k1, (b, seq, h, dh), jnp.float32)
    k = _rand(k2, (b, seq, h, dh), jnp.float32)
    v = _rand(k3, (b, seq, h, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_q_offset_decode_like():
    """Sq < Skv with q_offset (chunked prefill against a longer cache)."""
    b, h, dh = 1, 2, 128
    sq, skv, off = 128, 384, 256
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    q = _rand(k1, (b, sq, h, dh), jnp.float32)
    k = _rand(k2, (b, skv, h, dh), jnp.float32)
    v = _rand(k3, (b, skv, h, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=off,
                              interpret=True)
    want = ref.mha_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


# ---- fused adam ---------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(100,), (256, 128), (33, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("step", [1, 100])
def test_fused_adam_matches_ref(shape, dtype, step):
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    p = _rand(keys[0], shape, dtype)
    g = _rand(keys[1], shape, dtype)
    mu = _rand(keys[2], shape, jnp.float32)
    nu = jnp.abs(_rand(keys[3], shape, jnp.float32))
    got = adam_update(p, g, mu, nu, 1e-3, step, interpret=True)
    want = ref.adam_ref(p, g, mu, nu, 1e-3, step=step)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=tol,
                                   atol=tol)


def test_fused_adam_weight_decay():
    keys = jax.random.split(jax.random.PRNGKey(8), 4)
    p = _rand(keys[0], (128,), jnp.float32)
    g = _rand(keys[1], (128,), jnp.float32)
    mu = jnp.zeros((128,), jnp.float32)
    nu = jnp.zeros((128,), jnp.float32)
    got = adam_update(p, g, mu, nu, 1e-2, 1, weight_decay=0.1,
                      interpret=True)
    want = ref.adam_ref(p, g, mu, nu, 1e-2, step=1, weight_decay=0.1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


@pytest.mark.parametrize("shape", [(500,), (64, 200)])
@pytest.mark.parametrize("v_t", [0.0, 0.7])
def test_fused_adam_sig_matches_ref(shape, v_t):
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    p = _rand(keys[0], shape, jnp.float32)
    g = _rand(keys[1], shape, jnp.float32)
    mu = _rand(keys[2], shape, jnp.float32)
    nu = jnp.abs(_rand(keys[3], shape, jnp.float32))
    r = _rand(keys[4], shape, jnp.float32)
    got = adam_sig_update(p, g, mu, nu, r, 1e-3, 5, v_t, interpret=True)
    want = ref.adam_sig_ref(p, g, mu, nu, r, v_t, 1e-3, step=5)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_fused_adam_sig_equals_adam_then_filter():
    """The fusion must equal optimizer-then-filter composition exactly."""
    keys = jax.random.split(jax.random.PRNGKey(10), 5)
    p = _rand(keys[0], (300,), jnp.float32)
    g = _rand(keys[1], (300,), jnp.float32)
    mu = _rand(keys[2], (300,), jnp.float32)
    nu = jnp.abs(_rand(keys[3], (300,), jnp.float32))
    r = _rand(keys[4], (300,), jnp.float32)
    p2, mu2, nu2 = ref.adam_ref(p, g, mu, nu, 1e-3, step=3)
    u = p2 - p  # the adam update
    sig_a, res_a = ref.significance_ref(u, p, r, 0.5)
    sig_b, mu_b, nu_b, res_b = ref.adam_sig_ref(p, g, mu, nu, r, 0.5, 1e-3,
                                                step=3)
    np.testing.assert_allclose(np.asarray(sig_a), np.asarray(sig_b),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_a), np.asarray(res_b),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mu2), np.asarray(mu_b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nu2), np.asarray(nu_b), rtol=1e-6)


# ---- fused sLSTM scan ---------------------------------------------------------


def test_slstm_kernel_matches_module():
    """The Pallas fused time scan must equal models.xlstm's sequential
    reference cell-for-cell (zero initial state)."""
    import dataclasses

    from repro.kernels.slstm_scan import slstm_scan
    from repro.models import xlstm as xl
    from repro.models.config import ArchConfig, BlockSpec as BS, FF, Mixer, uniform_groups

    cfg = ArchConfig(
        name="slstm-test", family="ssm", d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64,
        groups=uniform_groups(BS(Mixer.SLSTM, FF.NONE), 1),
        max_seq_len=64, lstm_proj_factor=1.0,
    )
    import jax as _jax
    p = __import__("repro.models.params", fromlist=["materialize"]).materialize(
        xl.slstm_defs(cfg), _jax.random.PRNGKey(0)
    )
    B, S, d = 2, 16, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
    xg = (x @ p["w_in"].astype(x.dtype)).astype(jnp.float32) + p["b_in"]

    # reference: the module's sequential scan
    state = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3))

    def body(carry, xg_t):
        return xl._slstm_cell(p, xg_t, carry)

    _, hs_ref = jax.lax.scan(body, state, xg.swapaxes(0, 1))
    hs_ref = hs_ref.swapaxes(0, 1)

    hs_k = slstm_scan(xg, p["r"], n_heads=2, block_t=8, interpret=True)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_ref),
                               rtol=2e-5, atol=2e-5)
