"""Per-arch smoke tests: every assigned architecture, reduced config,
one train step + prefill + decode on CPU — shapes and finiteness.

The FULL configs are exercised only via the allocation-free dry-run
(launch/dryrun.py); these tests prove the model math of each family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_NAMES, get_arch, get_smoke
from repro.launch.specs import opt_state_defs
from repro.launch.steps import make_train_step
from repro.models import params as pdefs
from repro.models.transformer import LM

B, S, MAX_LEN = 2, 32, 64


def _opt_state(lm):
    o_defs = opt_state_defs(lm.param_defs())
    return jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype)
        if d.init == "zeros"
        else jnp.ones(d.shape, d.dtype),
        o_defs,
        is_leaf=pdefs.is_def,
    )


def _batch(cfg, b=B, s=S, train=True):
    batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    if train:
        batch["labels"] = jnp.ones((b, s), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones(
            (b, cfg.encoder.ctx_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.ones(
            (b, cfg.encoder.ctx_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_validates(name):
    cfg = get_arch(name)
    cfg.validate()
    assert cfg.n_layers > 0
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


# the assignment's exact full-size numbers
_EXPECT = {
    "whisper-base": dict(L=12, d=512, H=8, kv=8, ff=2048, V=51_865),
    "phi4-mini-3.8b": dict(L=32, d=3072, H=24, kv=8, ff=8192, V=200_064),
    "gemma3-12b": dict(L=48, d=3840, H=16, kv=8, ff=15_360, V=262_144),
    "qwen1.5-32b": dict(L=64, d=5120, H=40, kv=40, ff=27_392, V=152_064),
    "starcoder2-7b": dict(L=32, d=4608, H=36, kv=4, ff=18_432, V=49_152),
    "mixtral-8x22b": dict(L=56, d=6144, H=48, kv=8, ff=16_384, V=32_768),
    "phi3.5-moe-42b-a6.6b": dict(L=32, d=4096, H=32, kv=8, ff=6400,
                                 V=32_064),
    "recurrentgemma-9b": dict(L=38, d=4096, H=16, kv=1, ff=12_288,
                              V=256_000),
    "xlstm-1.3b": dict(L=48, d=2048, H=4, kv=4, ff=0, V=50_304),
    "paligemma-3b": dict(L=18, d=2048, H=8, kv=1, ff=16_384, V=257_216),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_matches_assignment(name):
    cfg = get_arch(name)
    e = _EXPECT[name]
    assert cfg.n_layers == e["L"], (cfg.n_layers, e["L"])
    assert cfg.d_model == e["d"]
    assert cfg.n_heads == e["H"]
    assert cfg.n_kv_heads == e["kv"]
    assert cfg.d_ff == e["ff"]
    assert cfg.vocab_size == e["V"]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    cfg = get_smoke(name)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(lm, optim.make("adam", 1e-3)))
    p2, o2, loss, metrics = step(params, _opt_state(lm), _batch(cfg))
    assert np.isfinite(float(loss)), name
    assert np.isfinite(float(metrics["xent"]))
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_prefill_decode(name):
    cfg = get_smoke(name)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    cache = lm.init_cache(B, MAX_LEN)
    logits, cache = jax.jit(lm.prefill)(params, cache, _batch(cfg, s=16,
                                                              train=False))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    dbatch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.family == "audio":
        dbatch["frames"] = _batch(cfg, train=False)["frames"]
    logits2, cache = jax.jit(lm.decode_step)(
        params, cache, dbatch, jnp.asarray(16, jnp.int32)
    )
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), name


@pytest.mark.parametrize("name", ["phi4-mini-3.8b", "xlstm-1.3b",
                                  "recurrentgemma-9b", "gemma3-12b"])
def test_decode_matches_prefill_logits(name):
    """Prefill(t0..tn) then decode(t_{n+1}) must equal prefill(t0..t_{n+1})
    for the last position — the KV-cache correctness invariant."""
    cfg = get_smoke(name)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 17), 0,
                              cfg.vocab_size)

    # one-shot prefill over all 17 tokens
    cache_a = lm.init_cache(B, MAX_LEN)
    logits_a, _ = jax.jit(lm.prefill)(
        params, cache_a, {"tokens": toks}
    )

    # prefill 16 then decode the 17th
    cache_b = lm.init_cache(B, MAX_LEN)
    _, cache_b = jax.jit(lm.prefill)(params, cache_b,
                                     {"tokens": toks[:, :16]})
    logits_b, _ = jax.jit(lm.decode_step)(
        params, cache_b, {"tokens": toks[:, 16:17]},
        jnp.asarray(16, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(logits_a[:, -1], np.float32),
        np.asarray(logits_b[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_loss_decreases_when_training():
    """A few steps on structured synthetic tokens must reduce loss."""
    from repro.data.tokens import TokenPipeline
    from repro.launch.train import LM_8M

    lm = LM(LM_8M)
    params = lm.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(lm, optim.make("adam", 1e-3)))
    opt = _opt_state(lm)
    pipe = TokenPipeline(LM_8M.vocab_size, 128, 8, seed=0)
    losses = []
    for i in range(30):
        params, opt, loss, _ = step(params, opt, pipe.next_batch(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_moe_aux_loss_nonzero():
    cfg = get_smoke("mixtral-8x22b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    loss, metrics = jax.jit(lm.train_loss)(
        params, {"tokens": toks, "labels": toks}
    )
    assert float(metrics["moe_aux"]) > 0.0


def test_param_counts_near_nameplate():
    """Full configs should land near their nameplate parameter counts."""
    targets = {
        "phi4-mini-3.8b": (3.8e9, 0.25),
        "gemma3-12b": (12e9, 0.25),
        "qwen1.5-32b": (32e9, 0.25),
        "starcoder2-7b": (7e9, 0.30),
        "mixtral-8x22b": (141e9, 0.25),
        "xlstm-1.3b": (1.3e9, 0.30),
    }
    for name, (want, tol) in targets.items():
        lm = LM(get_arch(name))
        n = lm.n_params()
        assert abs(n - want) / want < tol, f"{name}: {n:,} vs {want:,}"
