"""Multi-job fleet scheduler tests (DESIGN.md §14).

The fleet's load-bearing claim is that concurrency is observationally
invisible: a job packed with strangers onto one shared broker/worker pool
must end with final parameters BIT-identical to the same job run solo —
through transports, shard counts, mixed isp/ssp consistency and real
SIGKILLs — while the pool pays one merged bill.

Layers covered here:

* property tests (``sharding.job_namespace`` + namespaced
  ``tree_assignment``): job prefixes can never collide across jobs or
  with the solo namespace, and a job's partition is IDENTICAL to its
  solo partition (the uniform prefix preserves the (-size, key) order) —
  the invariant the bit-identity gate rests on;
* fleet admission validation (topology agreement, id charset, prewarm);
* live two-job end-to-end cells vs solo digests, including the
  worker-SIGKILL + broker-shard-SIGKILL cell;
* fair-share arbitration under ``pool_budget``;
* quantized eviction-flush payloads (``--wire-quant``, satellite of this
  PR): flush bytes shrink, replay stays deterministic;
* pre-warmed invocation respawn (solo supervisor): bit-identity plus a
  measured cold-start overlap.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import FaaSJobConfig, FleetConfig, FleetScheduler
from repro.runtime import run_job
from repro.runtime.sharding import job_namespace, tree_assignment
from repro.runtime.supervisor import final_params_digest
from runtime_harness import (
    fleet_job_cfg,
    run_small_fleet,
    run_small_pmf,
    small_lr_cfg,
    small_pmf_cfg,
)


def _tree(leaf_sizes):
    """A params-like tree with one leaf per requested element count."""
    return {
        f"layer{i}": np.zeros((max(n, 1),), np.float32)
        for i, n in enumerate(leaf_sizes)
    }


_IDS = st.lists(
    st.integers(0, 9).map(lambda i: f"job{i}"),
    min_size=1, max_size=4,
).map(lambda xs: sorted(set(xs)))


# -- properties: namespaced partition ----------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    ids=_IDS,
    sizes=st.lists(st.integers(1, 5000), min_size=1, max_size=6),
    n_shards=st.integers(1, 4),
    split_bytes=st.sampled_from([0, 4096]),
)
def test_job_namespaces_never_collide(ids, sizes, n_shards, split_bytes):
    """Across any set of jobs (and the solo job), the union of namespaced
    key sets is disjoint: no fleet can alias two jobs' state."""
    tree = _tree(sizes)
    keysets = []
    for ns in [""] + [job_namespace(j) for j in ids]:
        keysets.append(set(
            tree_assignment(tree, n_shards, split_bytes, namespace=ns)
        ))
    union = set().union(*keysets)
    assert len(union) == sum(len(k) for k in keysets)


@settings(max_examples=25, deadline=None)
@given(
    jid=st.integers(0, 99).map(lambda i: f"j{i}"),
    sizes=st.lists(st.integers(1, 5000), min_size=1, max_size=6),
    n_shards=st.integers(1, 4),
    split_bytes=st.sampled_from([0, 4096]),
)
def test_namespaced_partition_equals_solo(jid, sizes, n_shards, split_bytes):
    """A job's shard partition under its namespace is EXACTLY its solo
    partition with the prefix glued on — the uniform prefix preserves the
    (-size, key) sort, so per-shard slices, byte balance and summation
    order are independent of which other jobs share the pool.  This is
    what makes fleet final params bit-identical to solo."""
    tree = _tree(sizes)
    ns = job_namespace(jid)
    solo = tree_assignment(tree, n_shards, split_bytes)
    fleet = tree_assignment(tree, n_shards, split_bytes, namespace=ns)
    assert fleet == {ns + k: s for k, s in solo.items()}


@settings(max_examples=10, deadline=None)
@given(ids=_IDS)
def test_job_namespace_shape(ids):
    for jid in ids:
        ns = job_namespace(jid)
        assert ns == f"j{jid}/" and ns.count("/") == 1
    assert job_namespace(None) == ""


def test_job_namespace_rejects_delimiters():
    for bad in ("a/b", "a#b", "x/"):
        with pytest.raises(ValueError):
            job_namespace(bad)


# -- admission validation -----------------------------------------------------


def test_fleet_rejects_mismatched_pool_topology(tmp_path):
    jobs = {
        "a": small_pmf_cfg(tmp_path / "a", n_brokers=1),
        "b": small_pmf_cfg(tmp_path / "b", n_brokers=2),
    }
    with pytest.raises(ValueError, match="n_brokers"):
        FleetScheduler(FleetConfig(run_dir=str(tmp_path), jobs=jobs))
    jobs = {
        "a": small_pmf_cfg(tmp_path / "a", transport="tcp"),
        "b": small_pmf_cfg(tmp_path / "b", transport="shm"),
    }
    with pytest.raises(ValueError, match="transport"):
        FleetScheduler(FleetConfig(run_dir=str(tmp_path), jobs=jobs))
    with pytest.raises(ValueError):
        FleetScheduler(FleetConfig(
            run_dir=str(tmp_path),
            jobs={"a/b": small_pmf_cfg(tmp_path / "x")},
        ))
    with pytest.raises(ValueError, match="prewarm"):
        FleetScheduler(FleetConfig(
            run_dir=str(tmp_path),
            jobs={"a": small_pmf_cfg(tmp_path / "a", prewarm=True)},
        ))
    with pytest.raises(ValueError):
        FleetScheduler(FleetConfig(run_dir=str(tmp_path), jobs={}))


def test_fleet_pins_job_run_dirs(tmp_path):
    sched = FleetScheduler(FleetConfig(
        run_dir=str(tmp_path / "fleet"),
        jobs={"a": small_pmf_cfg(tmp_path / "elsewhere")},
    ))
    assert sched.jobs["a"].cfg.run_dir == str(tmp_path / "fleet/jobs/a")


# -- live two-job cells vs solo digests --------------------------------------
#
# Solo digests are computed ONCE per (workload, consistency): the repo's
# standing gate already proves solo runs are bit-identical across
# {tcp, shm} x n_brokers, so every fleet cell below compares against the
# same solo baselines.


@pytest.fixture(scope="module")
def solo_digests(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("fleet_solo")
    out = {}
    cfg = small_pmf_cfg(tmp / "pmf_isp")
    run_job(cfg)
    out["pmf_isp"] = final_params_digest(cfg)
    cfg = small_lr_cfg(tmp / "lr_isp")
    run_job(cfg)
    out["lr_isp"] = final_params_digest(cfg)
    cfg = small_lr_cfg(tmp / "lr_ssp", consistency="ssp", slack=2)
    run_job(cfg)
    out["lr_ssp"] = final_params_digest(cfg)
    return out


def _check_fleet(res, solo_digests, expect):
    assert res["dup_mismatches"] == 0
    for jid, key in expect.items():
        got = final_params_digest(fleet_job_cfg(res, jid))
        assert got == solo_digests[key], (
            f"job {jid} packed params diverged from solo ({key})"
        )
    # the merged rollup attributes the WHOLE pooled bill
    per_job = res["rollup"]["per_job"]
    assert set(per_job) == set(res["jobs"])
    assert sum(v["total"] for v in per_job.values()) == pytest.approx(
        res["rollup"]["total"]
    )


def test_fleet_two_jobs_tcp_single_shard(tmp_path, solo_digests):
    res = run_small_fleet(
        tmp_path, {"a": {}, "b": {"workload": "lr"}}
    )
    _check_fleet(res, solo_digests, {"a": "pmf_isp", "b": "lr_isp"})
    # bin-packing: slots 0/1 host BOTH jobs in one invocation process
    assert res["n_invocations"] == 3  # max(3, 2) slots, one invocation each


def test_fleet_two_jobs_two_shards_mixed_consistency(tmp_path, solo_digests):
    res = run_small_fleet(
        tmp_path,
        {
            "a": {"n_brokers": 2},
            "b": {"workload": "lr", "n_brokers": 2,
                  "consistency": "ssp", "slack": 2},
        },
    )
    _check_fleet(res, solo_digests, {"a": "pmf_isp", "b": "lr_ssp"})


def test_fleet_shm_faults_bit_identical(tmp_path, solo_digests):
    """The hardest cell: shm transport, 2 shards, mixed isp/ssp,
    invocation-bounded, a worker SIGKILL (kills the whole bin-packed
    process: BOTH jobs replay) and a broker-shard SIGKILL (multi-core WAL
    replays every job's history) — final params still bit-identical."""
    import platform
    import sys as _sys

    from repro.wire import shm as wire_shm

    if not _sys.platform.startswith("linux") \
            or platform.machine() not in wire_shm.SHM_MACHINES:
        pytest.skip("shm transport targets same-host Linux TSO machines")
    cell = {"transport": "shm", "n_brokers": 2}
    res = run_small_fleet(
        tmp_path,
        {
            # kill step 2 sits mid-invocation (boundary at 5): the SIGKILL
            # must land on a RUNNING process, not race a clean
            # bye:invocation-end exit at the boundary step
            "a": dict(cell, invocation_steps=5, checkpoint_every=2,
                      kill_worker_at_step=(1, 2)),
            "b": dict(cell, workload="lr", consistency="ssp", slack=2,
                      invocation_steps=4, checkpoint_every=2,
                      kill_broker_at_step=(1, 2)),
        },
    )
    _check_fleet(res, solo_digests, {"a": "pmf_isp", "b": "lr_ssp"})
    assert res["n_respawns"] >= 1  # the SIGKILL was real and replayed
    assert len(res["broker_respawns"]) >= 1  # the shard died and came back


def test_fleet_fair_share_pool_budget(tmp_path):
    """3 + 2 workers against a pool budget of 3: the scheduler evicts
    fair-share (most-active job first) until the fleet fits, both jobs
    still finish, and the evictions carry the 'fair-share' reason."""
    res = run_small_fleet(
        tmp_path,
        {"a": {}, "b": {"workload": "lr"}},
        pool_budget=3,
    )
    events = [e for j in res["jobs"].values() for e in j["scale_events"]]
    fair = [e for e in events if e["reason"] == "fair-share"]
    assert len(fair) >= 2  # 5 active pairs -> 3 takes two evictions
    # the larger job (a, 3 workers) gives up the first worker
    assert fair[0] in res["jobs"]["a"]["scale_events"]
    for jid, job in res["jobs"].items():
        assert job["final_pool"] >= 1, f"job {jid} lost every worker"
        assert job["steps"] == {"a": 8, "b": 6}[jid]
    assert res["dup_mismatches"] == 0


# -- quantized eviction flush (satellite) ------------------------------------


def test_quantized_flush_shrinks_bytes(tmp_path):
    """Under --wire-quant the eviction hand-off (a full dense replica —
    the largest single message in the system) ships quantized values:
    the broker-measured flush bytes drop to about half, and the run stays
    deterministic (dup_mismatches == 0 through replay)."""
    # evict early in a longer job: the granted evict step must land well
    # before total_steps or the victim can finish 'done' first
    base = dict(scripted_evict_steps=(2,), n_workers=3, total_steps=16)
    r_none = run_small_pmf(tmp_path / "none", **base)
    r_fp16 = run_small_pmf(tmp_path / "fp16", wire_quant="fp16", **base)
    b_none = r_none["broker_stats"]["flush"]["bytes_in"]
    b_fp16 = r_fp16["broker_stats"]["flush"]["bytes_in"]
    assert r_none["broker_stats"]["flush"]["count"] >= 1
    assert b_fp16 < 0.75 * b_none, (b_fp16, b_none)
    assert r_fp16["dup_mismatches"] == 0
    assert r_fp16["final_pool"] == 2  # the eviction really happened


# -- pre-warmed respawn (satellite) ------------------------------------------


def test_prewarm_bit_identical_with_measured_overlap(tmp_path):
    """Pre-spawning the next invocation must not perturb training: the
    gated successor only restores state after the previous invocation's
    final checkpoint is on disk.  The supervisor measures the init
    seconds that overlapped the previous invocation."""
    cold = small_pmf_cfg(tmp_path / "cold", invocation_steps=4,
                         checkpoint_every=2)
    run_job(cold)
    warm = small_pmf_cfg(tmp_path / "warm", invocation_steps=4,
                         checkpoint_every=2, prewarm=True)
    res = run_job(warm)
    assert final_params_digest(warm) == final_params_digest(cold)
    assert res["dup_mismatches"] == 0
    overlaps = res["cold_start_overlaps"]
    assert overlaps, "prewarm never fired"
    assert all(o["overlap_s"] >= 0.0 for o in overlaps)
