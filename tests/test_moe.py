"""MoE dispatch: virtual-expert equivalence, capacity, load-balance aux."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import params as pdefs
from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, MoEConfig, uniform_groups
from repro.models.moe import EP_TARGET, capacity, expert_split, moe_apply, moe_defs


def _cfg(e=4, k=2, d=32, f=64):
    return ArchConfig(
        name="moe-test",
        family="moe",
        d_model=d,
        n_heads=4,
        n_kv_heads=4,
        d_ff=f,
        vocab_size=128,
        groups=uniform_groups(BlockSpec(Mixer.GLOBAL_ATTN, FF.MOE), 1),
        moe=MoEConfig(n_experts=e, top_k=k, capacity_factor=4.0),
        max_seq_len=64,
    )


def _params(cfg, key):
    return pdefs.materialize(moe_defs(cfg), key)


def test_expert_split_values():
    assert expert_split(_cfg(e=16)) == 1
    assert expert_split(_cfg(e=8)) == 2
    assert expert_split(_cfg(e=4)) == 4
    assert expert_split(_cfg(e=2)) == 8


def test_virtual_experts_match_dense_unsplit():
    """The f-sliced virtual experts must compute exactly the same function
    as the unsplit experts: run moe_apply, then re-run with a manually
    merged (e, d, f) weight view through a dense reference."""
    cfg = _cfg(e=4, k=2, d=16, f=32)
    split = expert_split(cfg)  # 4
    key = jax.random.PRNGKey(0)
    p = _params(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)

    out, aux = moe_apply(cfg, p, x)

    # dense reference: merge virtual slices back to (e, d, f) and compute
    # every expert for every token, weighted by the same top-k gates
    e, d, f = 4, 16, 32
    wg = p["w_gate"].reshape(e, split, d, f // split).transpose(0, 2, 1, 3).reshape(e, d, f)
    wu = p["w_up"].reshape(e, split, d, f // split).transpose(0, 2, 1, 3).reshape(e, d, f)
    wd = p["w_down"].reshape(e, split, f // split, d)

    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, 2)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    def expert_out(ei):
        g = xt @ wg[ei]
        u = xt @ wu[ei]
        h = (jax.nn.silu(g) * u)
        # sum over the split f-slices of the down-projection
        hs = h.reshape(-1, split, f // split)
        return sum(hs[:, s_] @ wd[ei, s_] for s_ in range(split))

    all_out = jnp.stack([expert_out(ei) for ei in range(e)], axis=1)
    want = jnp.zeros_like(xt)
    for kk in range(2):
        sel = jnp.take_along_axis(all_out, ids[:, kk][:, None, None], 1)[:, 0]
        want = want + gates[:, kk][:, None] * sel
    want = want.reshape(x.shape)
    # capacity_factor=4 -> nothing dropped; results must match closely
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_capacity_drops_overflow():
    """With capacity_factor small, overflowing tokens are dropped (output
    contribution zero) — never NaN."""
    cfg = dataclasses.replace(
        _cfg(e=4, k=2), moe=MoEConfig(n_experts=4, top_k=2,
                                      capacity_factor=0.1),
    )
    p = _params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32), jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()
    # some tokens must produce strictly smaller output than uncapped
    cfg_big = _cfg(e=4, k=2)
    out_big, _ = moe_apply(cfg_big, p, x)
    assert not np.allclose(np.asarray(out), np.asarray(out_big))


def test_capacity_formula():
    cfg = _cfg(e=8, k=2)
    c = capacity(1024, dataclasses.replace(
        cfg, moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25)))
    assert c == 320  # 1024*2*1.25/8 = 320, already a multiple of 8
    assert capacity(4, cfg) == 8  # floor


def test_aux_loss_uniform_router_is_one():
    """With a uniform router, density ~ uniform and aux -> ~1.0 (E * E *
    (1/E) * (1/E)) — the Switch normalization sanity check."""
    cfg = _cfg(e=4, k=2)
    p = _params(cfg, jax.random.PRNGKey(4))
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32, 32), jnp.float32)
    _, aux = moe_apply(cfg, p, x)
    assert 0.9 < float(aux) < 1.1, float(aux)


def test_group_local_dispatch_matches_global():
    """G groups vs G=1 must give identical outputs when capacity doesn't
    bind (group-locality is a pure partitioning of the dispatch)."""
    cfg = _cfg(e=4, k=2)
    p = _params(cfg, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 8, 32), jnp.float32)

    class Pol:
        moe_groups = 4
        moe_group_ax = None
        moe_token_ax = None
        moe_ep_ax = None
        moe_f_ax = None
        mesh = None

        @staticmethod
        def constrain(t, axes):
            return t

    out_g, _ = moe_apply(cfg, p, x, policy=Pol())
    out_1, _ = moe_apply(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_1),
                               rtol=2e-2, atol=2e-2)


def test_moe_grads_flow():
    cfg = _cfg(e=4, k=2)
    p = _params(cfg, jax.random.PRNGKey(8))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 32), jnp.float32)

    def loss(p_):
        out, aux = moe_apply(cfg, p_, x)
        return jnp.sum(jnp.square(out)) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("w_gate", "w_up", "w_down", "router"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0.0, name
