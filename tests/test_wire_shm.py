"""Shared-memory transport tests (DESIGN.md §12): ring-buffer stream
round trips (wrap-around, oversized frames), full-ring backpressure,
concurrent writer/reader interleavings, generation-based reader-respawn
reattachment, torn-frame detection, and a real SIGKILL-mid-publish
process test asserting no torn frame is ever decoded."""

from __future__ import annotations

import os
import platform
import signal
import subprocess
import sys
import threading
import time

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.wire import shm

pytestmark = pytest.mark.skipif(
    not sys.platform.startswith("linux")
    or platform.machine() not in shm.SHM_MACHINES,
    reason="shm transport targets same-host Linux on TSO machines",
)


def _seg_name(tag: str) -> str:
    return f"mlt{os.getpid():x}{tag}"


class _Harness:
    """One segment + a server thread answering every request with an echo."""

    def __init__(self, tag: str, ring_bytes: int = 1 << 12):
        self.name = _seg_name(tag)
        self.seg = shm.Segment.create(self.name, ring_bytes=ring_bytes)
        self.errors: list = []
        self._stop = False
        self.thread = threading.Thread(target=self._serve, daemon=True)
        self.thread.start()

    def _serve(self) -> None:
        try:
            chan = shm.ShmServerChannel(self.name, stop=lambda: self._stop)
            while not self._stop:
                try:
                    rid, hdr, payload = chan.recv(timeout_s=10.0)
                except (ConnectionError, TimeoutError):
                    break
                chan.send(rid, {"ok": True, "echo": hdr, "n": len(payload)},
                          payload)
            chan.close()
        except Exception as e:  # pragma: no cover - surfaced by the test
            self.errors.append(e)

    def close(self) -> None:
        self._stop = True
        self.thread.join(timeout=10.0)
        assert not self.thread.is_alive(), "server thread wedged"
        self.seg.unlink()
        assert not self.errors, self.errors


@pytest.fixture
def harness(request):
    h = _Harness(tag=str(abs(hash(request.node.name)) % 10**6))
    yield h
    h.close()


def test_roundtrip_small(harness):
    with shm.ShmConnection(harness.name, timeout=10.0) as conn:
        hdr, payload = conn.request({"t": "ping", "x": 1}, b"hello")
        assert hdr["ok"] and hdr["echo"]["x"] == 1
        assert payload == b"hello"


def test_roundtrip_oversized_frame_streams_through(harness):
    # 4x the ring capacity: the frame must stream through in chunks
    big = bytes(range(256)) * 64
    with shm.ShmConnection(harness.name, timeout=10.0) as conn:
        hdr, payload = conn.request({"t": "big"}, big)
        assert hdr["n"] == len(big)
        assert payload == big


def test_vectored_payload_roundtrip(harness):
    with shm.ShmConnection(harness.name, timeout=10.0) as conn:
        hdr, payload = conn.request(
            {"t": "vec"}, [b"abc", b"", memoryview(b"defg")]
        )
        assert payload == b"abcdefg"


@settings(max_examples=15)
@given(
    sizes=st.lists(
        st.integers(min_value=0, max_value=12_000), min_size=1, max_size=8
    )
)
def test_stream_roundtrip_wraparound(sizes):
    """Random frame sizes through a tiny ring: every boundary (empty
    payload, exact ring multiples, many-times-capacity frames) must wrap
    and reassemble bit-exactly, in order."""
    h = _Harness(tag=f"w{abs(hash(tuple(sizes))) % 10**6}", ring_bytes=1 << 10)
    try:
        with shm.ShmConnection(h.name, timeout=20.0) as conn:
            for i, n in enumerate(sizes):
                blob = bytes([(i + j) % 251 for j in range(n)])
                hdr, payload = conn.request({"i": i}, blob)
                assert hdr["echo"]["i"] == i
                assert payload == blob
    finally:
        h.close()


def test_backpressure_blocks_writer_until_reader_drains():
    name = _seg_name("bp")
    seg = shm.Segment.create(name, ring_bytes=1 << 10)
    try:
        chan = shm.ShmServerChannel(name)
        client = shm.Segment.attach(name)
        req = shm.Ring(client, shm._REQ_HDR, "producer")
        payload = b"z" * 4096  # 4x capacity: cannot fit without draining
        state = {"sent": None}

        def writer():
            state["sent"] = shm.send_frame(
                req, 1, {"t": "bp"}, payload, time.monotonic() + 20.0
            )

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        time.sleep(0.3)
        # the ring is full and the writer is parked on the space futex
        assert t.is_alive(), "writer finished without a reader draining"
        assert state["sent"] is None
        rid, hdr, got = chan.recv(timeout_s=10.0)
        assert rid == 1 and got == payload
        t.join(timeout=10.0)
        assert not t.is_alive() and state["sent"] is not None
        req.release()
        client.close()
        chan.close()
    finally:
        seg.unlink()


def test_full_ring_times_out_without_reader():
    name = _seg_name("to")
    seg = shm.Segment.create(name, ring_bytes=1 << 10)
    try:
        chan = shm.ShmServerChannel(name)  # resets + publishes a generation
        client = shm.Segment.attach(name)
        req = shm.Ring(client, shm._REQ_HDR, "producer")
        with pytest.raises(TimeoutError):
            shm.send_frame(
                req, 1, {"t": "stuck"}, b"z" * 4096,
                time.monotonic() + 0.3,
            )
        req.release()
        client.close()
        chan.close()
    finally:
        seg.unlink()


@settings(max_examples=10)
@given(
    delays_ms=st.lists(
        st.integers(min_value=0, max_value=20), min_size=2, max_size=6
    )
)
def test_concurrent_interleavings(delays_ms):
    """A reader that stalls between (and within) frames interleaves with
    a writer pushing frames bigger than the ring — every frame arrives
    intact regardless of scheduling."""
    name = _seg_name(f"ci{abs(hash(tuple(delays_ms))) % 10**6}")
    seg = shm.Segment.create(name, ring_bytes=1 << 10)
    try:
        chan = shm.ShmServerChannel(name)
        client = shm.Segment.attach(name)
        req = shm.Ring(client, shm._REQ_HDR, "producer")
        frames = [
            bytes([(i * 37 + j) % 256 for j in range(1500 + 700 * i)])
            for i in range(len(delays_ms))
        ]

        def writer():
            for i, blob in enumerate(frames):
                shm.send_frame(
                    req, i, {"i": i}, blob, time.monotonic() + 30.0
                )

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        for i, delay in enumerate(delays_ms):
            time.sleep(delay / 1000.0)
            rid, hdr, got = chan.recv(timeout_s=20.0)
            assert rid == i and hdr["i"] == i
            assert got == frames[i]
        t.join(timeout=10.0)
        assert not t.is_alive()
        req.release()
        client.close()
        chan.close()
    finally:
        seg.unlink()


def test_reader_respawn_reattaches_and_replays():
    """Broker-respawn protocol: a new server resets the rings and bumps
    the generation; the client's in-flight request dies with a
    ConnectionError (never a wrong answer) and the replay lands on the
    new server."""
    name = _seg_name("rs")
    seg = shm.Segment.create(name, ring_bytes=1 << 12)
    try:
        ch1 = shm.ShmServerChannel(name)
        conn = shm.ShmConnection(name, timeout=5.0, connect_wait_s=5.0)
        conn.send_only({"t": "lost"}, b"x")
        ch2 = shm.ShmServerChannel(name)  # the respawn
        assert ch2.gen > ch1.gen
        with pytest.raises(ConnectionError):
            conn.recv_response(timeout=5.0)

        def serve_one():
            rid, hdr, payload = ch2.recv(timeout_s=10.0)
            ch2.send(rid, {"ok": True, "srv": 2}, payload)

        t = threading.Thread(target=serve_one, daemon=True)
        t.start()
        hdr, payload = conn.request({"t": "retry"}, b"abc")
        assert hdr["srv"] == 2 and payload == b"abc"
        t.join(timeout=10.0)
        conn.close()
        ch1.close()
        ch2.close()
    finally:
        seg.unlink()


def test_connect_requires_a_serving_generation():
    name = _seg_name("ng")
    seg = shm.Segment.create(name, ring_bytes=1 << 10)
    try:
        conn = shm.ShmConnection(name, timeout=1.0, connect_wait_s=0.3)
        with pytest.raises(ConnectionError):
            conn.request({"t": "nobody-home"})
    finally:
        seg.unlink()


def test_trailer_mismatch_raises_torn_frame():
    """A frame whose trailer word does not check out must raise — never
    surface bytes to the codec."""
    name = _seg_name("tf")
    seg = shm.Segment.create(name, ring_bytes=1 << 10)
    try:
        chan = shm.ShmServerChannel(name)
        client = shm.Segment.attach(name)
        req = shm.Ring(client, shm._REQ_HDR, "producer")
        raw = b"{}"
        frame = (
            shm._FRAME.pack(7, len(raw), 0)
            + raw
            + shm._TRAILER.pack(0xDEADBEEF)  # wrong trailer
        )
        req.write_bytes([memoryview(frame)], time.monotonic() + 5.0)
        with pytest.raises(shm.TornFrameError):
            chan.recv(timeout_s=5.0)
        req.release()
        client.close()
        chan.close()
    finally:
        seg.unlink()


_KILL_CHILD = r"""
import os, sys, time
from repro.wire import shm

name = sys.argv[1]
seg = shm.Segment.attach(name)
seg.set_client(os.getpid())
req = shm.Ring(seg, shm._REQ_HDR, "producer")
rid = 0
while True:  # frames >> ring size: a SIGKILL lands mid-frame w.h.p.
    rid += 1
    payload = bytes([rid % 256]) * 10_000
    shm.send_frame(req, rid, {"rid": rid}, payload,
                   time.monotonic() + 30.0)
"""


def test_sigkill_mid_publish_never_decodes_a_torn_frame():
    """A real worker process SIGKILLed mid-publish: every frame the
    reader decodes must be complete and content-exact; the partial frame
    at the kill point must surface as a connection/timeout error, never
    as data."""
    name = _seg_name("kp")
    seg = shm.Segment.create(name, ring_bytes=1 << 12)
    try:
        chan = shm.ShmServerChannel(name)
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = (
            os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
        )
        proc = subprocess.Popen(
            [sys.executable, "-c", _KILL_CHILD, name], env=env
        )
        try:
            got = 0
            # let a few frames through, then kill mid-stream
            while got < 3:
                rid, hdr, payload = chan.recv(timeout_s=30.0)
                assert payload == bytes([rid % 256]) * 10_000, (
                    f"torn frame decoded at rid {rid}"
                )
                got += 1
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=10.0)
            # drain whatever was fully committed; the torn tail must
            # raise, not decode
            while True:
                try:
                    rid, hdr, payload = chan.recv(timeout_s=2.0)
                except (ConnectionError, TimeoutError):
                    break  # client-death detection or drained ring
                assert payload == bytes([rid % 256]) * 10_000, (
                    f"torn frame decoded at rid {rid} after SIGKILL"
                )
                got += 1
            assert got >= 3
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=10.0)
        chan.close()
    finally:
        seg.unlink()


def test_segment_attach_rejects_garbage():
    name = _seg_name("bad")
    from multiprocessing import shared_memory

    raw = shared_memory.SharedMemory(name=name, create=True, size=4096)
    try:
        with pytest.raises(ConnectionError):
            shm.Segment.attach(name)
    finally:
        raw.close()
        raw.unlink()
