"""Data pipelines + optimizers + ParamDef system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.data import synthetic
from repro.data.tokens import TokenPipeline
from repro.models import params as pdefs


# ---- data ----------------------------------------------------------------------


def test_token_pipeline_deterministic_and_shifted():
    pipe = TokenPipeline(1024, 64, 4, seed=7)
    b1 = pipe.next_batch(3)
    b2 = pipe.next_batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are tokens shifted by one
    assert b1["tokens"].shape == (4, 64)
    assert b1["labels"].shape == (4, 64)
    b3 = pipe.next_batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 1024


def test_criteo_dense_learnable():
    cfg = synthetic.CriteoLikeConfig(n_samples=5000, seed=0)
    x, y = synthetic.make_criteo_dense(cfg)
    assert x.shape == (5000, 13)
    assert x.min() >= 0.0 and x.max() <= 1.0 + 1e-6
    assert 0.2 < y.mean() < 0.8  # not degenerate


def test_criteo_sparse_layout():
    cfg = synthetic.CriteoLikeConfig(n_samples=2000, hash_dim=5000, seed=0)
    idx, val, y = synthetic.make_criteo_sparse(cfg)
    assert idx.shape == (2000, 39)
    assert int(idx.max()) < 5000
    assert int(idx.min()) >= 0


def test_movielens_zipf_and_scale():
    cfg = synthetic.MovieLensLikeConfig(n_users=500, n_movies=800,
                                        n_ratings=20_000, seed=0)
    u, m, r = synthetic.make_movielens(cfg)
    assert int(u.max()) < 500 and int(m.max()) < 800
    assert r.min() >= 0.5 and r.max() <= 5.0
    # Zipf: the most popular user appears much more than the median
    counts = np.bincount(u)
    assert counts.max() > 10 * max(np.median(counts[counts > 0]), 1)


# ---- optimizers -----------------------------------------------------------------


def _quad_target(dim=30, seed=0):
    t = jax.random.normal(jax.random.PRNGKey(seed), (dim,))
    return t, lambda x: 0.5 * jnp.sum(jnp.square(x - t))


@pytest.mark.parametrize("name,lr", [("sgd", 0.3), ("nesterov", 0.1),
                                     ("adam", 0.3)])
def test_optimizers_converge_on_quadratic(name, lr):
    target, loss = _quad_target()
    opt = optim.make(name, lr)
    x = jnp.zeros_like(target)
    state = opt.init(x)
    for _ in range(200):
        g = jax.grad(loss)(x)
        upd, state = opt.update(g, state, x)
        x = optim.apply_updates(x, upd)
    assert float(loss(x)) < 1e-3 * float(loss(jnp.zeros_like(target)))


def test_adam_matches_reference_formula():
    from repro.kernels import ref

    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    opt = optim.make("adam", 1e-2)
    state = opt.init(x)
    upd, state2 = opt.update(g, state, x)
    want_p, want_mu, want_nu = ref.adam_ref(
        x, g, jnp.zeros_like(x), jnp.zeros_like(x), 1e-2, step=1
    )
    np.testing.assert_allclose(np.asarray(x + upd[...]), np.asarray(want_p),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state2.mu), np.asarray(want_mu),
                               rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped = optim.clip_by_global_norm(g, 1.0)
    n = optim.global_norm(clipped)
    assert float(n) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01), "b": jnp.full((4,), 0.01)}
    un = optim.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(un["a"]), np.asarray(small["a"]))


@settings(max_examples=20, deadline=None)
@given(lr=st.floats(1e-4, 0.5), steps=st.integers(1, 50))
def test_property_sgd_lr_decay_schedule(lr, steps):
    """eta_t = eta / sqrt(t) (Theorem 1 schedule)."""
    opt = optim.make("sgd", lr, lr_decay=True)
    x = jnp.ones((4,))
    state = opt.init(x)
    for _ in range(steps - 1):
        _, state = opt.update(jnp.zeros_like(x), state, x)
    g = jnp.ones((4,))
    upd, _ = opt.update(g, state, x)
    want = -lr / np.sqrt(steps)
    np.testing.assert_allclose(np.asarray(upd), want, rtol=1e-5)


# ---- ParamDef system --------------------------------------------------------------


def test_paramdef_three_views_consistent():
    defs = {
        "w": pdefs.ParamDef((8, 16), jnp.float32, ("data", "model")),
        "b": pdefs.ParamDef((16,), jnp.bfloat16, ("model",), "zeros"),
    }
    structs = pdefs.to_struct(defs)
    specs = pdefs.to_specs(defs)
    arrs = pdefs.materialize(defs, jax.random.PRNGKey(0))
    assert structs["w"].shape == arrs["w"].shape == (8, 16)
    assert structs["b"].dtype == arrs["b"].dtype
    from jax.sharding import PartitionSpec as P

    assert specs["w"] == P("data", "model")
    assert float(jnp.max(jnp.abs(arrs["b"]))) == 0.0


def test_paramdef_stack_and_drop_axis():
    d = pdefs.ParamDef((8, 16), jnp.float32, ("data", "model"))
    s = pdefs.stack({"w": d}, 4)["w"]
    assert s.shape == (4, 8, 16)
    assert s.axes == (None, "data", "model")
    dropped = pdefs.drop_axis({"w": d}, "data")["w"]
    assert dropped.axes == (None, "model")


def test_count_params_and_bytes():
    defs = {"w": pdefs.ParamDef((10, 10), jnp.bfloat16),
            "b": pdefs.ParamDef((10,), jnp.float32)}
    assert pdefs.count_params(defs) == 110
    assert pdefs.param_bytes(defs) == 10 * 10 * 2 + 10 * 4
