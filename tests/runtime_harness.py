"""Fixture library for the multi-process FaaS runtime tests (DESIGN.md §11.4).

Every runtime test used to copy-paste its own workload config, job builder
and broker setup; this module is the single home for that plumbing so the
test files state only what they assert:

* ``SMALL_PMF_WCFG`` / ``small_pmf_cfg`` / ``run_small_pmf`` — the tiny
  deterministic PMF job every end-to-end test sizes itself to (real worker
  processes are the slowest tier-1 tests);
* ``BrokerCluster`` — an in-thread sharded broker cluster on ephemeral
  ports (OS-assigned, so parallel test runs never collide) with
  teardown-with-timeout, for protocol-level tests that stub the workers;
* ``reference_updates`` — the in-process ``core.isp`` replica-semantics
  replay that the bit-verification tests compare runtime-published
  updates and final parameters against;
* ``final_params`` — restore one worker's newest checkpoint (the final
  replica) from a finished run directory.

Used by ``test_runtime_faas.py``, ``test_runtime_fault.py``,
``test_runtime_protocol.py`` and ``test_runtime_sharded.py``.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.runtime import FaaSJobConfig, build_workload, run_job
from repro.runtime import protocol
from repro.runtime.broker import Broker

PyTree = Any

# the shared tiny-PMF instance: small enough that a full multi-process run
# fits in a few seconds, big enough that the ISP filter actually filters
SMALL_PMF_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
SMALL_P = 3
SMALL_STEPS = 8
SMALL_V = 0.5
SMALL_LR = 0.08


def small_pmf_cfg(run_dir, **overrides) -> FaaSJobConfig:
    """The canonical small deterministic PMF job; override any field."""
    base = dict(
        run_dir=str(run_dir),
        workload="pmf",
        workload_cfg=dict(SMALL_PMF_WCFG),
        n_workers=SMALL_P,
        total_steps=SMALL_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=SMALL_LR,
        isp_v=SMALL_V,
        deadline_s=180.0,
    )
    base.update(overrides)
    return FaaSJobConfig(**base)


def run_small_pmf(tmp_path, **overrides) -> dict:
    """Run the canonical small job (real processes) and return its result."""
    return run_job(small_pmf_cfg(tmp_path / "job", **overrides))


# -- fleet (multi-job) fixtures (DESIGN.md §14) -------------------------------

# a second tiny workload so fleet tests pack two DIFFERENT models: a small
# dense logistic regression (single leaf, different shapes/batch cadence)
SMALL_LR_WCFG = {
    "n_samples": 4000,
    "batch_size": 128,
}
SMALL_LR_P = 2
SMALL_LR_STEPS = 6


def small_lr_cfg(run_dir, **overrides) -> FaaSJobConfig:
    """A tiny deterministic LR job (the fleet's second tenant)."""
    base = dict(
        run_dir=str(run_dir),
        workload="lr",
        workload_cfg=dict(SMALL_LR_WCFG),
        n_workers=SMALL_LR_P,
        total_steps=SMALL_LR_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.05,
        isp_v=SMALL_V,
        deadline_s=180.0,
    )
    base.update(overrides)
    return FaaSJobConfig(**base)


def small_fleet(run_dir, jobs: dict, **fleet_overrides):
    """Build a ``FleetConfig`` from per-job override dicts::

        small_fleet(tmp, {"a": {}, "b": {"workload": "lr", ...}})

    Jobs default to the canonical small PMF config (pass ``workload='lr'``
    plus LR fields to get the LR tenant); the scheduler pins each job's
    run_dir under ``<run_dir>/jobs/<id>`` itself.
    """
    from repro.runtime import FleetConfig

    built = {}
    for jid, ov in jobs.items():
        ov = dict(ov)
        maker = (
            small_lr_cfg if ov.pop("workload", "pmf") == "lr"
            else small_pmf_cfg
        )
        built[jid] = maker(str(run_dir) + f"/jobs/{jid}", **ov)
    return FleetConfig(run_dir=str(run_dir), jobs=built, **fleet_overrides)


def run_small_fleet(tmp_path, jobs: dict, **fleet_overrides) -> dict:
    """Run a small fleet (real processes) and return the fleet result."""
    from repro.runtime import run_fleet

    return run_fleet(small_fleet(tmp_path / "fleet", jobs, **fleet_overrides))


def fleet_job_cfg(fleet_result: dict, jid: str, maker=None,
                  **overrides) -> FaaSJobConfig:
    """Rebuild the effective per-job config of a finished fleet run (its
    run_dir pinned where the scheduler put it) so ``final_params`` /
    ``final_params_digest`` work unchanged on fleet jobs."""
    job = fleet_result["jobs"][jid]
    maker = maker or (small_lr_cfg if job["workload"] == "lr"
                      else small_pmf_cfg)
    return maker(job["run_dir"], **overrides)


class BrokerCluster:
    """In-thread broker shards for protocol-level tests.

    Each shard is the production ``Broker`` server (real sockets, real
    handler loops) on an OS-allocated ephemeral port; only the workers are
    stubbed by the test.  Shard 0 is the coordinator.  ``close`` tears
    every shard down with a bounded join so a wedged handler thread fails
    the test instead of hanging the suite.
    """

    def __init__(self, job: dict, n_shards: int = 1,
                 wal_dir: Optional[str] = None):
        self.n_shards = n_shards
        self.brokers: list[Broker] = []
        for s in range(n_shards):
            wal = f"{wal_dir}/shard{s:02d}.wal" if wal_dir else None
            self.brokers.append(
                Broker(dict(job), shard_id=s, n_shards=n_shards,
                       wal_path=wal)
            )
        self.addrs = [b.start() for b in self.brokers]

    @property
    def coordinator(self) -> Broker:
        return self.brokers[0]

    def rpc(self, header: dict, payload: bytes = b"", shard: int = 0,
            timeout: float = 10.0) -> tuple[dict, bytes]:
        return protocol.request(
            self.addrs[shard], header, payload, timeout=timeout
        )

    def close(self, timeout: float = 5.0) -> None:
        wedged = {
            b.core.shard_id: threads
            for b in self.brokers
            if (threads := b.stop(timeout=timeout))
        }
        if wedged:
            raise RuntimeError(
                f"broker shard(s) did not shut down within {timeout}s "
                f"(wedged handler threads by shard: {wedged})"
            )

    def __enter__(self) -> "BrokerCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def reference_updates(
    wcfg: dict = SMALL_PMF_WCFG,
    n_workers: int = SMALL_P,
    steps: int = SMALL_STEPS,
    isp_v: float = SMALL_V,
    lr: float = SMALL_LR,
    workload: str = "pmf",
    optimizer: str = "nesterov",
    consistency: str = "isp",
    slack: int = 3,
) -> tuple[dict, list]:
    """In-process ``core.isp`` replica-semantics replay of a full job.

    Returns ``(published, final_params)`` where ``published[(worker,
    step)]`` is the significance-filtered update that worker must have
    published at that step (bit-exact reference), and ``final_params[w]``
    is worker w's replica after the last step — what its final checkpoint
    must contain.

    Under ``consistency='ssp'`` the replay mirrors the live runtime's
    bounded-staleness delivery schedule (DESIGN.md §13): at step t each
    worker applies its own update plus the peers' updates of the frontier
    step ``t - slack - 1`` (none while that is < 1), and after the last
    step drains the still-undelivered tail ``steps - slack .. steps``
    peers-only, step-ascending — the identical float-summation order the
    live workers use, so the comparison stays bit-exact.
    """
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.core import isp as isp_lib

    wl = build_workload(workload, wcfg)
    opt = optim.make(optimizer, lr)
    isp = isp_lib.ISPConfig(v=isp_v)

    def compute(params, opt_state, residual, batch, inv_p, t):
        loss, grads = wl.grad_fn(params, batch)
        upd, opt_state = opt.update(grads, opt_state, params)
        u = jax.tree.map(lambda a: (a * inv_p).astype(a.dtype), upd)
        sig, st, _ = isp_lib.filter_update(
            isp, isp_lib.ISPState(residual=residual, step=t), u, params
        )
        return u, sig, st.residual, opt_state

    compute = jax.jit(compute)
    apply_v = jax.jit(
        lambda p, u, pe: jax.tree.map(
            lambda a, b, c: a + b + c.astype(a.dtype), p, u, pe
        )
    )
    apply_p = jax.jit(
        lambda p, pe: jax.tree.map(
            lambda a, c: a + c.astype(a.dtype), p, pe
        )
    )

    import numpy as np

    def peers_acc(sigs: dict, w: int):
        """np-accumulated peer sum in ascending worker order — the live
        decode path's exact float order (sharding.LeafBuffers)."""
        acc = jax.tree.map(
            lambda x: np.zeros(np.shape(x), np.asarray(x).dtype),
            wl.params0,
        )
        for w2 in sorted(sigs):
            if w2 != w:
                acc = jax.tree.map(
                    lambda a, b: a + np.asarray(b), acc, sigs[w2]
                )
        return acc

    P = n_workers
    params = [wl.params0] * P
    opts = [opt.init(wl.params0) for _ in range(P)]
    residuals = [jax.tree.map(jnp.zeros_like, wl.params0) for _ in range(P)]
    published: dict[tuple[int, int], PyTree] = {}
    sigs_hist: dict[int, dict] = {}
    for t in range(1, steps + 1):
        sigs, us = {}, {}
        for w in range(P):
            key = ((t - 1) * P + w) % wl.n_batches
            u, sig, r2, opts[w] = compute(
                params[w], opts[w], residuals[w], wl.batch(key),
                jnp.asarray(1.0 / P, jnp.float32),
                jnp.asarray(t, jnp.int32),
            )
            residuals[w] = r2
            sigs[w], us[w] = sig, u
            published[(w, t)] = sig
        sigs_hist[t] = sigs
        d = t if consistency == "isp" else t - slack - 1
        for w in range(P):
            acc = (
                peers_acc(sigs_hist[d], w) if d >= 1
                else jax.tree.map(
                    lambda x: np.zeros(np.shape(x), np.asarray(x).dtype),
                    wl.params0,
                )
            )
            params[w] = apply_v(params[w], us[w], acc)
    if consistency == "ssp":
        for d in range(max(steps - slack, 1), steps + 1):
            for w in range(P):
                params[w] = apply_p(params[w], peers_acc(sigs_hist[d], w))
    return published, params


def final_params(cfg: FaaSJobConfig, worker: int) -> tuple[int, PyTree]:
    """Restore worker ``worker``'s newest checkpoint from a finished run.
    Returns (checkpointed step, params)."""
    import os

    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.checkpoint import store as ckpt

    wl = build_workload(cfg.workload, cfg.workload_cfg)
    opt = optim.make(cfg.optimizer, cfg.lr)
    like = {
        "params": wl.params0,
        "opt": opt.init(wl.params0),
        "residual": jax.tree.map(jnp.zeros_like, wl.params0),
    }
    d = os.path.join(cfg.run_dir, "ckpt", f"w{worker:03d}")
    step = ckpt.latest_step(d)
    assert step is not None, f"no checkpoint for worker {worker} in {d}"
    return step, ckpt.restore(d, step, like)["params"]
