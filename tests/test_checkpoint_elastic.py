"""Checkpoint/restore + elastic re-mesh + fault-tolerance paths."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 4)),
                   "layers": [jax.random.normal(k2, (3,)),
                              jnp.ones((2, 2), jnp.bfloat16)]},
        "step_count": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    path = ckpt.save(str(tmp_path), 7, tree, extra={"pool": 5})
    assert os.path.exists(os.path.join(path, "manifest.json"))
    out = ckpt.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.manifest_extra(str(tmp_path), 7)["pool"] == 5


def test_latest_step_and_atomicity(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 10, tree)
    ckpt.save(str(tmp_path), 20, tree)
    # a leftover tmp dir (simulated crash mid-write) must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_0000000030.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_restore_shape_mismatch_raises(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 1, tree)
    bad = jax.tree.map(lambda x: jnp.zeros((5, 5)), tree)
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(str(tmp_path), 1, bad)


def test_overwrite_same_step(tmp_path):
    t1 = _tree(jax.random.PRNGKey(3))
    t2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t1)
    ckpt.save(str(tmp_path), 5, t1)
    ckpt.save(str(tmp_path), 5, t2)
    out = ckpt.restore(str(tmp_path), 5, t1)
    np.testing.assert_allclose(
        np.asarray(out["params"]["w"]), np.asarray(t2["params"]["w"]),
        rtol=1e-6,
    )


def test_train_restore_continues_bit_exact(tmp_path):
    """Train k steps, checkpoint, train k more; vs restore + k more —
    identical parameters (the node-failure recovery guarantee)."""
    from repro import optim
    from repro.data.tokens import TokenPipeline
    from repro.launch.specs import opt_state_defs
    from repro.launch.steps import make_train_step
    from repro.launch.train import LM_8M
    from repro.models import params as pdefs
    from repro.models.transformer import LM
    import dataclasses

    cfg = dataclasses.replace(LM_8M, name="lm-tiny", d_model=64, n_heads=4,
                              n_kv_heads=4, d_ff=128, vocab_size=512)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    o_defs = opt_state_defs(lm.param_defs())
    opt_state = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype) if d.init == "zeros"
        else jnp.ones(d.shape, d.dtype), o_defs, is_leaf=pdefs.is_def)
    step = jax.jit(make_train_step(lm, optim.make("adam", 1e-3)))
    pipe = TokenPipeline(cfg.vocab_size, 32, 2, seed=0)

    for i in range(3):
        params, opt_state, *_ = step(params, opt_state, pipe.next_batch(i))
    ckpt.save(str(tmp_path), 3, {"p": params, "o": opt_state})

    # branch A: continue in-process
    pa, oa = params, opt_state
    for i in range(3, 6):
        pa, oa, *_ = step(pa, oa, pipe.next_batch(i))

    # branch B: restore (simulated restart) then continue
    restored = ckpt.restore(str(tmp_path), 3, {"p": params, "o": opt_state})
    pb, ob = restored["p"], restored["o"]
    for i in range(3, 6):
        pb, ob, *_ = step(pb, ob, pipe.next_batch(i))

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_elastic_restore_with_resharding(tmp_path):
    """Save under one sharding, restore under another (the scale-in
    transition). On 1 CPU device both meshes are trivial, but the API path
    — restore_with_sharding -> device_put per leaf — is the real one."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 2, tree)
    mesh = make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out = ckpt.restore_with_sharding(str(tmp_path), 2, tree, shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == shardings["w"]


def test_elastic_pool_transitions():
    """dist.elastic: pool-size schedule maps onto meshes and the weak-
    scaling batch contract B_g = P * B holds across transitions."""
    from repro.dist import elastic

    plan = elastic.ElasticPlan(initial_pods=4, per_pod_batch=8)
    assert plan.global_batch(4) == 32
    assert plan.global_batch(2) == 16
    sizes = [elastic.mesh_shape_for(p, data=2, model=2) for p in (4, 2, 1)]
    assert sizes[0] == (4, 2, 2)
    assert sizes[1] == (2, 2, 2)
    assert sizes[2] == (2, 2)  # pod axis dropped at 1
