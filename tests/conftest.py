"""Shared pytest config: markers, import path, optional-dep degradation.

Two jobs:

1. Register the ``slow`` / ``tpu`` markers and skip them appropriately
   (tpu-marked tests only run on a TPU backend; slow tests need
   ``--run-slow``).
2. Degrade gracefully when optional deps are absent. ``hypothesis`` is the
   big one: three test modules import it at module scope, so a missing
   wheel used to abort the ENTIRE run at collection. When the real package
   is unavailable we install a small deterministic fallback into
   ``sys.modules`` — ``@given`` draws boundary values first, then seeded
   random examples — so the property tests still execute (with less
   adversarial search) instead of exploding.
"""

from __future__ import annotations

import os
import sys
import types
import zlib

import pytest

# `python -m pytest` without PYTHONPATH=src must still collect everything
_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


# -- markers ------------------------------------------------------------------


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test; needs --run-slow to execute"
    )
    config.addinivalue_line(
        "markers", "tpu: requires a real TPU backend (skipped on CPU/GPU)"
    )


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked slow",
    )


def pytest_collection_modifyitems(config, items):
    try:
        import jax

        backend = jax.default_backend()
    except Exception:  # jax missing/broken: let the tests report it
        backend = "none"
    skip_tpu = pytest.mark.skip(reason="requires a TPU backend")
    skip_slow = pytest.mark.skip(reason="slow test: pass --run-slow")
    for item in items:
        if "tpu" in item.keywords and backend != "tpu":
            item.add_marker(skip_tpu)
        if "slow" in item.keywords and not config.getoption("--run-slow"):
            item.add_marker(skip_slow)


# -- hypothesis fallback ------------------------------------------------------


class _Unsatisfied(Exception):
    """Raised by the fallback ``assume`` to discard one example."""


class _Strategy:
    """A deterministic value source: boundary values first, then seeded
    random draws. API-compatible with the tiny slice of hypothesis this
    repo's tests use (floats/integers/booleans/sampled_from/lists/just/
    one_of/tuples, plus .map/.filter)."""

    def __init__(self, draw, edges=()):
        self._draw = draw
        self.edges = tuple(edges)

    def example(self, rng, i):
        if i < len(self.edges):
            return self.edges[i]
        return self._draw(rng)

    def map(self, f):
        return _Strategy(
            lambda rng: f(self._draw(rng)), [f(e) for e in self.edges]
        )

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied("filter never satisfied")

        return _Strategy(draw, [e for e in self.edges if pred(e)])


def _make_strategies():
    import numpy as np

    st = types.ModuleType("hypothesis.strategies")

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)), (lo, hi))

    def integers(min_value=0, max_value=100, **_kw):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: int(rng.randint(lo, hi + 1)), (lo, hi))

    def booleans():
        return _Strategy(lambda rng: bool(rng.randint(0, 2)), (False, True))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(
            lambda rng: seq[int(rng.randint(0, len(seq)))], seq[:2]
        )

    def just(value):
        return _Strategy(lambda rng: value, (value,))

    def one_of(*strategies):
        def draw(rng):
            s = strategies[int(rng.randint(0, len(strategies)))]
            return s.example(rng, len(s.edges))  # random draw of that arm

        edges = [s.edges[0] for s in strategies if s.edges][:2]
        return _Strategy(draw, edges)

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.randint(min_size, max_size + 1))
            return [elements.example(rng, len(elements.edges) + j)
                    for j in range(n)]

        edges = []
        if min_size == 0:
            edges.append([])
        if elements.edges:
            edges.append([elements.edges[0]] * max(min_size, 1))
        return _Strategy(draw, edges)

    def tuples(*strategies):
        def draw(rng):
            return tuple(
                s.example(rng, len(s.edges)) for s in strategies
            )

        edges = []
        if all(s.edges for s in strategies):
            edges.append(tuple(s.edges[0] for s in strategies))
        return _Strategy(draw, edges)

    st.floats = floats
    st.integers = integers
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.just = just
    st.one_of = one_of
    st.lists = lists
    st.tuples = tuples
    st._rng_type = np.random.RandomState
    return st


def _install_hypothesis_fallback():
    import functools
    import inspect

    import numpy as np

    st = _make_strategies()
    hyp = types.ModuleType("hypothesis")

    def given(*args, **strategies):
        if args:
            raise TypeError(
                "fallback @given supports keyword strategies only"
            )

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*a, **kw):
                n = getattr(wrapper, "_fallback_max_examples", 10)
                seed = zlib.crc32(fn.__qualname__.encode())
                ran = 0
                for i in range(n):
                    rng = np.random.RandomState((seed + i) % 2**31)
                    drawn = {
                        k: s.example(rng, i) for k, s in strategies.items()
                    }
                    try:
                        fn(*a, **drawn, **kw)
                        ran += 1
                    except _Unsatisfied:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (fallback hypothesis) "
                            f"{fn.__name__}({drawn})"
                        ) from e
                if ran == 0:
                    raise _Unsatisfied(
                        f"{fn.__name__}: every example was discarded"
                    )

            wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
            # pytest must not mistake the drawn params for fixtures
            wrapper.__signature__ = inspect.Signature(
                [p for p in inspect.signature(fn).parameters.values()
                 if p.name not in strategies]
            )
            return wrapper

        return deco

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def assume(condition):
        if not condition:
            raise _Unsatisfied("assume() failed")
        return True

    class HealthCheck:
        too_slow = data_too_large = filter_too_much = all = None

    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.note = lambda *_a, **_k: None
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401  (the real package wins when present)
except ImportError:
    _install_hypothesis_fallback()
