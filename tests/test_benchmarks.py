"""Pure-python benchmark helpers (benchmarks/common.py).

Regression for the fig9 speedup bug: ``summarize()`` reports
``total_wall_s`` for a cell that never reached the loss target, and the
old speedup code divided by it anyway — a "speedup" against a step-capped
run, not a measurement.  Speedup must be ``None`` unless BOTH cells
converged.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import attach_speedups


def _row(P, model, t, converged):
    return {"P": P, "model": model, "time_to_loss_s": t,
            "converged": converged}


def test_speedup_reported_only_when_both_cells_converged():
    rows = [
        _row(4, "bsp", 10.0, True),
        _row(4, "isp", 5.0, True),
        _row(4, "ssp", 8.0, False),     # capped, not converged
        _row(8, "bsp", 20.0, False),    # baseline itself capped
        _row(8, "isp", 4.0, True),
    ]
    attach_speedups(rows)
    by = {(r["P"], r["model"]): r["speedup_vs_bsp"] for r in rows}
    assert by[(4, "isp")] == pytest.approx(2.0)
    assert by[(4, "bsp")] == pytest.approx(1.0)
    # non-converged cell: no speedup claim
    assert by[(4, "ssp")] is None
    # non-converged BASELINE poisons the whole P group
    assert by[(8, "bsp")] is None
    assert by[(8, "isp")] is None


def test_speedup_none_when_baseline_missing():
    rows = [_row(16, "isp", 3.0, True)]
    attach_speedups(rows)
    assert rows[0]["speedup_vs_bsp"] is None
