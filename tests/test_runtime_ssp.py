"""Live bounded-staleness (SSP) pull path (DESIGN.md §13).

Protocol-level: the broker's staleness-bounded release must never serve a
pull at step t before every update from steps <= t - slack - 1 is stored,
must serve exactly the frontier step t - slack - 1 when it releases, and
must preserve both properties across a SIGKILL-style shard respawn (WAL
replay rebuilds the per-worker clocks).

End-to-end: the multi-process runtime under ``consistency='ssp'`` must be
bit-identical to the in-process reference replay — including through a
worker SIGKILL + checkpoint-respawn — while the default ISP path stays
byte-for-byte what it always was (asserted by benchmarks/wire_guard.py).
"""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import protocol, run_job

from runtime_harness import (
    SMALL_P as P,
    SMALL_STEPS as STEPS,
    BrokerCluster,
    final_params,
    reference_updates,
    small_pmf_cfg,
)

SLACK = 2

JOB = {
    "workload": "pmf",
    "workload_cfg": {},
    "n_workers": 2,
    "total_steps": 10,
    "n_batches": 5,
    "consistency": "ssp",
    "slack": SLACK,
}


def _publish(cluster, worker, step, meta, payload, shard=0):
    cluster.rpc(
        {"t": "publish", "worker": worker, "step": step, "meta": meta,
         "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0},
        payload, shard=shard,
    )


@pytest.fixture()
def cluster():
    with BrokerCluster(dict(JOB)) as c:
        yield c


def test_ssp_pull_ready_immediately_below_bound(cluster):
    """While t - slack - 1 < 1 there is nothing a pull could owe: it
    releases immediately with empty parts, even with NOTHING published."""
    for step in range(1, SLACK + 2):
        resp, blob = cluster.rpc(
            {"t": "pull", "worker": 0, "step": step, "timeout_s": 0.2}
        )
        assert resp["ready"] is True
        assert resp["visible_step"] == step - SLACK - 1
        assert protocol.unpack_parts(resp["parts"], blob) == []


def test_ssp_release_respects_staleness_bound(cluster):
    """A pull at step t blocks until every worker's contiguous publish
    frontier reaches t - slack - 1; a publish below the frontier is not
    enough to release it."""
    for s in (1, 2, 3):
        meta, payload = protocol.encode_tree({"x": jnp.full(4, float(s))})
        _publish(cluster, 0, s, meta, payload)
    # worker 1 has published nothing: frontier step 2 is not stored yet
    resp, _ = cluster.rpc(
        {"t": "pull", "worker": 0, "step": SLACK + 3, "timeout_s": 0.2}
    )
    assert resp["ready"] is False
    w1_step2 = protocol.encode_tree({"x": jnp.full(4, 12.0)})
    done = {}

    def late():
        m1, p1 = protocol.encode_tree({"x": jnp.full(4, 11.0)})
        _publish(cluster, 1, 1, m1, p1)
        # clock(1) == 1 < frontier 2: the pull below must still be parked
        _publish(cluster, 1, 2, *w1_step2)
        done["ok"] = True

    th = threading.Thread(target=late)
    th.start()
    resp, blob = cluster.rpc(
        {"t": "pull", "worker": 0, "step": SLACK + 3, "timeout_s": 5.0}
    )
    th.join()
    assert done.get("ok") and resp["ready"] is True
    assert resp["visible_step"] == 2  # (SLACK+3) - SLACK - 1
    parts = protocol.unpack_parts(resp["parts"], blob)
    assert [p[0]["worker"] for p in parts] == [1]
    got = protocol.decode_tree(
        parts[0][0]["meta"], parts[0][1], {"x": jnp.zeros(4)}
    )
    np.testing.assert_array_equal(got["x"], np.full(4, 12.0))


def test_ssp_serves_exactly_the_frontier_step(cluster):
    metas = {}
    for s in (1, 2, 3):
        for w in (0, 1):
            meta, payload = protocol.encode_tree(
                {"x": jnp.full(4, float(10 * w + s))}
            )
            metas[(w, s)] = (meta, payload)
            _publish(cluster, w, s, meta, payload)
    resp, blob = cluster.rpc(
        {"t": "pull", "worker": 0, "step": SLACK + 3, "timeout_s": 5.0}
    )
    assert resp["ready"] is True and resp["visible_step"] == 2
    parts = protocol.unpack_parts(resp["parts"], blob)
    assert [p[0]["worker"] for p in parts] == [1]
    got = protocol.decode_tree(
        parts[0][0]["meta"], parts[0][1], {"x": jnp.zeros(4)}
    )
    np.testing.assert_array_equal(got["x"], np.full(4, 12.0))


def test_ssp_release_survives_shard_respawn(tmp_path):
    """WAL replay must rebuild the per-worker clocks: a respawned shard
    keeps blocking exactly where the dead one did."""
    meta, payload = protocol.encode_tree({"x": jnp.ones(4)})
    with BrokerCluster(dict(JOB), wal_dir=str(tmp_path)) as c1:
        for s in (1, 2):
            _publish(c1, 0, s, meta, payload)
        _publish(c1, 1, 1, meta, payload)
    with BrokerCluster(dict(JOB), wal_dir=str(tmp_path)) as c2:
        core = c2.coordinator.core
        assert core.clocks == {0: 2, 1: 1}
        # frontier 1 is stored -> pull at 1 + slack + 1 releases
        resp, _ = c2.rpc(
            {"t": "pull", "worker": 0, "step": SLACK + 2, "timeout_s": 2.0}
        )
        assert resp["ready"] is True and resp["visible_step"] == 1
        # frontier 2 is NOT (worker 1's clock is 1) -> still blocked,
        # exactly as before the crash
        resp, _ = c2.rpc(
            {"t": "pull", "worker": 0, "step": SLACK + 3, "timeout_s": 0.2}
        )
        assert resp["ready"] is False


# -- end-to-end: real processes ----------------------------------------------


@pytest.fixture(scope="module")
def ssp_run(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("faas_ssp")
    cfg = small_pmf_cfg(tmp / "job", consistency="ssp", slack=SLACK,
                        retain_updates=True)
    return cfg, run_job(cfg)


def test_ssp_live_matches_reference_replay(ssp_run):
    cfg, res = ssp_run
    assert res["steps"] == STEPS and res["dup_mismatches"] == 0
    ref, ref_final = reference_updates(consistency="ssp", slack=SLACK)
    pub = {(u["worker"], u["step"]): u["update"] for u in res["updates"]}
    assert len(pub) == P * STEPS
    for (w, t), sig in sorted(ref.items()):
        for a, b in zip(jax.tree.leaves(sig), jax.tree.leaves(pub[(w, t)])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"worker {w} step {t} published update diverged",
            )
    for w in range(P):
        step, live = final_params(cfg, w)
        assert step == STEPS + 1  # the post-drain sentinel checkpoint
        for a, b in zip(jax.tree.leaves(ref_final[w]), jax.tree.leaves(live)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"worker {w} final (drained) params diverged",
            )


def test_ssp_sigkill_respawn_stays_bit_identical(tmp_path):
    """A SIGKILLed worker replays from its checkpoint through the SSP
    schedule: re-publishes dup-check bit-identical and the drained final
    params still equal the reference — the t - slack - 1 bound held
    through the crash (a violation would change what the respawned
    replica saw, and the bit-compare would catch it)."""
    cfg = small_pmf_cfg(
        tmp_path / "job", consistency="ssp", slack=SLACK,
        checkpoint_every=4, kill_worker_at_step=(1, 5),
        deadline_s=240.0,
    )
    res = run_job(cfg)
    assert res["n_respawns"] >= 1
    assert res["respawns"][0]["worker"] == 1
    assert res["steps"] == STEPS
    assert res["dup_mismatches"] == 0
    _ref, ref_final = reference_updates(consistency="ssp", slack=SLACK)
    for w in range(P):
        step, live = final_params(cfg, w)
        assert step == STEPS + 1
        for a, b in zip(jax.tree.leaves(ref_final[w]), jax.tree.leaves(live)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"worker {w} final params diverged after respawn",
            )
