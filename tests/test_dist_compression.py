"""dist.compression: property-based equivalence + conservation tests.

The three invariants that make the compressed exchange safe to ship:

1. dense scheme at v = 0 is bit-compatible with BSP (Corollary 1);
2. topk never exceeds its byte budget;
3. error feedback conserves update mass under EVERY scheme — the
   communicated part plus the new residual always reconstructs r + u.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist.compression import (
    CompressionConfig,
    apply_combined,
    isp_compressed_step,
    split_significant,
)
from repro.wire import codec as wire_codec

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _pod_tree(seed, n_pods, shape, dtype=jnp.float32, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = (scale * jax.random.normal(ks[0], shape, jnp.float32)).astype(dtype)
    u = (scale * 0.1 * jax.random.normal(
        ks[1], (n_pods,) + shape, jnp.float32)).astype(dtype)
    r = (scale * 0.01 * jax.random.normal(
        ks[2], (n_pods,) + shape, jnp.float32)).astype(dtype)
    return u, x, r


# -- config validation --------------------------------------------------------


def test_config_rejects_bad_values():
    with pytest.raises(ValueError):
        CompressionConfig(scheme="gzip")
    with pytest.raises(ValueError):
        CompressionConfig(scheme="topk", budget=0.0)
    with pytest.raises(ValueError):
        CompressionConfig(scheme="topk", budget=1.5)
    with pytest.raises(ValueError):
        CompressionConfig(block=0)


def test_k_per_block_floor():
    cfg = CompressionConfig(scheme="topk", budget=0.001, block=128)
    assert cfg.k_per_block() == 1  # never zero: progress is guaranteed
    assert CompressionConfig(scheme="topk", budget=1.0).k_per_block() == 128


# -- Corollary 1: dense at v=0 == BSP ----------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n_pods=st.integers(1, 4),
    n=st.integers(1, 257),
)
def test_dense_v0_equals_bsp(seed, n_pods, n):
    """With v = 0 and zero residual, the dense exchange is exactly the BSP
    all-reduce: combined == sum_p u_p, residual stays zero."""
    cfg = CompressionConfig(scheme="dense")
    u, x, _ = _pod_tree(seed, n_pods, (n,))
    r = jnp.zeros_like(u)
    combined, res2, stats = isp_compressed_step(
        cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(0.0)
    )
    np.testing.assert_allclose(
        np.asarray(combined["w"]), np.asarray(jnp.sum(u, axis=0)),
        rtol=1e-6, atol=1e-7,
    )
    assert float(jnp.max(jnp.abs(res2["w"]))) == 0.0
    # and the filter reports full communication
    nz_frac = float(jnp.mean((u != 0).astype(jnp.float32)))
    assert float(stats["sent_fraction"]) == pytest.approx(nz_frac, abs=1e-6)


@pytest.mark.parametrize("dtype", list(DTYPES))
def test_dense_v0_matches_bsp_params_after_apply(dtype):
    """apply_combined(params, dense-v0 exchange) == params + sum_p u_p in
    fp32 accumulation, across dtypes."""
    cfg = CompressionConfig(scheme="dense")
    u, x, _ = _pod_tree(3, 3, (33, 7), DTYPES[dtype])
    r = jnp.zeros_like(u)
    combined, _, _ = isp_compressed_step(
        cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(0.0)
    )
    got = apply_combined({"w": x}, combined)["w"]
    want = (
        x.astype(jnp.float32)
        + jnp.sum(u.astype(jnp.float32), axis=0)
    ).astype(DTYPES[dtype])
    # bf16 rounds per-pod inside the exchange; one ulp of slack
    tol = 2e-2 if dtype == "bf16" else 1e-6
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# -- topk budget --------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(1, 400),
    budget=st.floats(0.01, 0.5),
    block=st.sampled_from([8, 32, 128]),
)
def test_topk_respects_budget_exactly(seed, n, budget, block):
    """Per pod, the number of communicated entries is exactly bounded by
    n_blocks * k_per_block — the wire budget is a hard guarantee."""
    cfg = CompressionConfig(scheme="topk", budget=budget, block=block)
    n_pods = 2
    u, x, r = _pod_tree(seed, n_pods, (n,))
    combined, res2, _ = isp_compressed_step(
        cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(0.0)
    )
    # v=0: everything is significant, so the only filtering is topk; the
    # per-pod sent tensor is (r + u) - res'
    sent = np.asarray(r + u - res2["w"])
    eff_block = min(block, n)
    n_blocks = -(-n // eff_block)
    cap = n_blocks * cfg.k_per_block(eff_block)
    for p in range(n_pods):
        assert int(np.sum(sent[p] != 0)) <= cap


def test_topk_keeps_the_largest_magnitudes():
    cfg = CompressionConfig(scheme="topk", budget=0.25, block=4)
    u = jnp.asarray([[4.0, -0.1, 0.2, -8.0, 0.3, 16.0, -0.4, 0.5]])
    x = jnp.ones((8,))
    r = jnp.zeros((1, 8))
    combined, res2, _ = isp_compressed_step(
        cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(0.0)
    )
    # block 0 = [4, -.1, .2, -8] keeps -8; block 1 = [.3, 16, -.4, .5]
    # keeps 16
    np.testing.assert_allclose(
        np.asarray(combined["w"]),
        np.asarray([0.0, 0.0, 0.0, -8.0, 0.0, 16.0, 0.0, 0.0]),
    )
    # everything else fed back into the residual
    np.testing.assert_allclose(
        np.asarray(res2["w"][0]),
        np.asarray([4.0, -0.1, 0.2, 0.0, 0.3, 0.0, -0.4, 0.5]),
    )


# -- error-feedback conservation ---------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(1, 311),
    v=st.floats(0.0, 2.0),
    scheme=st.sampled_from(["dense", "topk", "bitmap"]),
    dtype=st.sampled_from(["f32", "bf16"]),
)
def test_error_feedback_conservation(seed, n, v, scheme, dtype):
    """sent_p + res'_p == r_p + u_p for every pod, scheme, threshold and
    dtype — no update mass is ever created or destroyed, including on odd
    (non-multiple-of-block) shapes."""
    cfg = CompressionConfig(scheme=scheme, budget=0.1, block=32)
    n_pods = 3
    u, x, r = _pod_tree(seed, n_pods, (n,), DTYPES[dtype])
    combined, res2, _ = isp_compressed_step(
        cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(v)
    )
    # sum_p sent_p == combined, so sum_p (r+u-res') must equal combined
    want = jnp.sum(
        (r + u - res2["w"]).astype(jnp.float32), axis=0
    )
    tol = 2e-2 if dtype == "bf16" else 1e-5
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(combined["w"], np.float32),
        rtol=tol, atol=tol,
    )
    # per-pod reconstruction: res' + sent == r + u exactly, leaf-wise
    sent = (r + u) - res2["w"]
    np.testing.assert_allclose(
        np.asarray(sent + res2["w"], np.float32),
        np.asarray(r + u, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), v=st.floats(0.0, 2.0))
def test_bitmap_is_numerically_dense(seed, v):
    """bitmap is an encoding, not a filter: identical numbers to dense."""
    u, x, r = _pod_tree(seed, 2, (129,))
    outs = {}
    for scheme in ("dense", "bitmap"):
        cfg = CompressionConfig(scheme=scheme)
        outs[scheme] = isp_compressed_step(
            cfg, {"w": u}, {"w": x}, {"w": r}, jnp.float32(v)
        )
    np.testing.assert_array_equal(
        np.asarray(outs["dense"][0]["w"]), np.asarray(outs["bitmap"][0]["w"])
    )
    np.testing.assert_array_equal(
        np.asarray(outs["dense"][1]["w"]), np.asarray(outs["bitmap"][1]["w"])
    )
    # wire model (repro.wire bitmap codec): a ceil(n/8) packed mask per pod
    # + 4B per significant value; cheaper than dense exactly when the
    # filter is actually sparse (the paper's point — a dense update gains
    # nothing from a sparse encoding)
    n_total = u.size
    n_pods, leaf_n = u.shape
    hits = float(outs["bitmap"][2]["sent_fraction"]) * n_total
    want_bytes = n_pods * wire_codec.mask_nbytes(leaf_n) + 4.0 * hits
    assert float(outs["bitmap"][2]["wire_bytes"]) == pytest.approx(
        want_bytes, rel=1e-5
    )
    if hits < n_total * (1 - 1 / 32):
        assert float(outs["bitmap"][2]["wire_bytes"]) < float(
            outs["dense"][2]["wire_bytes"]
        )


def test_multi_leaf_pytree_and_broadcast():
    """Params without the pod axis broadcast against (P, ...) updates for
    arbitrarily nested pytrees."""
    cfg = CompressionConfig(scheme="dense")
    P = 2
    params = {"a": jnp.ones((3, 5)), "nested": {"b": jnp.full((4,), 2.0)}}
    u = jax.tree.map(
        lambda x: jnp.repeat(x[None] * 0.5, P, axis=0), params
    )
    r = jax.tree.map(jnp.zeros_like, u)
    combined, res2, stats = isp_compressed_step(
        cfg, u, params, r, jnp.float32(0.0)
    )
    np.testing.assert_allclose(np.asarray(combined["a"]), 0.5 * P)
    np.testing.assert_allclose(np.asarray(combined["nested"]["b"]), 1.0 * P)
    assert float(stats["sent_fraction"]) == pytest.approx(1.0)


def test_split_significant_fused_matches_reference():
    """The Pallas-kernel split and the jnp split agree on a pod-stacked
    odd-shaped tensor (interpret mode; real TPUs run the same kernel)."""
    u, x, r = _pod_tree(11, 3, (5, 77))
    for v in (0.0, 0.4, 1.5):
        sig_a, res_a = split_significant(u, x, r, jnp.float32(v))
        sig_b, res_b = split_significant(
            u, x, r, jnp.float32(v), fused=True, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(sig_a), np.asarray(sig_b), rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(
            np.asarray(res_a), np.asarray(res_b), rtol=1e-6, atol=1e-7
        )
