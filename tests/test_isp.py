"""ISP significance filter: unit + property tests (paper §4.1, Theorem 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isp


def _tree(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": scale * jax.random.normal(k1, (32, 16)),
        "b": scale * jax.random.normal(k2, (16,)),
        "nested": {"u": scale * jax.random.normal(k3, (8,))},
    }


def test_split_conservation_and_disjointness():
    key = jax.random.PRNGKey(0)
    acc = jax.random.normal(key, (1000,))
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,))
    sig, res, mask = isp.significance_split(acc, x, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(sig + res), np.asarray(acc))
    # sig and res have disjoint support
    assert float(jnp.sum(jnp.abs(sig) * jnp.abs(res))) == 0.0
    # mask consistency
    assert bool(jnp.all((sig != 0) == (mask & (acc != 0))))


def test_threshold_schedule():
    cfg = isp.ISPConfig(v=0.7, decay=True)
    assert float(cfg.threshold(1)) == pytest.approx(0.7)
    assert float(cfg.threshold(4)) == pytest.approx(0.35)
    assert float(cfg.threshold(100)) == pytest.approx(0.07)
    const = isp.ISPConfig(v=0.7, decay=False)
    assert float(const.threshold(100)) == pytest.approx(0.7)


def test_v0_is_bsp():
    """Corollary 1: v = 0 communicates everything, residual stays zero."""
    cfg = isp.ISPConfig(v=0.0, decay=False)
    params = _tree(jax.random.PRNGKey(0))
    state = isp.init_state(params)
    for step in range(3):
        upd = _tree(jax.random.PRNGKey(10 + step), scale=0.1)
        sig, state, masks = isp.filter_update(cfg, state, upd, params)
        for s, u in zip(jax.tree.leaves(sig), jax.tree.leaves(upd)):
            np.testing.assert_allclose(np.asarray(s), np.asarray(u),
                                       rtol=1e-6)
        for r in jax.tree.leaves(state.residual):
            assert float(jnp.max(jnp.abs(r))) == 0.0
    assert float(isp.communicated_fraction(masks)) == pytest.approx(1.0)


def test_residual_bound_invariant():
    """After filtering, every residual entry satisfies |r| <= v_t * |x|
    (+floor) — the Theorem 1 noisy-view bound witness."""
    cfg = isp.ISPConfig(v=0.7, decay=True, absolute_floor=1e-8)
    params = _tree(jax.random.PRNGKey(2))
    state = isp.init_state(params)
    for step in range(5):
        upd = _tree(jax.random.PRNGKey(20 + step), scale=0.05)
        v_t = float(cfg.threshold(state.step))
        sig, state, _ = isp.filter_update(cfg, state, upd, params)
        for r, x in zip(jax.tree.leaves(state.residual),
                        jax.tree.leaves(params)):
            bound = v_t * np.maximum(np.abs(np.asarray(x)), 1e-8)
            assert np.all(np.abs(np.asarray(r)) <= bound + 1e-6)


def test_mass_conservation_across_steps():
    """Sum of all communicated + final residual == sum of all updates."""
    cfg = isp.ISPConfig(v=1.5, decay=False)
    params = _tree(jax.random.PRNGKey(3))
    state = isp.init_state(params)
    total_upd = jax.tree.map(jnp.zeros_like, params)
    total_sig = jax.tree.map(jnp.zeros_like, params)
    for step in range(7):
        upd = _tree(jax.random.PRNGKey(30 + step), scale=0.1)
        total_upd = jax.tree.map(jnp.add, total_upd, upd)
        sig, state, _ = isp.filter_update(cfg, state, upd, params)
        total_sig = jax.tree.map(jnp.add, total_sig, sig)
    recon = jax.tree.map(jnp.add, total_sig, state.residual)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(total_upd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_flush_empties_residual():
    cfg = isp.ISPConfig(v=100.0, decay=False)  # filter everything
    params = _tree(jax.random.PRNGKey(4))
    state = isp.init_state(params)
    upd = _tree(jax.random.PRNGKey(40), scale=0.1)
    sig, state, _ = isp.filter_update(cfg, state, upd, params)
    assert float(isp.communicated_fraction(
        jax.tree.map(lambda s: s != 0, sig))) == 0.0
    flushed, state2 = isp.flush(state)
    for f, u in zip(jax.tree.leaves(flushed), jax.tree.leaves(upd)):
        np.testing.assert_allclose(np.asarray(f), np.asarray(u), rtol=1e-6)
    for r in jax.tree.leaves(state2.residual):
        assert float(jnp.max(jnp.abs(r))) == 0.0


@settings(max_examples=30, deadline=None)
@given(
    v=st.floats(0.0, 5.0),
    scale=st.floats(1e-3, 10.0),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**16),
)
def test_property_split_partition(v, scale, n, seed):
    """For any acc/x/v: sig+res == acc, supports disjoint, and the residual
    obeys the significance bound."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    acc = scale * jax.random.normal(k1, (n,))
    x = jax.random.normal(k2, (n,))
    sig, res, mask = isp.significance_split(acc, x, jnp.float32(v))
    np.testing.assert_allclose(np.asarray(sig + res), np.asarray(acc),
                               rtol=1e-6, atol=1e-7)
    denom = np.maximum(np.abs(np.asarray(x)), 1e-8)
    assert np.all(np.abs(np.asarray(res)) <= v * denom * (1 + 1e-6) + 1e-7)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), v=st.floats(0.0, 2.0))
def test_property_higher_threshold_sends_less(seed, v):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    acc = jax.random.normal(k1, (500,))
    x = jax.random.normal(k2, (500,))
    _, _, m1 = isp.significance_split(acc, x, jnp.float32(v))
    _, _, m2 = isp.significance_split(acc, x, jnp.float32(v + 0.5))
    assert int(jnp.sum(m2)) <= int(jnp.sum(m1))


def test_isp_sgd_convergence_quadratic():
    """ISP-filtered SGD on a convex quadratic converges (Theorem 1 spirit):
    the average regret goes to ~0 and matches unfiltered SGD's optimum."""
    dim = 50
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (dim,))

    def loss(x):
        return 0.5 * jnp.sum(jnp.square(x - target))

    cfg = isp.ISPConfig(v=0.5, decay=True)
    x = jnp.zeros((dim,))
    state = isp.init_state(x)
    eta0 = 0.3
    for t in range(1, 400):
        g = jax.grad(loss)(x)
        u = -(eta0 / jnp.sqrt(t)) * g
        sig, state, _ = isp.filter_update(cfg, state, u, x)
        x = x + sig
    assert float(loss(x)) < 1e-2 * float(loss(jnp.zeros((dim,))))


def test_regret_sublinear_slope():
    """Empirical O(sqrt(T)) check: cumulative regret on convex SGD grows
    with slope < 1 in log-log (Theorem 1)."""
    dim = 20
    target = jax.random.normal(jax.random.PRNGKey(1), (dim,))

    def f(x):
        return 0.5 * jnp.sum(jnp.square(x - target))

    cfg = isp.ISPConfig(v=0.7, decay=True)
    x = jnp.zeros((dim,))
    state = isp.init_state(x)
    fstar = 0.0
    regret = []
    acc = 0.0
    for t in range(1, 600):
        g = jax.grad(f)(x)
        u = -(0.3 / jnp.sqrt(t)) * g
        sig, state, _ = isp.filter_update(cfg, state, u, x)
        x = x + sig
        acc += float(f(x)) - fstar
        regret.append(acc)
    # slope of log(regret) vs log(t) over the second half
    ts = np.arange(1, 600)
    half = len(ts) // 2
    slope = np.polyfit(np.log(ts[half:]), np.log(np.asarray(regret)[half:]),
                       1)[0]
    assert slope < 0.9, f"regret slope {slope} not sublinear"
