"""Live topology re-sharding (DESIGN.md §16): consistent-hash ring,
chunk-floor guard, TopologyTuner policy, prewarm clock hygiene, broker
handover ops, and end-to-end bit-identity across a mid-job re-shard.
"""

from __future__ import annotations

import os
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.autotuner import (
    AutoTunerConfig,
    ScaleInAutoTuner,
    TopologyTuner,
    TopologyTunerConfig,
)
from repro.core.billing import CommModel
from repro.runtime import final_params_digest, sharding
from repro.runtime import supervisor as sup

from runtime_harness import BrokerCluster, run_small_pmf, small_pmf_cfg


# -- consistent-hash ring partitioner -----------------------------------------


def _keys(n: int, seed: int) -> list[str]:
    rng = np.random.RandomState(seed)
    return [f"leaf{seed}:{i}:{int(rng.randint(1_000_000))}" for i in range(n)]


@settings(max_examples=20)
@given(
    n_shards=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=40),
    n_keys=st.integers(min_value=1, max_value=80),
)
def test_ring_grow_moves_only_to_new_shard(n_shards, seed, n_keys):
    """N -> N+1: the only keys that change owner land on the NEW shard —
    existing shards never trade keys among themselves."""
    keys = _keys(n_keys, seed)
    a = sharding.ring_assign(keys, n_shards)
    b = sharding.ring_assign(keys, n_shards + 1)
    for k in keys:
        if a[k] != b[k]:
            assert b[k] == n_shards


@settings(max_examples=20)
@given(
    n_shards=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=40),
    n_keys=st.integers(min_value=1, max_value=80),
)
def test_ring_shrink_moves_only_from_removed_shard(n_shards, seed, n_keys):
    """N -> N-1 (retiring the last shard): every key that was NOT on the
    removed shard keeps its owner."""
    keys = _keys(n_keys, seed)
    a = sharding.ring_assign(keys, n_shards)
    b = sharding.ring_assign(keys, n_shards - 1)
    for k in keys:
        if a[k] != n_shards - 1:
            assert b[k] == a[k]


@settings(max_examples=15)
@given(
    n_shards=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=40),
)
def test_ring_assignment_is_pure(n_shards, seed):
    """The assignment is a pure function of (keys, N): key order and
    repeated evaluation do not matter."""
    keys = _keys(32, seed)
    a = sharding.ring_assign(keys, n_shards)
    b = sharding.ring_assign(list(reversed(keys)), n_shards)
    assert a == b == sharding.ring_assign(keys, n_shards)


def test_ring_moved_fraction_bounded():
    """Growing N -> N+1 moves roughly a 1/(N+1) fraction of the keys (the
    whole point of consistent hashing vs. rehash-everything)."""
    keys = [f"leaf{i}:{j * 1024}" for i in range(40) for j in range(50)]
    for n in range(1, 6):
        a = sharding.ring_assign(keys, n)
        b = sharding.ring_assign(keys, n + 1)
        moved = sum(1 for k in keys if a[k] != b[k])
        assert moved / len(keys) <= 1.0 / (n + 1) + 0.15


def test_tree_assignment_ring_covers_all_subkeys():
    tree = {"U": np.zeros((1000, 4), np.float32),
            "M": np.zeros((150, 4), np.float32)}
    asn = sharding.tree_assignment(
        tree, 3, split_bytes=1024, partitioner="ring"
    )
    subs = sharding.tree_subleaves(tree, 1024)
    assert set(asn) == {sk for _, sk, _, _ in subs}
    assert set(asn.values()) <= {0, 1, 2}
    with pytest.raises(ValueError):
        sharding.tree_assignment(tree, 2, partitioner="nope")


# -- chunk_elems floor (satellite: tiny split_bytes explosion) ----------------


def test_chunk_elems_clamps_tiny_split_with_one_warning():
    sharding._warned_small_split = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        n = sharding.chunk_elems(4, 1)
        assert w, "expected a one-time small-split warning"
    # the clamp enforces the minimum chunk byte size (8-elem aligned)
    assert n * 4 >= sharding._MIN_CHUNK_BYTES - 8 * 4
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        m = sharding.chunk_elems(4, 1)
        assert not w2, "warning must fire only once"
    assert m == n
    # sane splits are untouched
    assert sharding.chunk_elems(4, 4096) == 1024
    assert sharding.chunk_elems(4, 0) >= 8  # 0 = whole leaves elsewhere


@settings(max_examples=15)
@given(split=st.integers(min_value=1, max_value=1023))
def test_chunk_floor_bounds_subkey_count(split):
    """A 16 KiB leaf under any sub-floor split yields at most
    ceil(16 KiB / _MIN_CHUNK_BYTES) + 1 subkeys — never thousands."""
    sharding._warned_small_split = True  # silence the one-time warning
    tree = {"U": np.zeros((4096,), np.float32)}
    subs = sharding.tree_subleaves(tree, split)
    assert 1 <= len(subs) <= 16384 // sharding._MIN_CHUNK_BYTES + 1


# -- ScaleInAutoTuner interval accounting (satellite: stale timestamp) --------


def _synthetic_loss(t, theta=(0.05, 0.9, 0.5, 0.35)):
    a, b, c, d = theta
    return 1.0 / (a * np.power(t, b) + c) + d


def test_post_knee_eviction_waits_for_fresh_interval():
    """Fixed 1 s/step clock: pre-knee decide() calls must consume elapsed
    intervals, so the first post-knee decision fires on the next interval
    BOUNDARY — not immediately off a timestamp staled before the knee."""
    cfg = AutoTunerConfig(sched_interval_s=10.0, delta_s=5.0,
                          knee_slope_threshold=0.05, min_points_for_fit=6)
    tuner = ScaleInAutoTuner(cfg, initial_workers=8)
    t = np.arange(1, 120, dtype=np.float64)
    t_knee = None
    evict_times = []
    for i, loss in enumerate(_synthetic_loss(t), start=1):
        tuner.observe(i, float(loss), 1.0)
        d = tuner.decide()
        if tuner.knee_step is not None and t_knee is None:
            t_knee = tuner._time
        if d.remove_worker:
            evict_times.append(tuner._time)
    assert tuner.knee_step is not None and evict_times
    # knee discovery lands mid-interval; the buggy accounting fired the
    # knee-initial eviction right there off the stale pre-knee timestamp
    assert t_knee % cfg.sched_interval_s != 0.0
    for et in evict_times:
        assert et % cfg.sched_interval_s == 0.0, (t_knee, evict_times)


# -- TopologyTuner policy -----------------------------------------------------


def _cells():
    return [
        {"n_brokers": 1, "transport": "tcp"},
        {"n_brokers": 2, "transport": "tcp"},
    ]


def test_topology_tuner_explore_then_commit():
    tuner = TopologyTuner(
        _cells(), TopologyTunerConfig(explore_steps=2, warmup_steps=1)
    )
    assert tuner.next_action() is None
    for _ in range(3):  # warmup 1 + explore 2
        tuner.observe(0.02)
    kind, cell = tuner.next_action()
    assert kind == "explore" and cell == _cells()[1]
    # the active cell advances only when the supervisor reports the
    # handover complete — rows published meanwhile belong to the OLD cell
    assert tuner.active == 0
    tuner.observe(0.02)
    tuner.cell_started()
    assert tuner.active == 1
    for _ in range(3):
        tuner.observe(0.01)
    kind, cell = tuner.next_action()
    assert kind == "commit" and cell == _cells()[1]
    assert tuner.committed == 1
    s = tuner.summary()
    assert s["chosen"] == 1
    assert s["cells"][1]["p50"] == pytest.approx(0.01)
    # the straggler row landed in the old cell (4 observed, 1 warmup drop)
    assert s["cells"][0]["n_steps"] == 3


def test_topology_tuner_model_tie_break():
    """Measured p50s within rel_tolerance: the CommModel cost decides."""
    comm = CommModel()
    cheap = comm.indirect_exchange_time(1e6, 4, n_redis=2)
    dear = comm.indirect_exchange_time(1e6, 4, n_redis=1)
    assert cheap < dear  # precondition: more shards = less strain
    tuner = TopologyTuner(
        _cells(),
        TopologyTunerConfig(explore_steps=2, warmup_steps=1,
                            rel_tolerance=0.5),
        comm=comm, bytes_per_step=1e6, n_workers=4,
    )
    for _ in range(3):
        tuner.observe(0.0100)  # cell 0: slightly FASTER measured
    tuner.cell_started()
    for _ in range(3):
        tuner.observe(0.0105)  # cell 1: within 50% tolerance
    kind, cell = tuner.next_action()
    assert kind == "commit"
    assert cell["n_brokers"] == 2  # model cost broke the tie
    # out of tolerance the measurement wins regardless of the model
    strict = TopologyTuner(
        _cells(),
        TopologyTunerConfig(explore_steps=2, warmup_steps=1,
                            rel_tolerance=0.01),
        comm=comm, bytes_per_step=1e6, n_workers=4,
    )
    for _ in range(3):
        strict.observe(0.0100)
    strict.cell_started()
    for _ in range(3):
        strict.observe(0.0150)
    assert strict.next_action()[1]["n_brokers"] == 1


def test_topology_tuner_abandon():
    tuner = TopologyTuner(
        _cells(), TopologyTunerConfig(explore_steps=2, warmup_steps=1)
    )
    for _ in range(3):
        tuner.observe(0.02)
    tuner.abandon()
    assert tuner.next_action() is None
    s = tuner.summary()
    assert s["abandoned"] is True and s["chosen"] is None


# -- prewarm overlap clocks (satellite: wall/monotonic mix) -------------------


def _bare_supervisor(slot, tmp_path):
    s = object.__new__(sup.Supervisor)
    s.cfg = small_pmf_cfg(tmp_path)
    s.slots = [slot]
    s.cold_start_overlaps = []
    s._teardown_worker_shm = lambda sl: None
    return s


def test_promote_prewarmed_monotonic_overlap(tmp_path, monkeypatch):
    slot = sup._Slot(worker=0)
    slot.pre_proc = object()
    slot.pre_gate = str(tmp_path / "gate")
    slot.pre_spawned_mono = 100.0
    slot.pre_ready_mono = 105.5  # warmed in time
    s = _bare_supervisor(slot, tmp_path)
    monkeypatch.setattr(sup.time, "monotonic", lambda: 120.0)
    s._promote_prewarmed(slot)
    rec = s.cold_start_overlaps[-1]
    assert rec["overlap_s"] == pytest.approx(5.5)
    assert rec["ready_at_promotion"] is True
    assert os.path.exists(str(tmp_path / "gate"))


def test_promote_prewarmed_clamps_negative_overlap(tmp_path, monkeypatch):
    """Skewed bookkeeping (ready stamp before spawn stamp) is clamped to 0
    with a loud warning — never recorded as a negative/inflated overlap."""
    slot = sup._Slot(worker=0)
    slot.pre_proc = object()
    slot.pre_gate = str(tmp_path / "gate")
    slot.pre_spawned_mono = 100.0
    slot.pre_ready_mono = 90.0
    s = _bare_supervisor(slot, tmp_path)
    monkeypatch.setattr(sup.time, "monotonic", lambda: 120.0)
    with pytest.warns(UserWarning, match="negative prewarm overlap"):
        s._promote_prewarmed(slot)
    assert s.cold_start_overlaps[-1]["overlap_s"] == 0.0


def test_scan_prewarm_ready_ignores_file_mtime(tmp_path, monkeypatch):
    """The ready stamp is the supervisor's own monotonic sighting — a
    stepped wall clock (weird .ready mtime) cannot skew the overlap."""
    slot = sup._Slot(worker=0)
    slot.pre_proc = object()
    slot.pre_gate = str(tmp_path / "gate")
    ready = tmp_path / "gate.ready"
    ready.touch()
    os.utime(ready, (0, 0))  # epoch mtime: wall-clock garbage
    s = _bare_supervisor(slot, tmp_path)
    monkeypatch.setattr(sup.time, "monotonic", lambda: 55.5)
    s._scan_prewarm_ready()
    assert slot.pre_ready_mono == 55.5
    s._scan_prewarm_ready()  # first sighting sticks
    assert slot.pre_ready_mono == 55.5


# -- broker handover ops ------------------------------------------------------

JOB = {
    "workload": "pmf",
    "workload_cfg": {},
    "n_workers": 2,
    "total_steps": 10,
    "n_batches": 5,
}


def test_topo_begin_mint_idempotent_and_replayed(tmp_path):
    with BrokerCluster(dict(JOB), n_shards=2, wal_dir=str(tmp_path)) as c:
        r, _ = c.rpc({"t": "topo_begin"})
        assert r["granted"] and r["fence"] == 2  # max_published=0 -> 0+2
        r2, _ = c.rpc({"t": "topo_begin"})
        assert r2["granted"] and r2["fence"] == 2  # idempotent re-grant
        r3, _ = c.rpc({"t": "topo_begin"}, shard=1)
        assert not r3.get("granted")  # coordinator-only
        # the fence piggybacks on membership (hello/pull responses)
        hr, _ = c.rpc({"t": "hello", "worker": 0})
        assert hr["topo_fence"] == 2
    # SIGKILL-equivalent: a fresh cluster over the same WAL re-installs
    # the MINTED fence (logged as its result, never re-minted)
    with BrokerCluster(dict(JOB), n_shards=2, wal_dir=str(tmp_path)) as c2:
        assert c2.coordinator.core.topo_fence == 2
        r, _ = c2.rpc({"t": "topo_commit", "gen": 1, "n_shards": 2,
                       "n_brokers": 2, "transport": "shm"})
        assert r["ok"]
        assert c2.coordinator.core.topo_fence is None
        assert c2.coordinator.core.topo_gen == 1
        assert c2.coordinator.core.job["transport"] == "shm"
        hr, _ = c2.rpc({"t": "hello", "worker": 0})
        assert hr.get("topo_fence") is None
    # and the commit itself replays
    with BrokerCluster(dict(JOB), n_shards=2, wal_dir=str(tmp_path)) as c3:
        assert c3.coordinator.core.topo_fence is None
        assert c3.coordinator.core.topo_gen == 1


def test_topo_begin_refuses_past_end():
    with BrokerCluster(dict(JOB, total_steps=1)) as c:
        r, _ = c.rpc({"t": "topo_begin"})
        assert r["ok"] and not r["granted"] and r["reason"] == "past-end"
        assert c.coordinator.core.topo_fence is None


def test_migrate_roundtrip_totality_and_idempotence(tmp_path):
    """migrate_read -> migrate_in -> migrate_drop moves exactly the named
    (key, offset) identities; a retried migrate_in (respawned supervisor)
    is a no-op; byte accounting follows the moved update."""
    from repro.runtime import protocol

    import jax.numpy as jnp

    meta, payload = protocol.encode_tree(
        {"x": jnp.arange(6.0), "y": jnp.ones((4,))}
    )
    pub = {"t": "publish", "worker": 0, "step": 1, "meta": meta,
           "loss": 1.0, "sent_fraction": 1.0, "inv_err": 0.0}
    with BrokerCluster(dict(JOB), n_shards=2, wal_dir=str(tmp_path)) as c:
        c.rpc(pub, payload)
        bytes_before = c.coordinator.core.update_bytes
        r, blob = c.rpc({"t": "migrate_read", "moved": [["x", 0]]})
        assert r["ok"] and r["parts"]
        r_in, _ = c.rpc({"t": "migrate_in", "gen": 1, "src": 0,
                         "parts": r["parts"]}, blob, shard=1)
        assert r_in["ok"] and not r_in.get("already")
        dup, _ = c.rpc({"t": "migrate_in", "gen": 1, "src": 0,
                        "parts": r["parts"]}, blob, shard=1)
        assert dup["ok"] and dup["already"]  # idempotent retry
        rd, _ = c.rpc({"t": "migrate_drop", "moved": [["x", 0]]})
        assert rd["ok"]
        # source kept only 'y'; destination holds exactly 'x'
        src_meta = c.brokers[0].core.updates[1][0][0]
        dst_meta = c.brokers[1].core.updates[1][0][0]
        assert [m["k"] for m in src_meta] == ["y"]
        assert [m["k"] for m in dst_meta] == ["x"]
        moved_wire = protocol.wire_bytes(
            [m for m in meta if m["k"] == "x"]
        )
        assert c.brokers[0].core.update_bytes == bytes_before - moved_wire
        assert c.brokers[1].core.update_bytes == moved_wire
    # WAL replay on BOTH sides reproduces the post-migration stores
    with BrokerCluster(dict(JOB), n_shards=2, wal_dir=str(tmp_path)) as c2:
        assert [m["k"] for m in c2.brokers[0].core.updates[1][0][0]] == ["y"]
        assert [m["k"] for m in c2.brokers[1].core.updates[1][0][0]] == ["x"]
        assert (1, 0) in c2.brokers[1].core.migrations_applied


# -- end-to-end: live re-shard bit-identity (the acceptance runs) -------------


@pytest.fixture(scope="module")
def fixed_topology_digest(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("topo_ref")
    run_small_pmf(tmp)
    return final_params_digest(small_pmf_cfg(tmp / "job"))


def test_live_reshard_bit_identical(tmp_path, fixed_topology_digest):
    """1 -> 2 brokers AND tcp -> shm mid-job: final params bit-identical
    to the never-resharded reference, zero duplicate-publish mismatches."""
    res = run_small_pmf(
        tmp_path,
        scripted_retunes=((3, {"n_brokers": 2, "transport": "shm"}),),
        partitioner="ring",
        shard_split_bytes=1024,
        # pace the job (pure timing, identical math) so the supervisor
        # always reaches the trigger with steps left for the fence
        straggler={"worker": 0, "delay_s": 0.08, "every": 1},
    )
    assert res["dup_mismatches"] == 0
    events = [e for e in res["topology_events"] if "refused" not in e]
    assert len(events) == 1
    assert events[0]["changes"] == {"n_brokers": 2, "transport": "shm"}
    assert res["topology"]["n_brokers"] == 2
    assert res["topology"]["transport"] == "shm"
    got = final_params_digest(small_pmf_cfg(tmp_path / "job"))
    assert got == fixed_topology_digest


def test_live_reshard_survives_broker_sigkill(tmp_path,
                                              fixed_topology_digest):
    """SIGKILL the source shard right after its first migration RPC: the
    WAL replay + idempotent migrate_in reproduce the identical handover."""
    res = run_small_pmf(
        tmp_path,
        scripted_retunes=((3, {"n_brokers": 2, "transport": "shm"}),),
        partitioner="ring",
        shard_split_bytes=1024,
        kill_broker_during_handover=0,
        straggler={"worker": 0, "delay_s": 0.08, "every": 1},
    )
    assert res["dup_mismatches"] == 0
    events = [e for e in res["topology_events"] if "refused" not in e]
    assert len(events) == 1, res["topology_events"]
    assert len(res.get("broker_respawns", [])) >= 1
    got = final_params_digest(small_pmf_cfg(tmp_path / "job"))
    assert got == fixed_topology_digest


def test_reshard_requires_isp():
    with pytest.raises(ValueError, match="isp"):
        sup.Supervisor(small_pmf_cfg(
            "/tmp/nonexistent", consistency="ssp", slack=2,
            scripted_retunes=((4, {"n_brokers": 2}),),
        ))
    with pytest.raises(ValueError, match="prewarm"):
        sup.Supervisor(small_pmf_cfg(
            "/tmp/nonexistent", prewarm=True, topology_tune=True,
        ))
    with pytest.raises(ValueError, match="unknown knobs"):
        sup.Supervisor(small_pmf_cfg(
            "/tmp/nonexistent",
            scripted_retunes=((4, {"n_workers": 9}),),
        ))
