"""End-to-end tests of the multi-process FaaS runtime (repro.runtime).

These spawn REAL worker processes (each imports jax, restores from the
checkpoint store, and talks to the broker over sockets), so they are the
slowest tier-1 tests — sized to a tiny PMF instance.

The heart of the file is the bit-verification test the acceptance criteria
ask for: every update published by every worker process across a run must
be bit-identical to what the ``core.isp`` reference semantics produce on a
shared seed — the runtime is the paper's system, not an approximation of
it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import isp as isp_lib
from repro.runtime import FaaSJobConfig, build_workload, run_job

WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
P = 3
STEPS = 8
V = 0.5
LR = 0.08


def _cfg(tmp_path, **kw) -> FaaSJobConfig:
    base = dict(
        run_dir=str(tmp_path / "job"),
        workload="pmf",
        workload_cfg=WCFG,
        n_workers=P,
        total_steps=STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=LR,
        isp_v=V,
        deadline_s=180.0,
    )
    base.update(kw)
    return FaaSJobConfig(**base)


@pytest.fixture(scope="module")
def plain_run(tmp_path_factory):
    """One shared end-to-end run (real processes are expensive)."""
    tmp = tmp_path_factory.mktemp("faas_e2e")
    return run_job(_cfg(tmp, retain_updates=True))


def test_e2e_completes_all_steps_with_real_processes(plain_run):
    res = plain_run
    assert res["steps"] == STEPS
    assert len(res["history"]) == STEPS
    assert res["final_pool"] == P
    assert res["n_invocations"] == P  # one invocation per worker
    assert all(r["p_active"] == P for r in res["history"])


def test_e2e_conservation_invariant_holds_pool_wide(plain_run):
    # sent + residual' == residual + update, exactly, for every worker at
    # every step (each worker computes the witness on its own tensors)
    assert plain_run["invariant_max_err"] == 0.0


def test_e2e_bill_from_measured_lifetimes(plain_run):
    bill = plain_run["bill"]
    lifetimes = plain_run["lifetimes_s"]
    assert len(lifetimes) == P and all(t > 0 for t in lifetimes)
    # per-lifetime rounding up to the 100 ms quantum
    q = 0.1
    expect = sum(np.ceil(t / q) * q for t in lifetimes)
    assert bill["worker_seconds"] == pytest.approx(expect)
    assert bill["worker_seconds"] >= sum(lifetimes)
    assert bill["total"] > 0


def test_e2e_byte_accounting(plain_run):
    stats = plain_run["broker_stats"]
    for kind in ("hello", "batch", "publish", "pull", "report", "bye"):
        assert stats[kind]["count"] > 0, kind
    assert stats["publish"]["count"] == P * STEPS
    assert stats["publish"]["bytes_in"] > plain_run["wire_bytes_total"]
    assert plain_run["dup_mismatches"] == 0


def test_e2e_updates_bit_identical_to_core_isp_reference(plain_run):
    """Replay the whole job in-process with core.isp replica semantics and
    require every published update to match bit-for-bit."""
    pub = {
        (u["worker"], u["step"]): u["update"] for u in plain_run["updates"]
    }
    assert len(pub) == P * STEPS

    wl = build_workload("pmf", WCFG)
    optimizer = optim.make("nesterov", LR)
    isp = isp_lib.ISPConfig(v=V)

    def compute(params, opt_state, residual, batch, inv_p, t):
        loss, grads = wl.grad_fn(params, batch)
        upd, opt_state = optimizer.update(grads, opt_state, params)
        u = jax.tree.map(lambda a: (a * inv_p).astype(a.dtype), upd)
        sig, st, _ = isp_lib.filter_update(
            isp, isp_lib.ISPState(residual=residual, step=t), u, params
        )
        return u, sig, st.residual, opt_state

    compute = jax.jit(compute)
    apply_v = jax.jit(
        lambda p, u, pe: jax.tree.map(
            lambda a, b, c: a + b + c.astype(a.dtype), p, u, pe
        )
    )

    params = [wl.params0] * P
    opts = [optimizer.init(wl.params0) for _ in range(P)]
    residuals = [jax.tree.map(jnp.zeros_like, wl.params0) for _ in range(P)]
    for t in range(1, STEPS + 1):
        sigs, us = {}, {}
        for w in range(P):
            key = ((t - 1) * P + w) % wl.n_batches
            u, sig, r2, opts[w] = compute(
                params[w], opts[w], residuals[w], wl.batch(key),
                jnp.asarray(1.0 / P, jnp.float32),
                jnp.asarray(t, jnp.int32),
            )
            residuals[w] = r2
            sigs[w], us[w] = sig, u
            for ref, got in zip(
                jax.tree.leaves(sig), jax.tree.leaves(pub[(w, t)])
            ):
                np.testing.assert_array_equal(
                    np.asarray(ref), np.asarray(got),
                    err_msg=f"worker {w} step {t}: runtime diverged from "
                    f"core.isp semantics",
                )
        for w in range(P):
            acc = jax.tree.map(
                lambda x: np.zeros(np.shape(x), np.asarray(x).dtype),
                wl.params0,
            )
            for w2 in sorted(sigs):
                if w2 != w:
                    acc = jax.tree.map(
                        lambda a, b: a + np.asarray(b), acc, sigs[w2]
                    )
            params[w] = apply_v(params[w], us[w], acc)


def test_e2e_scripted_eviction_and_invocation_boundaries(tmp_path):
    """Scale-in mid-run + invocation-bounded workers in one job: the pool
    shrinks at the broker-chosen step, survivors keep training across
    invocation respawns, and the conservation invariant holds throughout."""
    res = run_job(
        _cfg(
            tmp_path,
            total_steps=14,
            invocation_steps=6,  # forces mid-job respawns
            checkpoint_every=5,
            scripted_evict_steps=(4,),
            deadline_s=240.0,
        )
    )
    assert res["steps"] == 14
    assert len(res["scale_events"]) == 1
    ev = res["scale_events"][0]
    assert ev["worker"] == P - 1  # highest id leaves (simulator policy)
    e = ev["evict_step"]
    pools = [r["p_active"] for r in res["history"]]
    assert all(p == P for p in pools[: e - 1])
    assert all(p == P - 1 for p in pools[e - 1 :])
    assert res["final_pool"] == P - 1
    assert res["invariant_max_err"] == 0.0
    assert res["dup_mismatches"] == 0
    # invocation boundaries: more invocations than workers, billed per spawn
    assert res["n_invocations"] > P
    assert len(res["lifetimes_s"]) == res["n_invocations"]
    # training kept making progress across the transition
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]
