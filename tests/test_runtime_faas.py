"""End-to-end tests of the multi-process FaaS runtime (repro.runtime).

These spawn REAL worker processes (each imports jax, restores from the
checkpoint store, and talks to the broker over sockets), so they are the
slowest tier-1 tests — sized to the tiny PMF instance the shared harness
provides (``tests/runtime_harness.py``).

The heart of the file is the bit-verification test the acceptance criteria
ask for: every update published by every worker process across a run must
be bit-identical to what the ``core.isp`` reference semantics produce on a
shared seed — the runtime is the paper's system, not an approximation of
it.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from runtime_harness import (
    SMALL_P as P,
    SMALL_STEPS as STEPS,
    reference_updates,
    run_small_pmf,
)


@pytest.fixture(scope="module")
def plain_run(tmp_path_factory):
    """One shared end-to-end run (real processes are expensive)."""
    tmp = tmp_path_factory.mktemp("faas_e2e")
    return run_small_pmf(tmp, retain_updates=True)


def test_e2e_completes_all_steps_with_real_processes(plain_run):
    res = plain_run
    assert res["steps"] == STEPS
    assert len(res["history"]) == STEPS
    assert res["final_pool"] == P
    assert res["n_invocations"] == P  # one invocation per worker
    assert all(r["p_active"] == P for r in res["history"])


def test_e2e_conservation_invariant_holds_pool_wide(plain_run):
    # sent + residual' == residual + update, exactly, for every worker at
    # every step (each worker computes the witness on its own tensors)
    assert plain_run["invariant_max_err"] == 0.0


def test_e2e_bill_from_measured_lifetimes(plain_run):
    bill = plain_run["bill"]
    lifetimes = plain_run["lifetimes_s"]
    assert len(lifetimes) == P and all(t > 0 for t in lifetimes)
    # per-lifetime rounding up to the 100 ms quantum
    q = 0.1
    expect = sum(np.ceil(t / q) * q for t in lifetimes)
    assert bill["worker_seconds"] == pytest.approx(expect)
    assert bill["worker_seconds"] >= sum(lifetimes)
    assert bill["total"] > 0
    # single-shard topology bills a single Redis-analogue VM
    assert bill["n_redis"] == 1


def test_e2e_byte_accounting(plain_run):
    stats = plain_run["broker_stats"]
    for kind in ("hello", "batch", "publish", "pull", "report", "bye"):
        assert stats[kind]["count"] > 0, kind
    assert stats["publish"]["count"] == P * STEPS
    assert stats["publish"]["bytes_in"] > plain_run["wire_bytes_total"]
    # the per-shard split sums to the merged view (one shard here)
    assert sum(plain_run["broker_update_bytes_per_shard"]) == (
        plain_run["wire_bytes_total"]
    )
    assert plain_run["dup_mismatches"] == 0


def test_e2e_updates_bit_identical_to_core_isp_reference(plain_run):
    """Replay the whole job in-process with core.isp replica semantics and
    require every published update to match bit-for-bit."""
    pub = {
        (u["worker"], u["step"]): u["update"] for u in plain_run["updates"]
    }
    assert len(pub) == P * STEPS

    ref, _final = reference_updates()
    for (w, t), sig in sorted(ref.items()):
        for a, b in zip(jax.tree.leaves(sig), jax.tree.leaves(pub[(w, t)])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"worker {w} step {t}: runtime diverged from "
                f"core.isp semantics",
            )


def test_e2e_scripted_eviction_and_invocation_boundaries(tmp_path):
    """Scale-in mid-run + invocation-bounded workers in one job: the pool
    shrinks at the broker-chosen step, survivors keep training across
    invocation respawns, and the conservation invariant holds throughout."""
    res = run_small_pmf(
        tmp_path,
        total_steps=14,
        invocation_steps=6,  # forces mid-job respawns
        checkpoint_every=5,
        scripted_evict_steps=(4,),
        deadline_s=240.0,
    )
    assert res["steps"] == 14
    assert len(res["scale_events"]) == 1
    ev = res["scale_events"][0]
    assert ev["worker"] == P - 1  # highest id leaves (simulator policy)
    e = ev["evict_step"]
    pools = [r["p_active"] for r in res["history"]]
    assert all(p == P for p in pools[: e - 1])
    assert all(p == P - 1 for p in pools[e - 1 :])
    assert res["final_pool"] == P - 1
    assert res["invariant_max_err"] == 0.0
    assert res["dup_mismatches"] == 0
    # invocation boundaries: more invocations than workers, billed per spawn
    assert res["n_invocations"] > P
    assert len(res["lifetimes_s"]) == res["n_invocations"]
    # training kept making progress across the transition
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]
