"""BSP / SSP / ISP exchange semantics (paper §3.1, §4.1, §6.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import consistency as cons
from repro.core.isp import ISPConfig


def _stacked(P, key, scale=1.0):
    return {"w": scale * jax.random.normal(key, (P, 6))}


def test_bsp_everyone_sees_everything():
    P = 4
    upd = _stacked(P, jax.random.PRNGKey(0))
    visible = cons.bsp_exchange(upd)
    want = jnp.sum(upd["w"], axis=0)
    for p in range(P):
        np.testing.assert_allclose(np.asarray(visible["w"][p]),
                                   np.asarray(want), rtol=1e-6)


def test_ssp_delays_up_to_slack():
    """With slack s, an update produced at step t must be fully visible by
    step t+s; until then workers may see partial histories."""
    P, slack = 3, 2
    params = _stacked(P, jax.random.PRNGKey(1))
    state = cons.ssp_init(params, slack)
    seen = jnp.zeros_like(params["w"])
    first = _stacked(P, jax.random.PRNGKey(2))
    visible, state = cons.ssp_step(state, first)
    seen = seen + visible["w"]
    total_first = jnp.sum(first["w"], axis=0)
    zeros = _stacked(P, jax.random.PRNGKey(3), scale=0.0)
    for _ in range(slack):
        visible, state = cons.ssp_step(state, zeros)
        seen = seen + visible["w"]
    # after `slack` more steps the first step's updates are fully applied
    for p in range(P):
        np.testing.assert_allclose(np.asarray(seen[p]),
                                   np.asarray(total_first), rtol=1e-5,
                                   atol=1e-6)


def test_ssp_drain_flushes_queue():
    P, slack = 2, 3
    params = _stacked(P, jax.random.PRNGKey(4))
    state = cons.ssp_init(params, slack)
    upd = _stacked(P, jax.random.PRNGKey(5))
    visible, state = cons.ssp_step(state, upd)
    rest = cons.ssp_drain(state)
    total = visible["w"] + rest["w"]
    want = jnp.sum(upd["w"], axis=0)
    for p in range(P):
        np.testing.assert_allclose(np.asarray(total[p]), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


@given(P=st.integers(min_value=2, max_value=4),
       slack=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=50))
@settings(max_examples=20)
def test_ssp_visibility_bound_property(P, slack, seed):
    """SSP contract, for ANY (P, slack): an update produced at step t is
    fully visible by step t + slack."""
    params = _stacked(P, jax.random.PRNGKey(seed))
    state = cons.ssp_init(params, slack)
    first = _stacked(P, jax.random.PRNGKey(seed + 1))
    visible, state = cons.ssp_step(state, first)
    seen = np.asarray(visible["w"])
    zeros = _stacked(P, jax.random.PRNGKey(0), scale=0.0)
    for _ in range(slack):
        visible, state = cons.ssp_step(state, zeros)
        seen = seen + np.asarray(visible["w"])
    want = np.asarray(jnp.sum(first["w"], axis=0))
    for p in range(P):
        np.testing.assert_allclose(seen[p], want, rtol=1e-5, atol=1e-6)


@given(P=st.integers(min_value=2, max_value=4),
       slack=st.integers(min_value=1, max_value=4),
       n_steps=st.integers(min_value=1, max_value=5),
       seed=st.integers(min_value=0, max_value=50))
@settings(max_examples=20)
def test_ssp_drain_conserves_mass_property(P, slack, n_steps, seed):
    """The delay queue never loses or duplicates update mass: everything
    made visible across the steps plus ``ssp_drain``'s remainder equals
    the sum of every update fed in, per replica row."""
    params = _stacked(P, jax.random.PRNGKey(seed))
    state = cons.ssp_init(params, slack)
    total_in = np.zeros((P, 6), np.float32)
    total_seen = np.zeros((P, 6), np.float32)
    for k in range(n_steps):
        upd = _stacked(P, jax.random.PRNGKey(seed + 10 + k))
        total_in = total_in + np.asarray(jnp.sum(upd["w"], axis=0))
        visible, state = cons.ssp_step(state, upd)
        total_seen = total_seen + np.asarray(visible["w"])
    rest = cons.ssp_drain(state)
    total_seen = total_seen + np.asarray(rest["w"])
    for p in range(P):
        np.testing.assert_allclose(total_seen[p], total_in[p],
                                   rtol=1e-4, atol=1e-5)


def test_isp_exchange_bounds_divergence():
    """Replica divergence under ISP stays within the Theorem 1 bound: any
    two replicas differ by at most the sum of the P residual bounds."""
    P = 3
    key = jax.random.PRNGKey(6)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (P,) + x.shape),
        {"w": jax.random.normal(key, (10,))},
    )
    cfg = ISPConfig(v=0.5, decay=False)
    state = cons.isp_init(params)
    for step in range(6):
        upd = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(10 + step),
                                            (P, 10))}
        # the significance test runs against the PRE-exchange replica
        # values; the residual bound |r_i| <= v * max(|x_i|, floor) holds
        # relative to these, not to the post-step params
        w_at_test = np.asarray(params["w"])
        visible, state, masks = cons.isp_exchange(cfg, state, upd, params)
        params = jax.tree.map(lambda p, v: p + v, params, visible)
    w = np.asarray(params["w"])
    # x_p - x_q == r_p - r_q exactly (emitted mass is common to all
    # replicas), so the spread is bounded by the P per-worker residual
    # bounds evaluated where the filter evaluated them
    spread = np.abs(w.max(0) - w.min(0))
    bound = P * 0.5 * np.maximum(np.abs(w_at_test).max(0), 1e-8) + 1e-5
    assert np.all(spread <= bound), (spread, bound)


def test_isp_v0_equals_bsp_replicas_identical():
    P = 4
    key = jax.random.PRNGKey(7)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (P,) + x.shape),
        {"w": jax.random.normal(key, (8,))},
    )
    cfg = ISPConfig(v=0.0, decay=False)
    state = cons.isp_init(params)
    for step in range(4):
        upd = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(20 + step),
                                            (P, 8))}
        visible, state, _ = cons.isp_exchange(cfg, state, upd, params)
        params = jax.tree.map(lambda p, v: p + v, params, visible)
    w = np.asarray(params["w"])
    for p in range(1, P):
        np.testing.assert_allclose(w[p], w[0], rtol=1e-5, atol=1e-6)


def test_isp_communicates_less_than_bsp():
    P = 4
    key = jax.random.PRNGKey(8)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (P,) + x.shape),
        {"w": jax.random.normal(key, (1000,))},
    )
    cfg = ISPConfig(v=2.0, decay=False)
    state = cons.isp_init(params)
    upd = {"w": 0.01 * jax.random.normal(jax.random.PRNGKey(30), (P, 1000))}
    _, state, masks = cons.isp_exchange(cfg, state, upd, params)
    frac = float(
        jnp.mean(jnp.asarray([jnp.mean(m.astype(jnp.float32))
                              for m in jax.tree.leaves(masks)]))
    )
    assert frac < 0.5
