"""Simulator + billing: platform orderings, cost model, paper sanity check."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import billing, consistency as cons
from repro.core.isp import ISPConfig
from repro.core.simulator import Platform, ServerlessSimulator, SimulatorConfig
from repro.models import pmf


def test_pricing_matches_table2():
    """Paper Table 2 (us-east, Apr 2021)."""
    # worker: 3.4e-5 $/s; C1.4x4 0.15 $/h; M1.2x16 0.17 $/h; B1.4x8 0.2 $/h
    bill = billing.faas_cost([100.0], wall_s=100.0, n_redis=1)
    assert bill.worker_cost == pytest.approx(3.4e-5 * 100.0)
    infra_hourly = 0.15 + 0.17
    assert bill.infra_cost == pytest.approx(infra_hourly / 3600 * 100.0)
    # four PyTorch workers share one B1.4x8 VM
    assert billing.iaas_cost(8, 3600.0) == pytest.approx(2 * 0.2)


def test_faas_cheaper_when_scaled_in():
    """Sub-second billing: dropping workers cuts the bill proportionally."""
    full = billing.faas_cost([100.0] * 8, 100.0, 1).total
    half = billing.faas_cost([100.0] * 4 + [50.0] * 4, 100.0, 1).total
    assert half < full


def _mini_pmf(P=4, platform=Platform.MLLESS, model=cons.Model.BSP,
              tuner=None, steps=30, seed=0):
    cfg = pmf.PMFConfig(n_users=200, n_movies=300, rank=8)
    params = pmf.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    users = rng.integers(0, 200, 20_000).astype(np.int32)
    movies = rng.integers(0, 300, 20_000).astype(np.int32)
    ratings = rng.normal(3.0, 1.0, 20_000).astype(np.float32)

    def batch_fn(step, n_workers):
        r = np.random.default_rng(step)
        idx = r.integers(0, 20_000, size=(n_workers, 256))
        return pmf.RatingsBatch(
            user=jnp.asarray(users[idx]), movie=jnp.asarray(movies[idx]),
            rating=jnp.asarray(ratings[idx]),
        )

    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=P, platform=platform,
            consistency=cons.ConsistencyConfig(model=model,
                                               isp=ISPConfig(v=0.7)),
            sparse_model=True, seed=seed,
        ),
        grad_fn=partial(pmf.grad_fn, cfg),
        optimizer=optim.make("nesterov", 0.05),
        params=params,
        flops_per_sample=6 * 8 * 3,
        update_nnz_fn=lambda b: 2 * 8 * b,
    )
    return sim.run(batch_fn, 256, steps, tuner=tuner)


def test_platform_step_time_ordering():
    """Per modelled step: PyWren (object-store exchange) slowest; the
    specialized platforms faster."""
    t = {}
    for plat in (Platform.MLLESS, Platform.SERVERFUL, Platform.PYWREN):
        res = _mini_pmf(platform=plat, steps=10)
        t[plat] = res.total_wall_s / len(res.records)
    assert t[Platform.PYWREN] > t[Platform.MLLESS]
    assert t[Platform.PYWREN] > t[Platform.SERVERFUL]


def test_isp_reduces_comm_bytes():
    bsp = _mini_pmf(model=cons.Model.BSP, steps=15)
    isp = _mini_pmf(model=cons.Model.ISP, steps=15)
    bsp_bytes = sum(r.comm_bytes for r in bsp.records)
    isp_bytes = sum(r.comm_bytes for r in isp.records)
    assert isp_bytes < 0.7 * bsp_bytes, (isp_bytes, bsp_bytes)


def test_convergence_identical_across_platforms_fixed_seed():
    """The paper's §6.1 sanity check: same seed -> identical per-step loss
    on every platform (timing differs, optimization does not)."""
    a = _mini_pmf(platform=Platform.MLLESS, steps=8)
    b = _mini_pmf(platform=Platform.SERVERFUL, steps=8)
    la = [r.loss for r in a.records]
    lb = [r.loss for r in b.records]
    np.testing.assert_allclose(la, lb, rtol=1e-5)


def test_tuner_reduces_cost():
    from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner

    fixed = _mini_pmf(model=cons.Model.ISP, steps=60)
    tuned = _mini_pmf(
        model=cons.Model.ISP, steps=60,
        tuner=ScaleInAutoTuner(
            AutoTunerConfig(sched_interval_s=0.5, delta_s=0.25,
                            min_points_for_fit=5), 4),
    )
    assert tuned.summary["final_workers"] <= fixed.summary["final_workers"]
    if tuned.summary["final_workers"] < fixed.summary["final_workers"]:
        assert tuned.total_cost < fixed.total_cost


def test_eviction_masks_worker_inert():
    res = _mini_pmf(steps=5)
    assert res.summary["final_workers"] == 4
    assert len(res.worker_lifetimes_s) == 4
    assert all(lt > 0 for lt in res.worker_lifetimes_s)


def test_comm_model_monotonicity():
    cm = billing.CommModel()
    t1 = cm.indirect_exchange_time(1e6, 4, 1)
    t2 = cm.indirect_exchange_time(1e6, 8, 1)
    t3 = cm.indirect_exchange_time(1e6, 8, 2)
    assert t2 > t1  # more workers -> more exchange through one store
    assert t3 < t2  # sharding the store helps
    assert cm.allreduce_time(1e6, 8) < cm.indirect_exchange_time(1e6, 8, 1)
