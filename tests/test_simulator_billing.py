"""Simulator + billing: platform orderings, cost model, paper sanity check."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import billing, consistency as cons
from repro.core.isp import ISPConfig
from repro.core.simulator import Platform, ServerlessSimulator, SimulatorConfig
from repro.models import pmf


def test_pricing_matches_table2():
    """Paper Table 2 (us-east, Apr 2021)."""
    # worker: 3.4e-5 $/s; C1.4x4 0.15 $/h; M1.2x16 0.17 $/h; B1.4x8 0.2 $/h
    bill = billing.faas_cost([100.0], wall_s=100.0, n_redis=1)
    assert bill.worker_cost == pytest.approx(3.4e-5 * 100.0)
    infra_hourly = 0.15 + 0.17
    assert bill.infra_cost == pytest.approx(infra_hourly / 3600 * 100.0)
    # four PyTorch workers share one B1.4x8 VM
    assert billing.iaas_cost(8, 3600.0) == pytest.approx(2 * 0.2)


def test_faas_cheaper_when_scaled_in():
    """Sub-second billing: dropping workers cuts the bill proportionally."""
    full = billing.faas_cost([100.0] * 8, 100.0, 1).total
    half = billing.faas_cost([100.0] * 4 + [50.0] * 4, 100.0, 1).total
    assert half < full


def _mini_pmf(P=4, platform=Platform.MLLESS, model=cons.Model.BSP,
              tuner=None, steps=30, seed=0, slack=3, straggler=None):
    cfg = pmf.PMFConfig(n_users=200, n_movies=300, rank=8)
    params = pmf.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    users = rng.integers(0, 200, 20_000).astype(np.int32)
    movies = rng.integers(0, 300, 20_000).astype(np.int32)
    ratings = rng.normal(3.0, 1.0, 20_000).astype(np.float32)

    def batch_fn(step, n_workers):
        r = np.random.default_rng(step)
        idx = r.integers(0, 20_000, size=(n_workers, 256))
        return pmf.RatingsBatch(
            user=jnp.asarray(users[idx]), movie=jnp.asarray(movies[idx]),
            rating=jnp.asarray(ratings[idx]),
        )

    straggler = straggler or {}
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=P, platform=platform,
            consistency=cons.ConsistencyConfig(model=model,
                                               isp=ISPConfig(v=0.7),
                                               slack=slack),
            sparse_model=True, seed=seed,
            straggler_worker=straggler.get("worker"),
            straggler_delay_s=straggler.get("delay_s", 0.0),
            straggler_every=straggler.get("every", 1),
        ),
        grad_fn=partial(pmf.grad_fn, cfg),
        optimizer=optim.make("nesterov", 0.05),
        params=params,
        flops_per_sample=6 * 8 * 3,
        update_nnz_fn=lambda b: 2 * 8 * b,
    )
    return sim.run(batch_fn, 256, steps, tuner=tuner)


def test_platform_step_time_ordering():
    """Per modelled step: PyWren (object-store exchange) slowest; the
    specialized platforms faster."""
    t = {}
    for plat in (Platform.MLLESS, Platform.SERVERFUL, Platform.PYWREN):
        res = _mini_pmf(platform=plat, steps=10)
        t[plat] = res.total_wall_s / len(res.records)
    assert t[Platform.PYWREN] > t[Platform.MLLESS]
    assert t[Platform.PYWREN] > t[Platform.SERVERFUL]


def test_isp_reduces_comm_bytes():
    bsp = _mini_pmf(model=cons.Model.BSP, steps=15)
    isp = _mini_pmf(model=cons.Model.ISP, steps=15)
    bsp_bytes = sum(r.comm_bytes for r in bsp.records)
    isp_bytes = sum(r.comm_bytes for r in isp.records)
    assert isp_bytes < 0.7 * bsp_bytes, (isp_bytes, bsp_bytes)


def test_convergence_identical_across_platforms_fixed_seed():
    """The paper's §6.1 sanity check: same seed -> identical per-step loss
    on every platform (timing differs, optimization does not)."""
    a = _mini_pmf(platform=Platform.MLLESS, steps=8)
    b = _mini_pmf(platform=Platform.SERVERFUL, steps=8)
    la = [r.loss for r in a.records]
    lb = [r.loss for r in b.records]
    np.testing.assert_allclose(la, lb, rtol=1e-5)


def test_tuner_reduces_cost():
    from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner

    fixed = _mini_pmf(model=cons.Model.ISP, steps=60)
    tuned = _mini_pmf(
        model=cons.Model.ISP, steps=60,
        tuner=ScaleInAutoTuner(
            AutoTunerConfig(sched_interval_s=0.5, delta_s=0.25,
                            min_points_for_fit=5), 4),
    )
    assert tuned.summary["final_workers"] <= fixed.summary["final_workers"]
    if tuned.summary["final_workers"] < fixed.summary["final_workers"]:
        assert tuned.total_cost < fixed.total_cost


def test_eviction_masks_worker_inert():
    res = _mini_pmf(steps=5)
    assert res.summary["final_workers"] == 4
    assert len(res.worker_lifetimes_s) == 4
    assert all(lt > 0 for lt in res.worker_lifetimes_s)


def test_ssp_pipeline_pricing_is_physical():
    """The modelled SSP wall prices the bounded-staleness pipeline
    (DESIGN.md §13): per-step wall increments are frontier advances, so
    they are non-negative, they sum to the pool frontier, and — since a
    worker never waits for a barrier, only for its own chain and the
    s-lagged gate — the pipelined wall can only beat the synchronous
    barrier over the identical busy-time stream (BSP at the same seed
    ships the same bytes and draws the same jitter)."""
    bsp = _mini_pmf(model=cons.Model.BSP, steps=20)
    ssp = _mini_pmf(model=cons.Model.SSP, steps=20, slack=3)
    assert all(r.wall_s >= 0.0 for r in ssp.records)
    assert ssp.total_wall_s == pytest.approx(
        sum(r.wall_s for r in ssp.records)
    )
    assert ssp.total_wall_s <= bsp.total_wall_s + 1e-9


def test_straggler_injection_prices_the_delay():
    """An intermittent straggler (delay d every k-th step) under a
    synchronous barrier costs exactly the injected delays: the hit worker
    is the per-step max on each hit step."""
    straggler = {"worker": 0, "delay_s": 0.5, "every": 4}
    base = _mini_pmf(model=cons.Model.ISP, steps=20)
    slow = _mini_pmf(model=cons.Model.ISP, steps=20, straggler=straggler)
    n_hits = 20 // 4
    excess = slow.total_wall_s - base.total_wall_s
    assert excess == pytest.approx(n_hits * 0.5, rel=0.05)


def test_comm_model_monotonicity():
    cm = billing.CommModel()
    t1 = cm.indirect_exchange_time(1e6, 4, 1)
    t2 = cm.indirect_exchange_time(1e6, 8, 1)
    t3 = cm.indirect_exchange_time(1e6, 8, 2)
    assert t2 > t1  # more workers -> more exchange through one store
    assert t3 < t2  # sharding the store helps
    assert cm.allreduce_time(1e6, 8) < cm.indirect_exchange_time(1e6, 8, 1)
