"""Bit-identity of the fused Pallas encode/decode path (DESIGN.md §15).

The Pallas kernels of ``kernels/wire_pack.py`` are an IMPLEMENTATION of
the wire codec, not a codec: for every (scheme, dtype, quant, shape)
cell the bytes on the wire, the meta accounting, the error-feedback
residual, and the decoded/accumulated values must equal the numpy
reference bit for bit.  Property tests drive random cells through both
backends; the edge-shape suite pins the cases a tiled kernel gets wrong
first (empty, scalar, single element, non-tile-aligned, all-significant,
all-zero).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime import sharding
from repro.wire import codec

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    BF16 = None

SCHEMES = ("dense", "sparse", "bitmap", "auto")

# (leaf dtype, quant) pairs that are valid together — integer leaves
# never quantize (codec.quant_dtype passes them through)
DTYPE_QUANT = [
    ("float32", "none"),
    ("float32", "fp16"),
    ("float16", "none"),
    ("int32", "none"),
]
if BF16 is not None:
    DTYPE_QUANT += [("float32", "bf16"), ("bfloat16", "none"),
                    ("bfloat16", "bf16")]


def _dtype(name: str) -> np.dtype:
    return BF16 if name == "bfloat16" else np.dtype(name)


def _leaf(n: int, density: float, seed: int, dtype_name: str,
          shape=None) -> np.ndarray:
    rng = np.random.RandomState(seed)
    dt = _dtype(dtype_name)
    if np.dtype(dt).kind == "f":
        x = (rng.randn(n) * 3).astype(np.float32)
    else:
        x = rng.randint(-1000, 1000, size=n).astype(np.int64)
    if n:
        x[rng.rand(n) >= density] = 0
    a = x.astype(dt)
    return a.reshape(shape) if shape is not None else a


def _encode_both(a, scheme, quant):
    """(meta, blob, residual) under each backend; pallas is FORCED (the
    explicit impl), so any silent fallback shows up as resolve_impl
    returning numpy — asserted by the caller when it expects the kernel."""
    out = {}
    for impl in ("numpy", "pallas"):
        meta, parts, res = codec.encode_leaf(
            a, scheme=scheme, quant=quant, key="k",
            with_residual=True, impl=impl,
        )
        out[impl] = (meta, b"".join(bytes(p) for p in parts), res)
    return out["numpy"], out["pallas"]


def _assert_identical(a, scheme, quant):
    (m_np, b_np, r_np), (m_pl, b_pl, r_pl) = _encode_both(a, scheme, quant)
    assert m_np == m_pl
    assert b_np == b_pl
    assert (r_np is None) == (r_pl is None)
    if r_np is not None:
        assert r_np.dtype == r_pl.dtype
        assert r_np.tobytes() == r_pl.tobytes()
    # decode round-trips identically through both backends
    d_np = codec.decode_leaf(m_np, b_np, impl="numpy")
    d_pl = codec.decode_leaf(m_pl, b_pl, impl="pallas")
    assert d_np.tobytes() == d_pl.tobytes()
    assert d_np.shape == d_pl.shape == a.shape
    return m_np, b_np


# -- property tests ------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(0, 3000),
    density=st.sampled_from((0.0, 0.05, 0.3, 1.0)),
    seed=st.integers(0, 2**16),
    scheme=st.sampled_from(SCHEMES),
    dq=st.sampled_from(DTYPE_QUANT),
)
def test_encode_bit_identity_property(n, density, seed, scheme, dq):
    dtype_name, quant = dq
    a = _leaf(n, density, seed, dtype_name)
    _assert_identical(a, scheme, quant)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    density=st.sampled_from((0.05, 0.5)),
    seed=st.integers(0, 2**16),
    quant=st.sampled_from(("none", "fp16")),
)
def test_residual_conservation_property(n, density, seed, quant):
    """sent + residual == original update mass, via either backend: the
    residual is exactly f32(x) - f32(dequant(quant(x))) on the support."""
    a = _leaf(n, density, seed, "float32")
    (m, b, r_np), (_, _, r_pl) = _encode_both(a, "auto", quant)
    assert r_np.tobytes() == r_pl.tobytes()
    dec = codec.decode_leaf(m, b).astype(np.float32)
    np.testing.assert_array_equal(dec + r_np, a.astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    density=st.sampled_from((0.0, 0.1, 1.0)),
    seed=st.integers(0, 2**16),
    dtype_name=st.sampled_from(("float32", "int32")),
)
def test_decode_add_matches_reference(n, density, seed, dtype_name):
    """The fused decode/apply (scatter-into-target) == target + decode."""
    a = _leaf(n, density, seed, dtype_name)
    meta, parts, _ = codec.encode_leaf(a, scheme="bitmap", key="k")
    blob = b"".join(bytes(p) for p in parts)
    target = _leaf(n, 1.0, seed + 1, dtype_name)
    want = target + codec.decode_leaf(meta, blob)
    got = codec.decode_add_leaf(target.copy(), meta, blob, impl="pallas")
    assert got.tobytes() == want.tobytes()
    assert got.dtype == want.dtype


# -- edge shapes ---------------------------------------------------------------


EDGE_SHAPES = [
    ((), "scalar"),
    ((0,), "empty"),
    ((1,), "single"),
    ((129,), "one_past_sublane"),
    ((8, 128), "exact_tile"),
    ((8, 129), "one_past_tile"),
    ((1025,), "non_aligned_1d"),
    ((3, 5, 7), "odd_3d"),
]


@pytest.mark.parametrize("shape", [s for s, _ in EDGE_SHAPES],
                         ids=[i for _, i in EDGE_SHAPES])
@pytest.mark.parametrize("density", [0.0, 0.2, 1.0])
@pytest.mark.parametrize("scheme", ["auto", "bitmap"])
def test_edge_shapes(shape, density, scheme):
    n = int(np.prod(shape)) if shape else 1
    a = _leaf(n, density, 7, "float32", shape=shape)
    _assert_identical(a, scheme, "fp16")
    _assert_identical(a, scheme, "none")


def test_negative_zero_dense_bits_preserved():
    """Dense encoding must ship -0.0's sign bit exactly as numpy does
    (the fused path may not build dense values from the masked array)."""
    a = np.array([-0.0, 0.0, 1.5, -0.0], dtype=np.float32)
    for quant in ("none", "fp16"):
        _assert_identical(a, "dense", quant)


def test_large_leaf_over_auto_threshold():
    n = codec.PALLAS_AUTO_MIN_N + 17  # non-aligned, past the auto gate
    a = _leaf(n, 0.05, 3, "float32")
    _assert_identical(a, "auto", "fp16")


# -- impl resolution -----------------------------------------------------------


def test_resolve_impl_gates():
    f32, i64 = np.dtype(np.float32), np.dtype(np.int64)
    assert codec.resolve_impl("numpy", 1000, f32) == "numpy"
    # pallas falls back where bit-identity can't hold / nothing to do
    assert codec.resolve_impl("pallas", 0, f32) == "numpy"
    assert codec.resolve_impl("pallas", 1000, i64) == "numpy"
    assert codec.resolve_impl("pallas", 1000, f32) == "pallas"
    # auto is a perf policy: small leaves stay numpy; interpret-mode
    # kernels (no TPU on this host) stay numpy at every size
    assert codec.resolve_impl("auto", 100, f32) == "numpy"
    big = codec.PALLAS_AUTO_MIN_N + 1
    expect = "numpy" if codec._interpret() else "pallas"
    assert codec.resolve_impl("auto", big, f32) == expect
    with pytest.raises(ValueError):
        codec.resolve_impl("cuda", 10, f32)


def test_decode_add_unsupported_dtype_falls_back():
    """f16 targets must NOT take the fused in-place add (double rounding):
    decode_add_leaf falls back to the reference add for them."""
    a = _leaf(300, 0.2, 5, "float16")
    meta, parts, _ = codec.encode_leaf(a, scheme="bitmap", key="k")
    blob = b"".join(bytes(p) for p in parts)
    target = _leaf(300, 1.0, 6, "float16")
    want = target + codec.decode_leaf(meta, blob)
    got = codec.decode_add_leaf(target.copy(), meta, blob, impl="pallas")
    assert got.tobytes() == want.tobytes()


# -- kernel internals ----------------------------------------------------------


def test_wire_pack_mask_matches_packbits():
    from repro.kernels import wire_pack

    rng = np.random.RandomState(0)
    for n in (1, 7, 8, 9, 500, 1024, 1025):
        flat = rng.randn(n).astype(np.float32)
        flat[rng.rand(n) >= 0.3] = 0.0
        mask, _qdense, _cvals, _cidx, nnz, _res = wire_pack.wire_pack(
            flat, vdt=np.dtype(np.float32),
            block_rows=wire_pack.pick_block_rows(n), interpret=True,
        )
        want = np.packbits(flat != 0, bitorder="little")
        assert np.asarray(mask).tobytes() == want.tobytes()
        assert int(nnz) == int(np.count_nonzero(flat))


def test_wire_nnz_counts():
    import jax.numpy as jnp

    from repro.kernels import wire_pack

    rng = np.random.RandomState(1)
    for n in (1, 129, 4096):
        flat = rng.randn(n).astype(np.float32)
        flat[rng.rand(n) >= 0.4] = 0.0
        got = wire_pack.wire_nnz(jnp.asarray(flat), interpret=True)
        assert int(got) == int(np.count_nonzero(flat))


# -- accumulator integration ---------------------------------------------------


def test_leafbuffers_add_encoded_bit_identical():
    """sharding.LeafBuffers.add_encoded under the pallas backend must
    reproduce the reference decode-then-add accumulation bit for bit —
    this is the fixed f32 summation order the cross-topology digests
    rest on."""
    rng = np.random.RandomState(2)
    like = {"w": np.zeros(700, np.float32), "b": np.zeros(33, np.float32)}
    payloads = []
    for seed in range(4):
        tree = {
            k: _leaf(v.size, 0.3, 10 + seed, "float32")
            for k, v in like.items()
        }
        enc = {}
        for k, a in tree.items():
            meta, parts, _ = codec.encode_leaf(
                a, scheme="bitmap", quant="fp16", key=k
            )
            enc[k] = (meta, b"".join(bytes(p) for p in parts))
        payloads.append(enc)

    results = {}
    for impl in ("numpy", "pallas"):
        bufs = sharding.LeafBuffers(
            {k: (v.shape, v.dtype) for k, v in like.items()}
        )
        for enc in payloads:
            for k, (meta, blob) in enc.items():
                bufs.add_encoded(meta, blob, impl=impl)
        results[impl] = {k: bufs[k].tobytes() for k in like}
    assert results["numpy"] == results["pallas"]
    ref = np.zeros_like(like["w"])
    for enc in payloads:
        meta, blob = enc["w"]
        ref = ref + codec.decode_leaf(meta, blob)
    assert results["numpy"]["w"] == ref.tobytes()
