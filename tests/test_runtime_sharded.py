"""The sharded update store (repro.runtime.sharding + multi-broker runtime).

Three claims, matching the PR's acceptance criteria:

1. **Topology-invariance**: the SAME job converges bit-identically for
   ``n_brokers in {1, 2, 4}`` — sharding changes where bytes live, never
   what any worker computes (per-leaf summation order is fixed because
   each leaf is owned by exactly one shard).
2. **Per-shard accounting**: what each broker shard measures for published
   updates equals what the simulator-side accountant
   (``sharding.predict_shard_nbytes``, same ``leaf_nbytes`` formula)
   charges for the same updates — §10's invariant, sharded.
3. **Shard crash recovery**: SIGKILL of a broker shard mid-run →
   supervisor respawn at the pinned port, WAL replay, and ZERO replay
   mismatches pool-wide.

Plus hypothesis property tests for the leaf-key → shard partitioner
(total, deterministic/order-independent, balanced within the
list-scheduling bound) on random key sets and on the concrete PMF/LR
leaf sets.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import build_workload, protocol, run_job
from repro.runtime import sharding

from runtime_harness import (
    SMALL_P as P,
    SMALL_STEPS as STEPS,
    final_params,
    reference_updates,
    small_pmf_cfg,
)

SHARD_COUNTS = (1, 2, 4)


@pytest.fixture(scope="module")
def sharded_runs(tmp_path_factory):
    """One small PMF job per shard count, shared seed, retained updates."""
    runs = {}
    for nb in SHARD_COUNTS:
        tmp = tmp_path_factory.mktemp(f"faas_nb{nb}")
        cfg = small_pmf_cfg(tmp / "job", n_brokers=nb, retain_updates=True)
        runs[nb] = (cfg, run_job(cfg))
    return runs


# -- 1. bit-exact equivalence across shard counts -----------------------------


def test_final_params_bit_identical_across_shard_counts(sharded_runs):
    ref_cfg, ref_res = sharded_runs[1]
    assert ref_res["steps"] == STEPS
    for nb in SHARD_COUNTS[1:]:
        cfg, res = sharded_runs[nb]
        assert res["steps"] == STEPS and res["final_pool"] == P
        assert res["dup_mismatches"] == 0
        for w in range(P):
            s_ref, p_ref = final_params(ref_cfg, w)
            s_nb, p_nb = final_params(cfg, w)
            assert s_ref == s_nb == STEPS
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_nb)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"worker {w} final params diverged at "
                    f"n_brokers={nb}",
                )


def test_sharded_updates_bit_identical_to_core_isp_reference(sharded_runs):
    """The merged per-shard dump reassembles exactly the reference updates
    — slicing + WAL + re-merge loses nothing."""
    ref, final = reference_updates()
    for nb in SHARD_COUNTS[1:]:
        _cfg, res = sharded_runs[nb]
        pub = {(u["worker"], u["step"]): u["update"]
               for u in res["updates"]}
        assert len(pub) == P * STEPS
        for (w, t), sig in sorted(ref.items()):
            for a, b in zip(
                jax.tree.leaves(sig), jax.tree.leaves(pub[(w, t)])
            ):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"n_brokers={nb} worker {w} step {t}",
                )


def test_billed_topology_matches_shard_count(sharded_runs):
    for nb, (_cfg, res) in sharded_runs.items():
        assert res["n_brokers"] == nb
        assert res["bill"]["n_redis"] == nb
        # more shards -> strictly larger always-on infra bill at equal wall
        assert res["bill"]["infra_cost"] > 0
    # wire bytes are topology-invariant (same updates, same codec)
    totals = {nb: res["wire_bytes_total"]
              for nb, (_c, res) in sharded_runs.items()}
    assert len(set(totals.values())) == 1, totals


# -- 2. broker-measured == simulator-accounted, per shard ---------------------


def test_per_shard_bytes_measured_equals_accounted(sharded_runs):
    from runtime_harness import SMALL_PMF_WCFG

    wl = build_workload("pmf", dict(SMALL_PMF_WCFG))
    for nb, (_cfg, res) in sharded_runs.items():
        assignment = sharding.tree_assignment(wl.params0, nb)
        expect = [0] * nb
        for u in res["updates"]:
            per_shard = sharding.predict_shard_nbytes(
                u["update"], assignment, nb
            )
            for s in range(nb):
                expect[s] += per_shard[s]
        measured = res["broker_update_bytes_per_shard"]
        assert measured == expect, f"n_brokers={nb}"
        # and the per-shard split sums to the telemetry total
        assert sum(measured) == res["wire_bytes_total"]


# -- 3. broker-shard SIGKILL -> respawn + WAL replay --------------------------


def test_sigkill_broker_shard_respawns_with_zero_replay_mismatches(tmp_path):
    res = run_job(
        small_pmf_cfg(
            tmp_path / "job",
            n_brokers=2,
            total_steps=14,
            checkpoint_every=4,
            kill_broker_at_step=(1, 6),
            deadline_s=300.0,
        )
    )
    # the kill really happened on the broker, not a worker
    assert len(res["broker_respawns"]) >= 1
    ev = res["broker_respawns"][0]
    assert ev["shard"] == 1
    assert ev["exit_code"] == -9  # SIGKILL
    # the workers rode out the gap on RPC retries: the WAL replay restored
    # every acked publish, retried ones dup-checked bit-identical
    assert res["dup_mismatches"] == 0
    assert res["steps"] == 14
    assert res["final_pool"] == P
    assert res["invariant_max_err"] == 0.0
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]


def test_reused_run_dir_does_not_replay_previous_jobs_wal(tmp_path):
    """A fresh job in a reused run_dir must start its broker shards EMPTY
    (a previous job's WAL would pre-fill barriers with stale updates and
    pre-install old evictions) — while a respawn WITHIN the job still
    replays this job's WAL."""
    import os

    from repro.runtime.broker import WriteAheadLog
    from repro.runtime.supervisor import Supervisor

    cfg = small_pmf_cfg(tmp_path / "job", n_brokers=1)
    sup = Supervisor(cfg)
    os.makedirs(cfg.run_dir)
    bdir = os.path.join(cfg.run_dir, "broker")
    os.makedirs(bdir)
    # plant a "previous job's" WAL with a step-3 publish
    stale = WriteAheadLog(os.path.join(bdir, "shard00.wal"))
    stale.append({"t": "publish", "worker": 0, "step": 3, "meta": []}, b"")
    stale.close()
    try:
        sup._start_brokers()
        resp, _ = sup._rpc({"t": "poll", "since": 1})
        assert resp["max_published"] == 0  # stale WAL was discarded
        # this job's own mutations DO replay across a shard respawn
        sup._rpc({"t": "publish", "worker": 0, "step": 2, "meta": []})
        sup.shards[0].proc.kill()
        sup.shards[0].proc.wait(timeout=10)
        sup._reap_brokers()
        assert len(sup.broker_respawns) == 1
        resp, _ = sup._rpc({"t": "poll", "since": 1})
        assert resp["max_published"] == 2
    finally:
        for conn in sup._conns:
            if conn is not None:
                conn.close()
        for bs in sup.shards:
            if bs.proc is not None:
                bs.proc.kill()


# -- partitioner property tests -----------------------------------------------


_KEYS = st.lists(
    st.integers(min_value=0, max_value=10_000).map(lambda i: f"leaf/{i}"),
    min_size=1, max_size=64,
).map(lambda ks: sorted(set(ks)))


@settings(max_examples=60)
@given(
    keys=_KEYS,
    n_shards=st.integers(min_value=1, max_value=9),
    size_seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partitioner_total_and_in_range(keys, n_shards, size_seed):
    """Every key is owned by exactly one shard, in [0, n_shards)."""
    rng = np.random.RandomState(size_seed % 2**31)
    sizes = [int(rng.randint(1, 1 << 20)) for _ in keys]
    a = sharding.assign_shards(keys, sizes, n_shards)
    assert sorted(a) == list(keys)  # exactly the input keys, once each
    assert all(0 <= s < n_shards for s in a.values())


@settings(max_examples=60)
@given(
    keys=_KEYS,
    n_shards=st.integers(min_value=1, max_value=9),
    size_seed=st.integers(min_value=0, max_value=2**31 - 1),
    perm_seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partitioner_deterministic_and_order_independent(
    keys, n_shards, size_seed, perm_seed
):
    """The assignment is a pure function of the (key, size) multiset —
    independent of input order and of anything process-local (no salted
    ``hash``), so every worker and the supervisor agree, and a scale-in
    of the WORKER pool (which is not even an input) cannot move keys."""
    rng = np.random.RandomState(size_seed % 2**31)
    sizes = {k: int(rng.randint(1, 1 << 20)) for k in keys}
    a1 = sharding.assign_shards(keys, [sizes[k] for k in keys], n_shards)
    perm = list(keys)
    np.random.RandomState(perm_seed % 2**31).shuffle(perm)
    a2 = sharding.assign_shards(perm, [sizes[k] for k in perm], n_shards)
    assert a1 == a2
    # recomputation (a respawned worker's view) is identical too
    assert a1 == sharding.assign_shards(
        keys, [sizes[k] for k in keys], n_shards
    )


@settings(max_examples=60)
@given(
    keys=_KEYS,
    n_shards=st.integers(min_value=1, max_value=9),
    size_seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partitioner_balance_bound(keys, n_shards, size_seed):
    """Least-loaded greedy bound: max shard load <= total/n + max item."""
    rng = np.random.RandomState(size_seed % 2**31)
    sizes = [int(rng.randint(1, 1 << 20)) for _ in keys]
    a = sharding.assign_shards(keys, sizes, n_shards)
    load = [0] * n_shards
    for k, sz in zip(keys, sizes):
        load[a[k]] += sz
    assert max(load) <= sharding.shard_bytes_bound(sizes, n_shards) + 1e-9


@pytest.mark.parametrize("workload,wcfg", [
    ("pmf", {"n_users": 64, "n_movies": 80, "n_ratings": 1000, "rank": 4,
             "batch_size": 32}),
    ("lr", {"n_samples": 512, "batch_size": 64}),
])
def test_partitioner_on_real_leaf_sets(workload, wcfg):
    """The concrete PMF/LR parameter templates: total, balanced within
    bound at every practical shard count, and consistent with what the
    worker's encoder actually ships to each shard."""
    wl = build_workload(workload, wcfg)
    keys = protocol.tree_keys(wl.params0)
    leaves = jax.tree_util.tree_leaves(wl.params0)
    sizes = [int(np.asarray(x).size * np.asarray(x).dtype.itemsize)
             for x in leaves]
    for nb in (1, 2, 3, 4, 8):
        a = sharding.tree_assignment(wl.params0, nb)
        assert sorted(a) == sorted(keys)
        load = [0] * nb
        for k, sz in zip(keys, sizes):
            load[a[k]] += sz
        assert max(load) <= sharding.shard_bytes_bound(sizes, nb)
        # the two big PMF embedding matrices must not share a shard
        if workload == "pmf" and nb >= 2:
            assert len(set(a.values())) == 2
        # encoder slices agree with the assignment: every leaf's meta
        # lands on exactly the assigned shard
        per_shard, _ = sharding.encode_tree_sharded(wl.params0, a, nb)
        for s, (meta, _parts) in enumerate(per_shard):
            assert all(a[m["k"]] == s for m in meta)
        assert sum(len(meta) for meta, _ in per_shard) == len(keys)
