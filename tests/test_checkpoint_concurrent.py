"""checkpoint.store atomicity under concurrent writers and readers.

The FaaS runtime has several worker *processes* saving and restoring
snapshots concurrently (and a SIGKILL can land mid-save), so the store
promises: a tag is always one writer's complete output — never a torn mix —
and a reader racing a replace retries the brief not-found window instead of
observing partial state.
"""

from __future__ import annotations

import multiprocessing as mp
import os

import numpy as np
import pytest

from repro.checkpoint import store

pytestmark = pytest.mark.skipif(
    "fork" not in mp.get_all_start_methods(),
    reason="needs fork to share the imported test state cheaply",
)


def _tree(fill: float) -> dict:
    return {
        "params": np.full((32, 8), fill, np.float32),
        "opt": np.full((8,), fill * 2, np.float32),
    }


def _writer(directory: str, step: int, fill: float, n_saves: int) -> None:
    for _ in range(n_saves):
        store.save(directory, step, _tree(fill), extra={"fill": fill})


def _assert_untorn(directory: str, step: int, fills: tuple[float, ...]) -> None:
    """One restore must observe ONE writer's output end to end (all leaves
    from the same save — the npz-embedded manifest makes the read a single
    file open, so this holds even while a writer replaces the tag)."""
    got = store.restore(directory, step, _tree(0.0))
    fill = float(got["params"][0, 0])
    assert fill in fills
    np.testing.assert_array_equal(got["params"], _tree(fill)["params"])
    np.testing.assert_array_equal(got["opt"], _tree(fill)["opt"])


def test_two_processes_saving_the_same_tag_never_tear(tmp_path):
    d = str(tmp_path / "ck")
    ctx = mp.get_context("fork")
    procs = [
        ctx.Process(target=_writer, args=(d, 7, fill, 20))
        for fill in (1.0, 2.0)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(60)
        assert p.exitcode == 0
    _assert_untorn(d, 7, (1.0, 2.0))
    # quiescent: manifest.json agrees with the arrays (same winning writer)
    fill = float(store.restore(d, 7, _tree(0.0))["params"][0, 0])
    assert store.manifest_extra(d, 7)["fill"] == fill
    assert store.latest_step(d) == 7
    # no staging/aside litter survives a clean race
    leftovers = [x for x in os.listdir(d) if not x == "step_0000000007"]
    assert leftovers == []


def test_restore_while_writer_replaces(tmp_path):
    d = str(tmp_path / "ck")
    store.save(d, 3, _tree(1.0), extra={"fill": 1.0})
    ctx = mp.get_context("fork")
    w = ctx.Process(target=_writer, args=(d, 3, 2.0, 40))
    w.start()
    try:
        for _ in range(60):  # hammer restores during the replaces
            _assert_untorn(d, 3, (1.0, 2.0))
    finally:
        w.join(60)
    assert w.exitcode == 0
    _assert_untorn(d, 3, (2.0,))  # last writer wins once quiescent


def test_latest_step_ignores_staging_and_aside_dirs(tmp_path):
    d = str(tmp_path / "ck")
    store.save(d, 5, _tree(1.0))
    os.makedirs(os.path.join(d, "step_0000000009.tmp-123-abc"))
    os.makedirs(os.path.join(d, "step_0000000011.old-deadbeef"))
    assert store.latest_step(d) == 5


def test_crash_mid_save_leaves_no_visible_checkpoint(tmp_path):
    """A writer SIGKILL'd mid-save (simulated by a dangling staging dir)
    must not make the tag visible or restorable."""
    d = str(tmp_path / "ck")
    os.makedirs(os.path.join(d, "step_0000000004.tmp-999-dead"))
    with open(
        os.path.join(d, "step_0000000004.tmp-999-dead", "manifest.json"), "w"
    ) as f:
        f.write("{")  # torn json, as a crash would leave
    assert store.latest_step(d) is None


def test_replace_same_step_updates_content(tmp_path):
    # the runtime re-saves a tag after eviction transitions; replace must
    # be atomic AND take effect
    d = str(tmp_path / "ck")
    store.save(d, 2, _tree(1.0), extra={"fill": 1.0})
    store.save(d, 2, _tree(3.0), extra={"fill": 3.0})
    _assert_untorn(d, 2, (3.0,))
