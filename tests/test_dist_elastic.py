"""dist.elastic: transition sequences, batch contract, reintegration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import elastic


# -- plan + mesh schedule -----------------------------------------------------


def test_plan_validation():
    with pytest.raises(ValueError):
        elastic.ElasticPlan(initial_pods=0, per_pod_batch=4)
    with pytest.raises(ValueError):
        elastic.ElasticPlan(initial_pods=4, per_pod_batch=4, min_pods=5)
    plan = elastic.ElasticPlan(initial_pods=4, per_pod_batch=4, min_pods=2)
    with pytest.raises(ValueError):
        plan.global_batch(1)  # below min_pods
    with pytest.raises(ValueError):
        plan.global_batch(8)  # above initial


def test_monotone_shrink_8_to_1():
    """The full 8 -> 1 schedule: batch contract B_g = P*B at every step,
    mesh shapes match mesh_shape_for, pod axis dropped exactly at P=1."""
    plan = elastic.ElasticPlan(
        initial_pods=8, per_pod_batch=4, data=2, model=2
    )
    sizes = [8, 7, 6, 5, 4, 3, 2, 1]
    trs = elastic.transition_schedule(plan, sizes)
    assert len(trs) == 7
    for tr, (old, new) in zip(trs, zip(sizes[:-1], sizes[1:])):
        assert (tr.old_pods, tr.new_pods) == (old, new)
        assert tr.old_global_batch == old * 4
        assert tr.new_global_batch == new * 4
        assert tr.old_mesh_shape == elastic.mesh_shape_for(old, 2, 2)
        assert tr.new_mesh_shape == elastic.mesh_shape_for(new, 2, 2)
        assert tr.evicted == tuple(range(new, old))
    assert trs[-1].new_mesh_shape == (2, 2)  # pod axis gone at P=1
    assert elastic.mesh_axes_for(1) == ("data", "model")
    assert elastic.mesh_axes_for(2) == ("pod", "data", "model")


def test_transition_schedule_rejects_bad_start():
    plan = elastic.ElasticPlan(initial_pods=4, per_pod_batch=2)
    with pytest.raises(ValueError):
        elastic.transition_schedule(plan, [3, 2, 1])
    with pytest.raises(ValueError):
        elastic.plan_transition(plan, 2, 2)  # must strictly shrink
    with pytest.raises(ValueError):
        elastic.plan_transition(plan, 2, 3)  # never grows


def test_mesh_shape_for_matches_checkpoint_elastic_contract():
    assert elastic.mesh_shape_for(4, data=2, model=2) == (4, 2, 2)
    assert elastic.mesh_shape_for(2, data=2, model=2) == (2, 2, 2)
    assert elastic.mesh_shape_for(1, data=2, model=2) == (2, 2)
    with pytest.raises(ValueError):
        elastic.mesh_shape_for(0)


# -- reintegration ------------------------------------------------------------


def test_replica_reintegration_preserves_parameter_mean():
    """Mean-preserving model averaging: after the evicted replica is pulled
    into the survivors with weight 1/P, the pool-mean parameter vector is
    exactly unchanged."""
    P, evicted = 5, 3
    reps = {"w": jax.random.normal(jax.random.PRNGKey(0), (P, 6, 4)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (P, 9))}
    mask = jnp.asarray([True] * P).at[evicted].set(False)
    out = elastic.reintegrate_replicas(reps, evicted, mask)
    for k in reps:
        old_mean = np.asarray(jnp.mean(reps[k], axis=0))
        active = np.asarray(out[k])[np.asarray(mask)]
        np.testing.assert_allclose(active.mean(0), old_mean,
                                   rtol=1e-5, atol=1e-6)
        # the evicted slot is untouched (inert)
        np.testing.assert_array_equal(np.asarray(out[k][evicted]),
                                      np.asarray(reps[k][evicted]))


def test_apply_transition_conserves_update_mass():
    """Error-feedback reintegration: params' + surviving residual mass ==
    params + all residual mass; the evicted pods' unsent updates are
    flushed, not dropped."""
    plan = elastic.ElasticPlan(initial_pods=4, per_pod_batch=2)
    tr = elastic.plan_transition(plan, 4, 2)
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (5, 3))}
    opt = {"mu": jax.random.normal(jax.random.PRNGKey(3), (4, 5, 3))}
    res = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(4), (4, 5, 3))}
    p2, opt2, res2 = elastic.apply_transition(tr, params, opt, res)
    assert opt2["mu"].shape == (2, 5, 3)
    assert res2["w"].shape == (2, 5, 3)
    np.testing.assert_array_equal(np.asarray(res2["w"]),
                                  np.asarray(res["w"][:2]))
    total_before = np.asarray(params["w"]) + np.asarray(
        jnp.sum(res["w"], axis=0))
    total_after = np.asarray(p2["w"]) + np.asarray(
        jnp.sum(res2["w"], axis=0))
    np.testing.assert_allclose(total_after, total_before,
                               rtol=1e-5, atol=1e-6)


def test_shrink_pod_state_slices_every_leaf():
    tree = {"a": jnp.arange(12.0).reshape(4, 3),
            "n": {"b": jnp.arange(8.0).reshape(4, 2)}}
    out = elastic.shrink_pod_state(tree, 2)
    assert out["a"].shape == (2, 3) and out["n"]["b"].shape == (2, 2)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"][:2]))


# -- checkpoint-mediated re-mesh ---------------------------------------------


def test_resharded_restore_roundtrip(tmp_path):
    """Save under pool P, restore under pool 1's mesh (the only pool a
    1-device CPU host can build): values identical, sharding on the new
    mesh."""
    from repro.checkpoint import store as ckpt

    tree = {"w": jnp.arange(24.0).reshape(6, 4),
            "b": jnp.ones((3,), jnp.bfloat16)}
    ckpt.save(str(tmp_path), 9, tree, extra={"pool": 4})
    out = elastic.resharded_restore(str(tmp_path), 9, tree, pods=1)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert out["w"].sharding.mesh.axis_names == ("data", "model")


def test_make_mesh_for_single_pod_on_cpu():
    mesh = elastic.make_mesh_for(1, data=1, model=1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)
