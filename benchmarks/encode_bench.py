"""Encode/decode wire-codec microbenchmark — the impl-seam sweep (§15.5).

Times ``wire.codec.encode_leaf`` / ``decode_leaf`` over the cross product
leaf size x scheme x quantization x backend (numpy reference vs the fused
Pallas kernels of ``kernels/wire_pack.py``), on significance-split-shaped
inputs (~10% density f32).  Every cell first asserts the two backends
produce byte-identical encodings — a perf sweep over a broken codec would
be noise — then records p50/p95 wall microseconds per call.

Honest-numbers rule: the sweep records losers too.  On small leaves the
Pallas path pays fixed dispatch/(interpret-mode) overhead and LOSES to
numpy — that measured crossover is exactly what ``codec.resolve_impl``'s
``impl='auto'`` size threshold (PALLAS_AUTO_MIN_N) is calibrated against,
and the ``pallas_auto_min_n_sane`` flag in the payload checks the recorded
threshold still sits between a losing cell and a winning cell.

Results land in ``results/bench/encode_bench.json`` and are merged into
``BENCH_runtime.json`` under ``encode_sweep`` (existing sections from the
other live benchmarks are preserved).

    PYTHONPATH=src python -m benchmarks.run encode
"""

from __future__ import annotations

import json
import os
import statistics
import time

import numpy as np

from benchmarks.common import write_result

SIZES = (4096, 65_536, 1_048_576)
SCHEMES = ("dense", "sparse", "bitmap", "auto")
QUANTS = ("none", "fp16")
IMPLS = ("numpy", "pallas")
DENSITY = 0.1  # significance-split shaped: ~10% survivors


def _leaf(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    x[rng.rand(n) >= DENSITY] = 0.0
    return x


def _reps(n: int) -> int:
    # enough samples for a stable p95 on small leaves without letting the
    # 1M-element cells dominate the harness wall clock
    return int(max(7, min(40, 2_000_000 // max(n, 1))))


def _time_encode(a: np.ndarray, scheme: str, quant: str, impl: str,
                 reps: int) -> tuple[list[float], tuple, int]:
    from repro.wire import codec

    # one untimed warmup call absorbs jit compilation (pallas) and numpy
    # allocator warm-up alike
    meta, parts, _ = codec.encode_leaf(a, scheme=scheme, quant=quant,
                                       key="k", impl=impl)
    xs = []
    for _ in range(reps):
        t0 = time.perf_counter()
        meta, parts, _ = codec.encode_leaf(a, scheme=scheme, quant=quant,
                                           key="k", impl=impl)
        xs.append((time.perf_counter() - t0) * 1e6)
    blob = b"".join(bytes(p) for p in parts)
    return xs, (meta, blob), int(meta["nbytes"])


def _time_decode(meta: dict, blob: bytes, impl: str,
                 reps: int) -> tuple[list[float], np.ndarray]:
    from repro.wire import codec

    out = codec.decode_leaf(meta, blob, impl=impl)
    xs = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = codec.decode_leaf(meta, blob, impl=impl)
        xs.append((time.perf_counter() - t0) * 1e6)
    return xs, out


def _pctl(xs: list[float]) -> dict:
    xs = sorted(xs)
    return {
        "p50": statistics.median(xs),
        "p95": xs[min(len(xs) - 1, int(round(0.95 * (len(xs) - 1))))],
    }


def run() -> dict:
    from repro.wire import codec

    rows = []
    for n in SIZES:
        a = _leaf(n)
        reps = _reps(n)
        for scheme in SCHEMES:
            for quant in QUANTS:
                encoded = {}
                cell = {}
                for impl in IMPLS:
                    enc_us, (meta, blob), nbytes = _time_encode(
                        a, scheme, quant, impl, reps
                    )
                    dec_us, out = _time_decode(meta, blob, impl, reps)
                    encoded[impl] = (meta, blob, out)
                    cell[impl] = {
                        "encode_us": _pctl(enc_us),
                        "decode_us": _pctl(dec_us),
                        "nbytes": nbytes,
                        "resolved": codec.resolve_impl(
                            impl, n, a.dtype, quant
                        ),
                    }
                # the sweep's own bit-identity guard: same bytes on the
                # wire, same decoded leaf, same accounted size
                m_np, b_np, o_np = encoded["numpy"]
                m_pl, b_pl, o_pl = encoded["pallas"]
                assert b_np == b_pl, (n, scheme, quant, "blob mismatch")
                assert m_np["nbytes"] == m_pl["nbytes"]
                assert m_np["enc"] == m_pl["enc"]
                assert o_np.tobytes() == o_pl.tobytes()
                rows.append({
                    "n": n, "scheme": scheme, "quant": quant,
                    "reps": reps, **{
                        impl: cell[impl] for impl in IMPLS
                    },
                    "encode_p50_speedup_pallas": (
                        cell["numpy"]["encode_us"]["p50"]
                        / max(cell["pallas"]["encode_us"]["p50"], 1e-9)
                    ),
                })
    payload = {
        "density": DENSITY,
        "dtype": "float32",
        "pallas_auto_min_n": codec.PALLAS_AUTO_MIN_N,
        "interpret_mode": codec._interpret(),
        "rows": rows,
        "note": (
            "p50/p95 wall us per encode_leaf/decode_leaf call; pallas "
            "cells on this host run the kernels in interpret mode when no "
            "TPU is attached, so small-leaf cells losing to numpy is the "
            "measured, expected result the impl='auto' threshold encodes"
        ),
    }
    # sanity: the auto policy should not select pallas where this host's
    # sweep measured it losing — under interpret mode (no TPU) that means
    # auto must resolve to numpy at EVERY size; compiled, only below the
    # size threshold
    if codec._interpret():
        payload["pallas_auto_min_n_sane"] = all(
            codec.resolve_impl("auto", n, np.dtype(np.float32)) == "numpy"
            for n in SIZES
        )
    else:
        by_n: dict = {}
        for r in rows:
            by_n.setdefault(r["n"], []).append(
                r["encode_p50_speedup_pallas"]
            )
        payload["pallas_auto_min_n_sane"] = all(
            max(v) < 1.5 for n, v in by_n.items()
            if n < codec.PALLAS_AUTO_MIN_N
        )
    write_result("encode_bench", payload)
    _merge_into_bench_runtime(payload)
    return payload


def _merge_into_bench_runtime(payload: dict) -> None:
    """Merge the sweep into BENCH_runtime.json under ``encode_sweep``,
    preserving every other live benchmark's section."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    doc = {}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["encode_sweep"] = payload
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        name = f"encode_n{r['n']}_{r['scheme']}_{r['quant']}"
        np_p50 = r["numpy"]["encode_us"]["p50"]
        pl_p50 = r["pallas"]["encode_us"]["p50"]
        lines.append(
            f"encode,{name},{np_p50:.0f},"
            f"pallas_us={pl_p50:.0f};speedup="
            f"{r['encode_p50_speedup_pallas']:.2f}"
        )
    return lines
