"""Fig. 10: scalability — worker count x Redis shard count (PMF).

(a) normalized execution time for P in {24..96}-scaled-down worker pools
with 1 vs 2 Redis instances: sharding the exchange channel restores
scaling once a single instance saturates.
(b) steps-to-threshold vs P (statistical efficiency under fixed global
batch).
"""

from __future__ import annotations

from benchmarks.common import (
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    write_result,
)
from repro.core import consistency as cons

B_GLOBAL = 16_384
TARGET = 1.1
MAX_STEPS = 120


def run() -> dict:
    rows = []
    for P in (4, 8, 16, 24):
        b = max(B_GLOBAL // P, 64)
        for n_redis in (1, 2):
            sim = pmf_sim(P, model=cons.Model.ISP, n_redis=n_redis)
            res = sim.run(pmf_batch_fn(b), b, max_steps=MAX_STEPS,
                          loss_threshold=TARGET, eval_fn=pmf_eval_fn())
            r = summarize(f"P{P}_redis{n_redis}", res)
            r["P"] = P
            r["n_redis"] = n_redis
            rows.append(r)
    base = next(r for r in rows if r["P"] == 4 and r["n_redis"] == 1)
    for r in rows:
        r["normalized_time"] = (
            r["time_to_loss_s"] / base["time_to_loss_s"]
        )
    write_result("fig10_scalability", {"rows": rows})
    return {"rows": rows}


def report(out: dict) -> list[str]:
    return [
        f"fig10,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
        f"norm={r['normalized_time']:.3f},steps={r['steps']}"
        for r in out["rows"]
    ]
