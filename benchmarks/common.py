"""Shared setup for the paper-figure benchmarks.

Small-but-real instances of the paper's two workloads (PMF / LR) plus
simulator glue. Losses are genuine training traces; platform wall-clock and
cost come from the calibrated timing model (core/billing.py, paper Table 2).
Sizes are chosen so the full suite runs in minutes on 1 CPU while preserving
every qualitative effect the paper measures.
"""

from __future__ import annotations

import dataclasses
import json
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import consistency as cons
from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner
from repro.core.isp import ISPConfig
from repro.core.simulator import (
    Platform,
    ServerlessSimulator,
    SimulatorConfig,
    SimResult,
)
from repro.data import synthetic
from repro.models import lr, pmf

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def write_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


# ---- PMF workload (MovieLens-like) ---------------------------------------------

PMF_ML = synthetic.MovieLensLikeConfig(
    n_users=2000, n_movies=4000, n_ratings=200_000, rank=20, seed=0
)
_pmf_data = None


def pmf_workload():
    global _pmf_data
    if _pmf_data is None:
        users, movies, ratings = synthetic.make_movielens(PMF_ML)
        cfg = pmf.PMFConfig(n_users=PMF_ML.n_users, n_movies=PMF_ML.n_movies,
                            rank=PMF_ML.rank)
        params0 = pmf.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        eidx = rng.choice(len(ratings), 8192, replace=False)
        eval_batch = synthetic.ratings_batch(users, movies, ratings, eidx)
        _pmf_data = (users, movies, ratings, cfg, params0, eval_batch)
    return _pmf_data


def pmf_batch_fn(b_per_worker: int):
    users, movies, ratings, *_ = pmf_workload()

    def batch_fn(step: int, n_workers: int):
        r = np.random.default_rng(step)
        idx = r.integers(0, len(ratings), size=(n_workers, b_per_worker))
        return pmf.RatingsBatch(
            user=jnp.asarray(users[idx]),
            movie=jnp.asarray(movies[idx]),
            rating=jnp.asarray(ratings[idx]),
        )

    return batch_fn


def pmf_eval_fn():
    *_, eval_batch = pmf_workload()
    return lambda p: float(pmf.rmse(p, eval_batch))


def pmf_sim(
    P: int,
    platform: Platform = Platform.MLLESS,
    model: cons.Model = cons.Model.BSP,
    v: float = 0.7,
    slack: int = 3,
    n_redis: int = 1,
    lr_: float = 0.08,
    seed: int = 0,
) -> ServerlessSimulator:
    *_, cfg, params0, _ = pmf_workload()[3], pmf_workload()[3:5][0], None
    users, movies, ratings, cfg, params0, eval_batch = pmf_workload()
    return ServerlessSimulator(
        SimulatorConfig(
            n_workers=P,
            platform=platform,
            consistency=cons.ConsistencyConfig(
                model=model, isp=ISPConfig(v=v), slack=slack
            ),
            sparse_model=True,
            n_redis=n_redis,
            seed=seed,
        ),
        grad_fn=partial(pmf.grad_fn, cfg),
        optimizer=optim.make("nesterov", lr_),
        params=params0,
        flops_per_sample=6 * PMF_ML.rank * 3,
        update_nnz_fn=lambda bsz: 2 * PMF_ML.rank * min(bsz, PMF_ML.n_users),
    )


# ---- LR workloads (Criteo-like dense + sparse) -----------------------------------

LR_CFG = synthetic.CriteoLikeConfig(n_samples=120_000, hash_dim=20_000,
                                    seed=0)
_lr_dense = None
_lr_sparse = None


def lr_dense_workload():
    global _lr_dense
    if _lr_dense is None:
        x, y = synthetic.make_criteo_dense(LR_CFG)
        cfg = lr.LRConfig(n_features=LR_CFG.n_numerical, sparse=False)
        params0 = lr.init(cfg, jax.random.PRNGKey(0))
        _lr_dense = (x, y, cfg, params0)
    return _lr_dense


def lr_sparse_workload():
    global _lr_sparse
    if _lr_sparse is None:
        idx, val, y = synthetic.make_criteo_sparse(LR_CFG)
        cfg = lr.LRConfig(n_features=LR_CFG.hash_dim, sparse=True)
        params0 = lr.init(cfg, jax.random.PRNGKey(0))
        _lr_sparse = (idx, val, y, cfg, params0)
    return _lr_sparse


def lr_batch_fn(sparse: bool, b_per_worker: int):
    if sparse:
        idx, val, y, *_ = lr_sparse_workload()

        def batch_fn(step: int, n_workers: int):
            r = np.random.default_rng(1000 + step)
            sel = r.integers(0, len(y), size=(n_workers, b_per_worker))
            return lr.SparseBatch(
                idx=jnp.asarray(idx[sel]), val=jnp.asarray(val[sel]),
                y=jnp.asarray(y[sel]),
            )
    else:
        x, y, *_ = lr_dense_workload()

        def batch_fn(step: int, n_workers: int):
            r = np.random.default_rng(1000 + step)
            sel = r.integers(0, len(y), size=(n_workers, b_per_worker))
            return lr.DenseBatch(x=jnp.asarray(x[sel]), y=jnp.asarray(y[sel]))

    return batch_fn


def lr_sim(
    sparse: bool,
    P: int,
    platform: Platform = Platform.MLLESS,
    model: cons.Model = cons.Model.BSP,
    v: float = 0.7,
    n_redis: int = 1,
    lr_rate: float = 0.3,
    seed: int = 0,
) -> ServerlessSimulator:
    if sparse:
        idx, val, y, cfg, params0 = lr_sparse_workload()
        nnz_fn = lambda bsz: bsz * LR_CFG.n_numerical + bsz * LR_CFG.n_categorical
    else:
        x, y, cfg, params0 = lr_dense_workload()
        nnz_fn = None
    return ServerlessSimulator(
        SimulatorConfig(
            n_workers=P,
            platform=platform,
            consistency=cons.ConsistencyConfig(
                model=model, isp=ISPConfig(v=v)
            ),
            sparse_model=sparse,
            n_redis=n_redis,
            seed=seed,
        ),
        grad_fn=partial(lr.grad_fn, cfg),
        optimizer=optim.make("adam", lr_rate),
        params=params0,
        flops_per_sample=6.0 * (cfg.n_features if not sparse else 39),
        update_nnz_fn=nnz_fn,
    )


def tuner(P: int, interval: float = 2.0) -> ScaleInAutoTuner:
    return ScaleInAutoTuner(
        AutoTunerConfig(sched_interval_s=interval, delta_s=interval / 2,
                        min_points_for_fit=6),
        P,
    )


def summarize(name: str, res: SimResult) -> dict:
    t = res.converged_at_s or res.total_wall_s
    return {
        "name": name,
        "time_to_loss_s": t,
        "converged": res.converged_at_s is not None,
        "cost_usd": res.total_cost,
        "final_loss": res.final_loss,
        "perf_per_dollar": res.perf_per_dollar(),
        "final_workers": res.summary["final_workers"],
        "steps": len(res.records),
    }


def attach_speedups(rows: list, base_model: str = "bsp",
                    key: str = "speedup_vs_bsp") -> list:
    """Annotate per-P speedup vs the base model's time-to-loss, in place.

    ``summarize()`` falls back to ``total_wall_s`` for a cell that never
    reached the loss target, so a ratio against a non-converged baseline is
    an inflated "speedup" against a step-capped run, not a measurement.
    Speedup is reported only when BOTH cells converged; otherwise ``None``
    (the per-cell ``converged`` flag says which side failed).
    """
    base = {r["P"]: r for r in rows if r["model"] == base_model}
    for r in rows:
        b = base.get(r["P"])
        if b is None or not b["converged"] or not r["converged"]:
            r[key] = None
        else:
            r[key] = b["time_to_loss_s"] / max(r["time_to_loss_s"], 1e-9)
    return rows
