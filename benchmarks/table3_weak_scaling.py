"""Table 3: execution time at constant GLOBAL batch as workers vary
(LR sparse, Criteo-like). The paper shows ~equal time-to-loss for
(12, B=6250), (24, B=3125), (48, B=1562) — statistical efficiency is
preserved when B_g is held constant.
"""

from __future__ import annotations

from benchmarks.common import lr_batch_fn, lr_sim, summarize, write_result
from repro.core import consistency as cons

B_GLOBAL = 16_384
TARGET = 0.55
MAX_STEPS = 200


def run() -> dict:
    rows = []
    for P in (4, 8, 16):
        b = B_GLOBAL // P
        sim = lr_sim(True, P, model=cons.Model.BSP)
        res = sim.run(lr_batch_fn(True, b), b, max_steps=MAX_STEPS,
                      loss_threshold=TARGET)
        r = summarize(f"P{P}_B{b}", res)
        r["P"] = P
        r["B"] = b
        rows.append(r)
    times = [r["time_to_loss_s"] for r in rows]
    spread = (max(times) - min(times)) / max(min(times), 1e-9)
    write_result("table3_weak_scaling", {"rows": rows, "spread": spread})
    return {"rows": rows, "spread": spread}


def report(out: dict) -> list[str]:
    lines = [
        f"table3,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
        f"steps={r['steps']}"
        for r in out["rows"]
    ]
    lines.append(f"table3,time_spread,{out['spread']*1e6:.0f},"
                 f"rel_spread={out['spread']:.2f}")
    return lines
