"""Fig. 9: SSP vs ISP vs BSP at increasing worker counts, fixed global
batch (PMF). The paper's finding: ISP beats SSP at every P — staleness
without byte savings cannot beat filtered exchange when communication
dominates.
"""

from __future__ import annotations

from benchmarks.common import (
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    write_result,
)
from repro.core import consistency as cons

B_GLOBAL = 16_384
TARGET = 1.05
MAX_STEPS = 150


def run() -> dict:
    rows = []
    for P in (4, 8, 16):
        b = B_GLOBAL // P
        for model in (cons.Model.BSP, cons.Model.SSP, cons.Model.ISP):
            sim = pmf_sim(P, model=model, slack=3)
            res = sim.run(pmf_batch_fn(b), b, max_steps=MAX_STEPS,
                          loss_threshold=TARGET, eval_fn=pmf_eval_fn())
            r = summarize(f"P{P}_{model.value}", res)
            r["P"] = P
            r["model"] = model.value
            rows.append(r)
    # speedups vs BSP at the same P
    base = {r["P"]: r["time_to_loss_s"] for r in rows
            if r["model"] == "bsp"}
    for r in rows:
        r["speedup_vs_bsp"] = base[r["P"]] / max(r["time_to_loss_s"], 1e-9)
    write_result("fig9_ssp_vs_isp", {"rows": rows})
    return {"rows": rows}


def report(out: dict) -> list[str]:
    return [
        f"fig9,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
        f"speedup_vs_bsp={r['speedup_vs_bsp']:.2f}x"
        for r in out["rows"]
    ]
