"""Fig. 9: SSP vs ISP vs BSP at increasing worker counts, fixed global
batch (PMF). The paper's finding: ISP beats SSP at every P — staleness
without byte savings cannot beat filtered exchange when communication
dominates.

``run(live=True)`` additionally runs the LIVE bounded-staleness runtime
(DESIGN.md §13) head-to-head against the default ISP barrier under an
injected intermittent straggler, and merges the ``ssp_sweep`` payload into
``BENCH_runtime.json`` at the repo root: where SSP earns its keep is the
non-straggler workers' step-time tail — with slack they keep stepping
through a peer's hiccup instead of parking at the barrier — while the
default ISP path stays bit-identical (``benchmarks/wire_guard.py`` holds
that bar against ``wire_baseline.json``).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (
    attach_speedups,
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    write_result,
)
from repro.core import consistency as cons

B_GLOBAL = 16_384
TARGET = 1.05
MAX_STEPS = 150

# -- live straggler duel configuration -----------------------------------------
# Small deterministic PMF job (auto-tuner off, one invocation round) so the
# only asymmetry between the ISP and SSP cells is the barrier model.  The
# straggler must hiccup RARELY, not persistently: the slack lead is a
# fixed budget of `slack` steps, so a delay every few steps rate-limits
# the followers exactly like ISP does once the lead is spent (the gates
# advance at the straggler's average pace — same tail, just shifted).
# With a hiccup every 12 steps the arithmetic splits the two cells:
# under ISP every worker parks the full delay at each hit step (>= 5% of
# non-straggler samples inflated -> the p95 catches them), under SSP the
# followers only pay `delay - slack*step_time` once per hit, a burst that
# stays below the p95 cut.
LIVE_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
LIVE_P = 3
LIVE_STEPS = 24
LIVE_SLACK = 3
STRAGGLER = {"worker": 0, "delay_s": 0.5, "every": 12}


def _nonstraggler_p95(history: list) -> float:
    """p95 over the NON-straggler workers' per-step durations — the
    straggler's own steps carry the injected sleep in both cells and would
    drown the signal (the row-level ``dur_s`` is the pool max, i.e. the
    straggler, in every row where it sleeps).  Step 1 is excluded like
    fig6's ``_steady``: its ~seconds-scale XLA compile would own the p95
    of BOTH cells and hide the barrier behaviour being measured."""
    durs = [
        d
        for row in history
        if row["step"] > 1
        for w, d in (row.get("dur_s_by_worker") or {}).items()
        if int(w) != STRAGGLER["worker"]
    ]
    return float(np.percentile(durs, 95)) if durs else float("nan")


def _run_live_cell(consistency: str) -> dict:
    import tempfile

    from repro.runtime import FaaSJobConfig, final_params_digest, run_job

    job = FaaSJobConfig(
        run_dir=tempfile.mkdtemp(prefix=f"bench_ssp_{consistency}_"),
        workload="pmf",
        workload_cfg=dict(LIVE_WCFG),
        n_workers=LIVE_P,
        total_steps=LIVE_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.5,
        autotune=False,
        consistency=consistency,
        slack=LIVE_SLACK,
        straggler=dict(STRAGGLER),
        deadline_s=480.0,
    )
    live = run_job(job)
    hist = live["history"]
    return {
        "consistency": consistency,
        "slack": LIVE_SLACK if consistency == "ssp" else None,
        "steps": live["steps"],
        "wall_s": live["wall_s"],
        "measured_step_s_mean": live["measured_step_s"],
        "nonstraggler_step_s_p95": _nonstraggler_p95(hist),
        "final_loss": live["final_loss"],
        "wire_bytes_total": live["wire_bytes_total"],
        "dup_mismatches": live["dup_mismatches"],
        "faas_cost_usd": live["bill"]["total"],
        "final_params_sha256": final_params_digest(job),
    }


def _run_ssp_sweep() -> dict:
    rows = [_run_live_cell("isp"), _run_live_cell("ssp")]
    by = {r["consistency"]: r for r in rows}
    return {
        "workload": dict(LIVE_WCFG),
        "n_workers": LIVE_P,
        "steps": LIVE_STEPS,
        "slack": LIVE_SLACK,
        "straggler": dict(STRAGGLER),
        "rows": rows,
        # the headline: slack absorbs the straggler's hiccups for everyone
        # else, so SSP's non-straggler tail must beat ISP's
        "ssp_tail_beats_isp": (
            by["ssp"]["nonstraggler_step_s_p95"]
            < by["isp"]["nonstraggler_step_s_p95"]
        ),
        "nonstraggler_p95_ssp_over_isp": (
            by["ssp"]["nonstraggler_step_s_p95"]
            / max(by["isp"]["nonstraggler_step_s_p95"], 1e-12)
        ),
    }


def _merge_into_bench_runtime(sweep: dict) -> None:
    """BENCH_runtime.json is shared with fig6's live calibration payload:
    load-merge-write so whichever benchmark ran last keeps the other's
    keys."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["ssp_sweep"] = sweep
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run(live: bool = False) -> dict:
    rows = []
    for P in (4, 8, 16):
        b = B_GLOBAL // P
        for model in (cons.Model.BSP, cons.Model.SSP, cons.Model.ISP):
            sim = pmf_sim(P, model=model, slack=3)
            res = sim.run(pmf_batch_fn(b), b, max_steps=MAX_STEPS,
                          loss_threshold=TARGET, eval_fn=pmf_eval_fn())
            r = summarize(f"P{P}_{model.value}", res)
            r["P"] = P
            r["model"] = model.value
            rows.append(r)
    attach_speedups(rows)
    out = {"rows": rows}
    if live:
        sweep = _run_ssp_sweep()
        out["ssp_sweep"] = sweep
        _merge_into_bench_runtime(sweep)
    write_result("fig9_ssp_vs_isp", out)
    return out


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        sp = r["speedup_vs_bsp"]
        sp_txt = f"{sp:.2f}x" if sp is not None else "n/a(not converged)"
        lines.append(
            f"fig9,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
            f"speedup_vs_bsp={sp_txt}"
        )
    sweep = out.get("ssp_sweep")
    if sweep:
        for r in sweep["rows"]:
            lines.append(
                f"fig9,live_{r['consistency']},"
                f"{r['nonstraggler_step_s_p95']*1e6:.0f},"
                f"nonstraggler_p95={r['nonstraggler_step_s_p95']*1e3:.1f}ms,"
                f"step_mean={r['measured_step_s_mean']*1e3:.0f}ms,"
                f"dup={r['dup_mismatches']}"
            )
        lines.append(
            f"fig9,ssp_tail_over_isp,"
            f"{sweep['nonstraggler_p95_ssp_over_isp']*1e6:.0f},"
            f"ssp/isp={sweep['nonstraggler_p95_ssp_over_isp']:.2f}x,"
            f"beats={sweep['ssp_tail_beats_isp']}"
        )
    return lines
