"""Fig. 7: loss-vs-time traces for PyTorch-like (serverful), PyWren-like and
MLLess variants (BSP / +ISP / +All), PMF workload.

The paper's headline: MLLess converges ~15x faster than serverful for
fast-convergent sparse models. The simulator reproduces the mechanism: the
serverful platform pays dense ring-all-reduce per step at IaaS speeds while
MLLess pays sparse Redis exchange, and ISP shrinks those bytes further.
"""

from __future__ import annotations

from benchmarks.common import (
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    tuner,
    write_result,
)
from repro.core import consistency as cons
from repro.core.simulator import Platform

P = 8
B = 2048
TARGET = 1.05
MAX_STEPS = 150


def run() -> dict:
    systems = {
        "pytorch_like": dict(platform=Platform.SERVERFUL,
                             model=cons.Model.BSP, tuned=False),
        "pywren_like": dict(platform=Platform.PYWREN, model=cons.Model.BSP,
                            tuned=False),
        "mlless_bsp": dict(platform=Platform.MLLESS, model=cons.Model.BSP,
                           tuned=False),
        "mlless_isp": dict(platform=Platform.MLLESS, model=cons.Model.ISP,
                           tuned=False),
        "mlless_all": dict(platform=Platform.MLLESS, model=cons.Model.ISP,
                           tuned=True),
    }
    rows, traces = [], {}
    for name, s in systems.items():
        sim = pmf_sim(P, platform=s["platform"], model=s["model"])
        res = sim.run(
            pmf_batch_fn(B), B, max_steps=MAX_STEPS, loss_threshold=TARGET,
            eval_fn=pmf_eval_fn(), tuner=tuner(P) if s["tuned"] else None,
        )
        rows.append(summarize(name, res))
        t = 0.0
        trace = []
        for rec in res.records:
            t += rec.wall_s
            trace.append({"t": t, "loss": rec.loss,
                          "workers": rec.active_workers})
        traces[name] = trace
    base = next(r for r in rows if r["name"] == "pytorch_like")
    for r in rows:
        r["speedup_vs_pytorch"] = (
            base["time_to_loss_s"] / max(r["time_to_loss_s"], 1e-9)
        )
    write_result("fig7_loss_vs_time", {"rows": rows, "traces": traces})
    return {"rows": rows, "traces": traces}


def report(out: dict) -> list[str]:
    return [
        f"fig7,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
        f"speedup={r['speedup_vs_pytorch']:.2f}x,loss={r['final_loss']:.3f}"
        for r in out["rows"]
    ]
