"""Fig. 12 (extension): live topology co-tuning — brokers x transport.

Default mode is model-only: rank the candidate topology cells with the
same ``CommModel.indirect_exchange_time`` term the simulator prices (the
paper's scalability argument: exchange strain scales with P*bytes/shards,
so more update-store shards buy exchange time — at the price of one more
always-on VM in the bill).

``run(live=True)`` runs the REAL multi-process runtime with the online
``TopologyTuner`` (DESIGN.md §16): explore-then-commit over {current,
flip n_brokers, flip transport}, each explore step a WAL-coordinated live
re-shard at an epoch fence, and merges the measured per-cell phase
p50/p95 plus the chosen cell into ``BENCH_runtime.json`` at the repo
root.  Honest-host note: on a 2-CPU container a second broker process
COSTS step time (the model's shard win assumes real parallel stores), so
the tuner committing back to 1 broker is the correct live answer there.
"""

from __future__ import annotations

import json
import os
import tempfile

from benchmarks.common import write_result
from repro.core.billing import CommModel, faas_cost

# the model sweep prices the paper-scale exchange: P workers shipping
# ~sent_fraction-filtered PMF updates each step
MODEL_P = 8
MODEL_BYTES_PER_STEP = 2.0e6
MODEL_CELLS = [
    {"n_brokers": b, "transport": t}
    for b in (1, 2, 3, 4)
    for t in ("tcp", "shm")
]

# the live duel reuses the canonical small PMF instance (tests sized it);
# a light per-step pacing delay keeps the supervisor's 50 ms control loop
# ahead of the workers so every explore fence lands mid-job
LIVE_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
LIVE_P = 3
LIVE_STEPS = 42
LIVE_EXPLORE = 3
LIVE_PACING = {"worker": 0, "delay_s": 0.06, "every": 1}


def _model_rows() -> list[dict]:
    comm = CommModel()
    rows = []
    for cell in MODEL_CELLS:
        ex = comm.indirect_exchange_time(
            MODEL_BYTES_PER_STEP, MODEL_P, n_redis=cell["n_brokers"]
        )
        # the bill prices the extra always-on store VMs the shards need
        bill = faas_cost([MODEL_P * 60.0], 60.0, n_redis=cell["n_brokers"])
        rows.append({
            "cell": dict(cell),
            "model_exchange_s": float(ex),
            "cost_usd_per_min": float(bill.total),
        })
    rows.sort(key=lambda r: (r["model_exchange_s"], r["cost_usd_per_min"]))
    return rows


def _run_live() -> dict:
    from repro.runtime import FaaSJobConfig, run_job

    run_dir = tempfile.mkdtemp(prefix="fig12_topo_")
    cfg = FaaSJobConfig(
        run_dir=run_dir,
        workload="pmf",
        workload_cfg=dict(LIVE_WCFG),
        n_workers=LIVE_P,
        total_steps=LIVE_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.5,
        n_brokers=1,
        transport="tcp",
        topology_tune=True,
        topo_explore_steps=LIVE_EXPLORE,
        partitioner="ring",
        shard_split_bytes=1024,
        straggler=dict(LIVE_PACING),
        deadline_s=300.0,
    )
    res = run_job(cfg)
    tuner = res["topology_tuner"] or {}
    return {
        "workload": dict(LIVE_WCFG),
        "n_workers": LIVE_P,
        "steps": LIVE_STEPS,
        "explore_steps": LIVE_EXPLORE,
        "pacing": dict(LIVE_PACING),
        "start_cell": {"n_brokers": 1, "transport": "tcp"},
        "cells": tuner.get("cells", []),
        "chosen": tuner.get("chosen"),
        "chosen_cell": tuner.get("chosen_cell"),
        "committed": tuner.get("committed"),
        "abandoned": tuner.get("abandoned"),
        "topology_events": res["topology_events"],
        "final_topology": res["topology"],
        "dup_mismatches": res["dup_mismatches"],
        "faas_cost_usd": res["bill"]["total"],
        "n_redis_billed": res["bill"]["n_redis"],
    }


def _merge_into_bench_runtime(live: dict) -> None:
    """Load-merge-write the shared BENCH_runtime.json (fig6/fig9/fig11
    co-own it; whichever ran last keeps the others' keys)."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["fig12_topology"] = live
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run(live: bool = False) -> dict:
    rows = _model_rows()
    out = {
        "model_rows": rows,
        # the scalability claim the model encodes: exchange time strictly
        # improves with shards at fixed bytes (paper Fig. 12 shape)
        "model_prefers_more_shards": (
            rows[0]["cell"]["n_brokers"]
            == max(c["n_brokers"] for c in MODEL_CELLS)
        ),
    }
    if live:
        lv = _run_live()
        out["live"] = lv
        _merge_into_bench_runtime(lv)
    write_result("fig12_topology", out)
    return out


def report(out: dict) -> list[str]:
    lines = []
    best = out["model_rows"][0]
    lines.append(
        f"fig12,model_best,{best['model_exchange_s']*1e6:.0f},"
        f"cell=b{best['cell']['n_brokers']}_{best['cell']['transport']},"
        f"prefers_more_shards={out['model_prefers_more_shards']}"
    )
    lv = out.get("live")
    if lv:
        for c in lv["cells"]:
            p50 = c.get("p50")
            lines.append(
                f"fig12,live_b{c['cell'].get('n_brokers')}_"
                f"{c['cell'].get('transport')},"
                f"{(p50 or 0.0)*1e6:.0f},"
                f"n={c.get('n_steps')},"
                f"p95={(c.get('p95') or 0.0)*1e3:.1f}ms"
            )
        chosen = lv.get("chosen_cell") or {}
        lines.append(
            f"fig12,live_chosen,{0 if lv['chosen'] is None else lv['chosen']}"
            f",cell=b{chosen.get('n_brokers')}_{chosen.get('transport')},"
            f"committed={lv['committed']},reshards="
            f"{len([e for e in lv['topology_events'] if 'refused' not in e])}"
            f",dup={lv['dup_mismatches']}"
        )
    return lines
