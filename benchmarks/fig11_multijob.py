"""Fig. 11 (extension): multi-job bin-packing on one serverless pool.

MLLess bills every live function at the 100 ms quantum, so a worker
parked at a barrier is pure cost.  The fleet scheduler (DESIGN.md §14)
admits N jobs onto ONE broker/worker pool: job B's steps run inside job
A's barrier stalls in the SAME invocation processes, the shared VMs are
billed once on one wall clock, and ``core.billing.multi_job_rollup``
attributes the pooled bill by measured busy seconds.

``run()`` is the modelled form (pure billing arithmetic: how much of the
solo-sum an ideally packed pool shaves).  ``run(live=True)`` measures it:
solo PMF + solo LR on the real multi-process runtime, then the same two
jobs packed, asserting each job's final params stay BIT-identical to its
solo run, and merges the ``multijob_sweep`` payload (solo-sum vs packed
cost, per-job step p50/p95 interference, pre-warm overlap) into
``BENCH_runtime.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from benchmarks.common import write_result
from repro.core import billing

# -- live cells ----------------------------------------------------------------
# Small deterministic jobs (auto-tuner off) sized so the packed run still
# finishes in benchmark time; the PMF job is the long tenant, the LR job
# the short one that rides inside its barrier stalls.
PMF_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
PMF_P, PMF_STEPS = 3, 16
LR_WCFG = {"n_samples": 4000, "batch_size": 128}
LR_P, LR_STEPS = 2, 10


def _pmf_cfg(run_dir, **overrides):
    from repro.runtime import FaaSJobConfig

    base = dict(
        run_dir=run_dir,
        workload="pmf",
        workload_cfg=dict(PMF_WCFG),
        n_workers=PMF_P,
        total_steps=PMF_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.5,
        deadline_s=480.0,
    )
    base.update(overrides)
    return FaaSJobConfig(**base)


def _lr_cfg(run_dir, **overrides):
    from repro.runtime import FaaSJobConfig

    base = dict(
        run_dir=run_dir,
        workload="lr",
        workload_cfg=dict(LR_WCFG),
        n_workers=LR_P,
        total_steps=LR_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.05,
        isp_v=0.5,
        deadline_s=480.0,
    )
    base.update(overrides)
    return FaaSJobConfig(**base)


def _step_tail(history: list) -> dict:
    """p50/p95 of per-step durations, step 1 (XLA compile) excluded."""
    durs = [r["dur_s"] for r in history if r["step"] > 1 and r.get("dur_s")]
    if not durs:
        return {"p50": None, "p95": None}
    return {
        "p50": float(np.percentile(durs, 50)),
        "p95": float(np.percentile(durs, 95)),
    }


def _run_live_sweep() -> dict:
    from repro.runtime import (
        FleetConfig,
        final_params_digest,
        run_fleet,
        run_job,
    )

    root = tempfile.mkdtemp(prefix="bench_multijob_")

    # solo baselines — each pays its own pool AND its own infra wall
    solo = {}
    cfg_a = _pmf_cfg(os.path.join(root, "solo_a"))
    cfg_b = _lr_cfg(os.path.join(root, "solo_b"))
    for jid, cfg in (("a", cfg_a), ("b", cfg_b)):
        res = run_job(cfg)
        solo[jid] = {
            "workload": cfg.workload,
            "n_workers": cfg.n_workers,
            "steps": res["steps"],
            "wall_s": res["wall_s"],
            "cost_usd": res["bill"]["total"],
            "step_s": _step_tail(res["history"]),
            "dup_mismatches": res["dup_mismatches"],
            "final_params_sha256": final_params_digest(cfg),
        }

    # the same two jobs packed on ONE pool
    fleet_dir = os.path.join(root, "fleet")
    packed = run_fleet(FleetConfig(
        run_dir=fleet_dir,
        jobs={
            "a": _pmf_cfg(os.path.join(fleet_dir, "jobs", "a")),
            "b": _lr_cfg(os.path.join(fleet_dir, "jobs", "b")),
        },
    ))
    packed_jobs = {}
    for jid, mk in (("a", _pmf_cfg), ("b", _lr_cfg)):
        job = packed["jobs"][jid]
        digest = final_params_digest(mk(job["run_dir"]))
        identical = digest == solo[jid]["final_params_sha256"]
        assert identical, (
            f"job {jid}: packed params diverged from solo — the fleet is "
            "NOT observationally invisible"
        )
        pt, st = _step_tail(job["history"]), solo[jid]["step_s"]
        packed_jobs[jid] = {
            "steps": job["steps"],
            "busy_s": job["busy_s"],
            "attributed_cost_usd": packed["rollup"]["per_job"][jid]["total"],
            "step_s": pt,
            # interference: how much the co-tenant stretches this job's
            # step tail (packed / solo, > 1 means slower packed)
            "interference_p50": (
                pt["p50"] / st["p50"] if pt["p50"] and st["p50"] else None
            ),
            "interference_p95": (
                pt["p95"] / st["p95"] if pt["p95"] and st["p95"] else None
            ),
            "bit_identical_to_solo": identical,
        }

    solo_sum = sum(s["cost_usd"] for s in solo.values())
    packed_cost = packed["bill"]["total"]

    # pre-warm overlap cell (solo supervisor, DESIGN.md §14.5): the same
    # PMF job split into invocations, its respawn cold start pre-warmed
    warm_cfg = _pmf_cfg(
        os.path.join(root, "prewarm"),
        invocation_steps=PMF_STEPS // 2, checkpoint_every=4, prewarm=True,
    )
    warm = run_job(warm_cfg)
    overlaps = [o["overlap_s"] for o in warm["cold_start_overlaps"]]
    prewarm_cell = {
        "invocations": warm["n_invocations"],
        "n_overlapped": len(overlaps),
        "overlap_s_mean": float(np.mean(overlaps)) if overlaps else None,
        "bit_identical_to_solo": (
            final_params_digest(warm_cfg)
            == solo["a"]["final_params_sha256"]
        ),
    }

    return {
        "solo": solo,
        "packed": {
            "wall_s": packed["wall_s"],
            "n_invocations": packed["n_invocations"],
            "cost_usd": packed_cost,
            "jobs": packed_jobs,
            "dup_mismatches": packed["dup_mismatches"],
        },
        "solo_sum_cost_usd": solo_sum,
        "packed_cost_usd": packed_cost,
        "packed_over_solo_sum": packed_cost / max(solo_sum, 1e-12),
        # the headline: two bin-packed jobs cost less than the same two
        # jobs run solo (shared infra wall + absorbed barrier stalls)
        "packed_cheaper": packed_cost < solo_sum,
        "prewarm": prewarm_cell,
    }


def _merge_into_bench_runtime(sweep: dict) -> None:
    """BENCH_runtime.json is shared with fig6/fig9's live payloads:
    load-merge-write so whichever benchmark ran last keeps the rest."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["multijob_sweep"] = sweep
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def _modelled_packing() -> dict:
    """Billing arithmetic only: two jobs whose barrier-idle fractions are
    taken from the live phase telemetry's typical shape (PMF small jobs
    park 30-50% of a step at the pull barrier).  Solo, each job bills its
    workers for its whole wall plus its own VMs; packed, the pool runs
    job B inside job A's stalls, bills max(wall) once and splits it by
    busy seconds."""
    wall_a, p_a, idle_a = 10.0, 3, 0.4
    wall_b, p_b = 6.0, 2
    solo_a = billing.faas_cost([wall_a] * p_a, wall_a, n_redis=1).total
    solo_b = billing.faas_cost([wall_b] * p_b, wall_b, n_redis=1).total
    # ideal pack: B's compute fits inside A's idle worker-seconds
    fits = wall_b * p_b * (1 - 0.0) <= wall_a * p_a * idle_a
    packed_wall = wall_a if fits else wall_a + wall_b * 0.5
    packed = billing.faas_cost(
        [packed_wall] * p_a, packed_wall, n_redis=1
    )
    rollup = billing.multi_job_rollup(
        [packed_wall] * p_a, packed_wall, 1,
        {"a": wall_a * p_a * (1 - idle_a), "b": wall_b * p_b},
    )
    return {
        "solo_sum_usd": solo_a + solo_b,
        "packed_usd": packed.total,
        "packed_over_solo_sum": packed.total / (solo_a + solo_b),
        "b_fits_in_a_stalls": fits,
        "per_job_shares": {
            j: r["share"] for j, r in rollup["per_job"].items()
        },
    }


def run(live: bool = False) -> dict:
    out = {"model": _modelled_packing()}
    if live:
        sweep = _run_live_sweep()
        out["multijob_sweep"] = sweep
        _merge_into_bench_runtime(sweep)
    write_result("fig11_multijob", out)
    return out


def report(out: dict) -> list[str]:
    m = out["model"]
    lines = [
        f"fig11,modelled_pack,{m['packed_usd']*1e6:.0f},"
        f"packed/solo_sum={m['packed_over_solo_sum']:.2f}x"
    ]
    sweep = out.get("multijob_sweep")
    if sweep:
        for jid, j in sweep["packed"]["jobs"].items():
            lines.append(
                f"fig11,live_job_{jid},{j['step_s']['p50']*1e6:.0f},"
                f"interf_p50={j['interference_p50']:.2f}x,"
                f"interf_p95={j['interference_p95']:.2f}x,"
                f"bit_identical={j['bit_identical_to_solo']}"
            )
        lines.append(
            f"fig11,live_pack,{sweep['packed_cost_usd']*1e6:.2f},"
            f"packed/solo_sum={sweep['packed_over_solo_sum']:.2f}x,"
            f"cheaper={sweep['packed_cheaper']},"
            f"prewarm_overlap_s="
            f"{sweep['prewarm']['overlap_s_mean']}"
        )
    return lines
