"""Fig. 6: scale-in auto-tuner effect on Perf/$ and execution time.

Runs each job with and without the auto-tuner (ISP on) and reports the
Perf/$ ratio — the paper measures 1.1x-1.6x improvements depending on the
workload.

``run(live=True)`` additionally runs the SAME PMF job on the real
multi-process FaaS runtime (``repro.runtime``) and on the simulator with a
matching configuration, and emits ``BENCH_runtime.json`` at the repo root
comparing simulator-predicted vs measured step durations and FaaS cost —
the calibration check of the timing model (DESIGN.md §8 vs §9).
"""

from __future__ import annotations

import json
import os

from benchmarks.common import (
    lr_batch_fn,
    lr_sim,
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    tuner,
    write_result,
)
from repro.core import consistency as cons

P = 8
B = 2048

# -- live-vs-simulated configuration ------------------------------------------
# the live job IS the quickstart job (examples/mlless_faas.py) — one shared
# config in repro.runtime, so the benchmark always calibrates against the
# job the example runs
LIVE_P = 4
LIVE_STEPS = 140
# solo-measured runtime-init constant of the local substrate (python +
# jax import + restore, uncontended) — the FaaS cold start each invocation
# bills and each invocation round stalls the pool for.  A modelling
# constant like CommModel's RTTs, NOT fit to the live run.
COLD_START_S = 2.0
# the shard sweep: a store-bound PMF job (big dense updates, the regime
# "Towards Demystifying Serverless ML Training" identifies as the
# indirect-communication bottleneck), live, at each update-store shard
# count — the wire phase (publish + pipelined barrier pulls) is the cost
# the sharded topology attacks, and the bill carries n_redis == n_brokers.
# Shards are extra PROCESSES: they can only help up to the host's spare
# cores (os.cpu_count() is recorded in the payload), so the wire mean
# shrinks 1 -> 2 on a 2-core runner and saturates beyond it.
SWEEP_BROKERS = (1, 2, 4)
SWEEP_STEPS = 30
SWEEP_P = 2
SWEEP_WCFG = {
    "n_users": 2000,
    "n_movies": 3000,
    "n_ratings": 40_000,
    "rank": 32,
    "batch_size": 1024,
}


def _run(kind: str, with_tuner: bool) -> dict:
    if kind == "pmf":
        sim = pmf_sim(P, model=cons.Model.ISP)
        res = sim.run(
            pmf_batch_fn(B), B, max_steps=150, loss_threshold=1.05,
            eval_fn=pmf_eval_fn(),
            tuner=tuner(P) if with_tuner else None,
        )
    else:
        sparse = kind == "lr_sparse"
        sim = lr_sim(sparse, P, model=cons.Model.ISP)
        res = sim.run(
            lr_batch_fn(sparse, B), B, max_steps=150, loss_threshold=0.55,
            tuner=tuner(P) if with_tuner else None,
        )
    tag = "tuned" if with_tuner else "fixed"
    return summarize(f"{kind}_{tag}", res)


def _run_live() -> dict:
    """The same PMF job, live (real processes) and simulated (timing model)."""
    import tempfile
    from functools import partial

    from repro import optim
    from repro.core.isp import ISPConfig
    from repro.core.simulator import (
        Platform, ServerlessSimulator, SimulatorConfig,
    )
    from repro.runtime import (
        build_workload, pmf_quickstart_config, run_job,
    )

    # -- live: real worker processes, measured durations, real bill
    job = pmf_quickstart_config(
        run_dir=tempfile.mkdtemp(prefix="bench_faas_"),
        n_workers=LIVE_P,
        total_steps=LIVE_STEPS,
    )
    job.retain_updates = True  # for the per-scheme wire-bytes sweep below
    wl = build_workload(job.workload, job.workload_cfg)
    live = run_job(job)

    # -- per-scheme wire bytes over the ACTUAL published updates of the live
    # run: simulated == measured by construction (repro.wire, §10), so
    # re-accounting every stored update under each codec gives exactly the
    # bytes the broker would have measured had the job shipped that scheme
    from repro import wire

    wire_bytes_by_scheme = {
        scheme: float(
            sum(
                wire.predict_tree_nbytes(u["update"], scheme=scheme)
                for u in live["updates"]
            )
        )
        for scheme in ("dense", "sparse", "bitmap", "auto")
    }

    # -- simulated: identical math (same Workload object), modelled platform
    rank = wl.cfg["rank"]
    # invocation rounds of the live job — billed per invocation AND added
    # to the predicted wall (each round stalls the pool at the barrier)
    inv_rounds = max(-(-job.total_steps // job.invocation_steps), 1)
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=LIVE_P,
            platform=Platform.MLLESS,
            consistency=cons.ConsistencyConfig(
                model=cons.Model.ISP, isp=ISPConfig(v=job.isp_v)
            ),
            sparse_model=True,
            # predicted bytes read the SAME repro.wire codec formula the
            # live workers' encoder asserts against (DESIGN.md §10), and
            # the modelled store topology is the one the job ran
            wire_scheme=job.wire_scheme,
            n_redis=job.n_brokers,
            cold_start_s=COLD_START_S,
            invocations_per_worker=inv_rounds,
        ),
        grad_fn=wl.grad_fn,
        optimizer=optim.make(job.optimizer, job.lr),
        params=wl.params0,
        flops_per_sample=6 * rank * 3,
        update_nnz_fn=partial(
            lambda r, n, bsz: 2 * r * min(bsz, n), rank, wl.cfg["n_users"]
        ),
    )

    def batch_fn(step: int, n_workers: int):
        return wl.make_batch(wl.store.fetch_stacked(step, n_workers))

    simres = sim.run(
        batch_fn, wl.cfg["batch_size"], LIVE_STEPS,
        tuner=tuner(LIVE_P, interval=2.0),
    )

    # symmetric step-time comparison: the live mean includes the pool-wide
    # barrier stalls of invocation-boundary cold starts (a respawning peer
    # blocks everyone), so the predicted mean must include the modelled
    # stall rounds too — same cold-start constant the bill charges
    predicted_step = (
        simres.total_wall_s + COLD_START_S * inv_rounds
    ) / max(len(simres.records), 1)
    payload = {
        "workload": dict(wl.cfg),
        "n_workers": LIVE_P,
        "steps": LIVE_STEPS,
        "isp_v": job.isp_v,
        "live": {
            "measured_step_s_mean": live["measured_step_s"],
            "wall_s": live["wall_s"],
            "faas_cost_usd": live["bill"]["total"],
            "worker_seconds": live["bill"]["worker_seconds"],
            "final_loss": live["final_loss"],
            "final_pool": live["final_pool"],
            "n_scale_events": len(live["scale_events"]),
            "n_invocations": live["n_invocations"],
            "wire_scheme": live["wire_scheme"],
            "wire_bytes_total": live["wire_bytes_total"],
            "wire_bytes_by_scheme": wire_bytes_by_scheme,
            "invariant_max_err": live["invariant_max_err"],
            # per-phase data-path breakdown (mean seconds per step), so a
            # future regression is attributable to encode/wire/decode/compute
            "phase_s_mean": live["phase_s_mean"],
            # measured loss/pool trajectory — fig7/fig8-style time-to-loss
            # and cost-to-loss curves from a LIVE run instead of the model
            "history": [
                {"step": r["step"], "loss": r["loss"],
                 "dur_s": r["dur_s"], "p_active": r["p_active"]}
                for r in live["history"]
            ],
        },
        "simulated": {
            "predicted_step_s_mean": predicted_step,
            "modelled_wall_s": simres.total_wall_s,
            "cold_start_s": COLD_START_S,
            "invocation_rounds": inv_rounds,
            "faas_cost_usd": simres.total_cost,
            "final_loss": simres.final_loss,
            "final_workers": simres.summary["final_workers"],
        },
        "ratios": {
            "step_time_measured_over_predicted": (
                (live["measured_step_s"] or 0.0) / max(predicted_step, 1e-12)
            ),
            "cost_measured_over_predicted": (
                live["bill"]["total"] / max(simres.total_cost, 1e-12)
            ),
        },
    }
    payload["shard_sweep"] = _run_shard_sweep()
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "BENCH_runtime.json"), "w") as f:
        json.dump(payload, f, indent=1)
    write_result("fig6_runtime_live", payload)
    return payload


def _run_shard_sweep() -> dict:
    """The same deterministic store-bound PMF job, live, at each
    update-store shard count (``runtime.sharding``): auto-tuner off and a
    single invocation per worker so every run ships the IDENTICAL update
    stream — wire bytes are bit-equal across the sweep, and the only
    things that move are the wire phase (broker-side serialization, now
    split and parallelized across shard processes) and the
    ``n_redis == n_brokers`` infra bill."""
    import tempfile

    from repro.runtime import FaaSJobConfig, run_job

    rows = []
    for nb in SWEEP_BROKERS:
        job = FaaSJobConfig(
            run_dir=tempfile.mkdtemp(prefix=f"bench_shards{nb}_"),
            workload="pmf",
            workload_cfg=dict(SWEEP_WCFG),
            n_workers=SWEEP_P,
            total_steps=SWEEP_STEPS,
            checkpoint_every=100,
            optimizer="nesterov",
            lr=0.1,
            isp_v=0.7,
            wire_scheme="dense",  # store-bound: ship full dense updates
            n_brokers=nb,
            autotune=False,
            deadline_s=480.0,
        )
        live = run_job(job)
        ph = live["phase_s_mean"] or {}
        rows.append(
            {
                "n_brokers": nb,
                "measured_step_s_mean": live["measured_step_s"],
                "wire_phase_s_mean": ph.get("wire"),
                "phase_s_mean": ph,
                "wire_bytes_total": live["wire_bytes_total"],
                "update_bytes_per_shard": live[
                    "broker_update_bytes_per_shard"
                ],
                "dup_mismatches": live["dup_mismatches"],
                "faas_cost_usd": live["bill"]["total"],
                "infra_cost_usd": live["bill"]["infra_cost"],
                "n_redis_billed": live["bill"]["n_redis"],
            }
        )
    return {
        "workload": dict(SWEEP_WCFG),
        "n_workers": SWEEP_P,
        "steps": SWEEP_STEPS,
        "wire_scheme": "dense",
        # shard processes only parallelize up to the host's spare cores
        "host_cpus": os.cpu_count(),
        "rows": rows,
    }


def run(live: bool = False) -> dict:
    rows = []
    ratios = {}
    for kind in ("pmf", "lr_dense", "lr_sparse"):
        fixed = _run(kind, False)
        tuned = _run(kind, True)
        ratio = tuned["perf_per_dollar"] / max(fixed["perf_per_dollar"],
                                               1e-12)
        ratios[kind] = ratio
        rows += [fixed, tuned]
    write_result("fig6_autotuner", {"rows": rows, "perf_ratios": ratios})
    out = {"rows": rows, "perf_ratios": ratios}
    if live:
        out["runtime_live"] = _run_live()
    return out


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        lines.append(
            f"fig6,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
            f"perf/$={r['perf_per_dollar']:.3f},workers={r['final_workers']}"
        )
    for k, v in out["perf_ratios"].items():
        lines.append(f"fig6,{k}_perf_ratio,{v*1e6:.0f},tuned/fixed={v:.2f}x")
    rt = out.get("runtime_live")
    if rt:
        meas = rt["live"]["measured_step_s_mean"] or 0.0
        pred = rt["simulated"]["predicted_step_s_mean"]
        lines.append(
            f"fig6,runtime_live_step,{meas*1e6:.0f},"
            f"measured/predicted={rt['ratios']['step_time_measured_over_predicted']:.2f}x"
        )
        lines.append(
            f"fig6,runtime_live_cost,{rt['live']['faas_cost_usd']*1e6:.0f},"
            f"cost_ratio={rt['ratios']['cost_measured_over_predicted']:.2f}x"
        )
        ph = rt["live"].get("phase_s_mean") or {}
        if ph:
            breakdown = "/".join(f"{k}={v*1e3:.1f}ms" for k, v in ph.items())
            lines.append(f"fig6,runtime_live_phases,0,{breakdown}")
        for scheme, b in (rt["live"].get("wire_bytes_by_scheme") or {}).items():
            lines.append(f"fig6,wire_bytes_{scheme},{b:.0f},bytes={b:.0f}")
        for row in (rt.get("shard_sweep") or {}).get("rows", []):
            w = row["wire_phase_s_mean"] or 0.0
            lines.append(
                f"fig6,shard_sweep_b{row['n_brokers']},{w*1e6:.0f},"
                f"wire={w*1e3:.1f}ms,step={row['measured_step_s_mean']*1e3:.0f}ms,"
                f"n_redis={row['n_redis_billed']}"
            )
    return lines
