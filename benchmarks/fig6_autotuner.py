"""Fig. 6: scale-in auto-tuner effect on Perf/$ and execution time.

Runs each job with and without the auto-tuner (ISP on) and reports the
Perf/$ ratio — the paper measures 1.1x-1.6x improvements depending on the
workload.
"""

from __future__ import annotations

from benchmarks.common import (
    lr_batch_fn,
    lr_sim,
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    tuner,
    write_result,
)
from repro.core import consistency as cons

P = 8
B = 2048


def _run(kind: str, with_tuner: bool) -> dict:
    if kind == "pmf":
        sim = pmf_sim(P, model=cons.Model.ISP)
        res = sim.run(
            pmf_batch_fn(B), B, max_steps=150, loss_threshold=1.05,
            eval_fn=pmf_eval_fn(),
            tuner=tuner(P) if with_tuner else None,
        )
    else:
        sparse = kind == "lr_sparse"
        sim = lr_sim(sparse, P, model=cons.Model.ISP)
        res = sim.run(
            lr_batch_fn(sparse, B), B, max_steps=150, loss_threshold=0.55,
            tuner=tuner(P) if with_tuner else None,
        )
    tag = "tuned" if with_tuner else "fixed"
    return summarize(f"{kind}_{tag}", res)


def run() -> dict:
    rows = []
    ratios = {}
    for kind in ("pmf", "lr_dense", "lr_sparse"):
        fixed = _run(kind, False)
        tuned = _run(kind, True)
        ratio = tuned["perf_per_dollar"] / max(fixed["perf_per_dollar"],
                                               1e-12)
        ratios[kind] = ratio
        rows += [fixed, tuned]
    write_result("fig6_autotuner", {"rows": rows, "perf_ratios": ratios})
    return {"rows": rows, "perf_ratios": ratios}


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        lines.append(
            f"fig6,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
            f"perf/$={r['perf_per_dollar']:.3f},workers={r['final_workers']}"
        )
    for k, v in out["perf_ratios"].items():
        lines.append(f"fig6,{k}_perf_ratio,{v*1e6:.0f},tuned/fixed={v:.2f}x")
    return lines
