"""Fig. 6: scale-in auto-tuner effect on Perf/$ and execution time.

Runs each job with and without the auto-tuner (ISP on) and reports the
Perf/$ ratio — the paper measures 1.1x-1.6x improvements depending on the
workload.

``run(live=True)`` additionally runs the SAME PMF job on the real
multi-process FaaS runtime (``repro.runtime``) and on the simulator with a
matching configuration, and emits ``BENCH_runtime.json`` at the repo root
comparing simulator-predicted vs measured step durations and FaaS cost —
the calibration check of the timing model (DESIGN.md §8 vs §9).
"""

from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks.common import (
    lr_batch_fn,
    lr_sim,
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    tuner,
    write_result,
)
from repro.core import consistency as cons

P = 8
B = 2048

# -- live-vs-simulated configuration ------------------------------------------
# the live job IS the quickstart job (examples/mlless_faas.py) — one shared
# config in repro.runtime, so the benchmark always calibrates against the
# job the example runs
LIVE_P = 4
LIVE_STEPS = 140
# solo-measured runtime-init constant of the local substrate (python +
# jax import + restore, uncontended) — the FaaS cold start each invocation
# bills and each invocation round stalls the pool for.  A modelling
# constant like CommModel's RTTs, NOT fit to the live run.
COLD_START_S = 2.0
# the shard sweep: a store-bound PMF job (big dense updates, the regime
# "Towards Demystifying Serverless ML Training" identifies as the
# indirect-communication bottleneck), live, at each update-store shard
# count — the wire phase (publish + pipelined barrier pulls) is the cost
# the sharded topology attacks, and the bill carries n_redis == n_brokers.
# Shards are extra PROCESSES: they can only help up to the host's spare
# cores (os.cpu_count() is recorded in the payload), so the wire mean
# shrinks 1 -> 2 on a 2-core runner and saturates beyond it.
SWEEP_BROKERS = (1, 2, 4)
SWEEP_STEPS = 30
SWEEP_P = 2
SWEEP_WCFG = {
    "n_users": 2000,
    "n_movies": 3000,
    "n_ratings": 40_000,
    "rank": 32,
    "batch_size": 1024,
}
# PMF has exactly TWO leaves, so without splitting every shard past the
# second owns zero update bytes and the sweep silently stops measuring —
# split leaves denser than 128 KiB into chunks (topology-independent:
# wire bytes stay bit-identical across every n_brokers row)
SWEEP_SPLIT_BYTES = 128 * 1024
# the transport sweep: the SAME store-bound job over each update-path
# transport x shard count — the zero-copy claim of DESIGN.md §12 as a
# measured number, with bit-identical bytes/params across every cell
TRANSPORT_SWEEP = ("tcp", "shm")
TRANSPORT_SWEEP_BROKERS = (1, 2)
# the codec-backend compare (DESIGN.md §15): the SAME store-bound job per
# wire impl — encode phase p50/p95 moves, bytes and final params may not
ENCODE_IMPLS = ("numpy", "pallas")
ENCODE_IMPL_STEPS = 12


def _run(kind: str, with_tuner: bool) -> dict:
    if kind == "pmf":
        sim = pmf_sim(P, model=cons.Model.ISP)
        res = sim.run(
            pmf_batch_fn(B), B, max_steps=150, loss_threshold=1.05,
            eval_fn=pmf_eval_fn(),
            tuner=tuner(P) if with_tuner else None,
        )
    else:
        sparse = kind == "lr_sparse"
        sim = lr_sim(sparse, P, model=cons.Model.ISP)
        res = sim.run(
            lr_batch_fn(sparse, B), B, max_steps=150, loss_threshold=0.55,
            tuner=tuner(P) if with_tuner else None,
        )
    tag = "tuned" if with_tuner else "fixed"
    return summarize(f"{kind}_{tag}", res)


def _phase_stats(history: list) -> tuple[dict, dict]:
    """Per-phase mean and {p50, p95} over a run's per-step phase rows —
    tail percentiles make transport wins visible that a mean smears
    (a single slow barrier wakeup hides in the mean, not in the p95)."""
    import numpy as np

    phases = [r["phase"] for r in history if r.get("phase")]
    if not phases:
        return {}, {}
    keys = list(phases[0])
    vals = {
        k: [p[k] for p in phases if p.get(k) is not None] for k in keys
    }
    mean = {k: float(np.mean(v)) for k, v in vals.items() if v}
    quant = {
        k: {
            "p50": float(np.percentile(v, 50)),
            "p95": float(np.percentile(v, 95)),
        }
        for k, v in vals.items()
        if v
    }
    return mean, quant


def _steady(history: list) -> list:
    """Drop the compile/warm-up step (step 1): its ~3 s XLA compile is a
    cold-start constant, not a step-time sample — with it in, the mean is
    ~2x the steady state and every comparison is noise-dominated."""
    return [r for r in history if r["step"] > 1]


def _run_live() -> dict:
    """The same PMF job, live (real processes) and simulated (timing model)."""
    import tempfile
    from functools import partial

    from repro import optim
    from repro.core.isp import ISPConfig
    from repro.core.simulator import (
        Platform, ServerlessSimulator, SimulatorConfig,
    )
    from repro.runtime import (
        build_workload, pmf_quickstart_config, run_job,
    )

    # -- live: real worker processes, measured durations, real bill
    job = pmf_quickstart_config(
        run_dir=tempfile.mkdtemp(prefix="bench_faas_"),
        n_workers=LIVE_P,
        total_steps=LIVE_STEPS,
    )
    job.retain_updates = True  # for the per-scheme wire-bytes sweep below
    wl = build_workload(job.workload, job.workload_cfg)
    live = run_job(job)

    # -- per-scheme wire bytes over the ACTUAL published updates of the live
    # run: simulated == measured by construction (repro.wire, §10), so
    # re-accounting every stored update under each codec gives exactly the
    # bytes the broker would have measured had the job shipped that scheme
    from repro import wire

    wire_bytes_by_scheme = {
        scheme: float(
            sum(
                wire.predict_tree_nbytes(u["update"], scheme=scheme)
                for u in live["updates"]
            )
        )
        for scheme in ("dense", "sparse", "bitmap", "auto")
    }

    # -- simulated: identical math (same Workload object), modelled platform
    rank = wl.cfg["rank"]
    # invocation rounds of the live job — billed per invocation AND added
    # to the predicted wall (each round stalls the pool at the barrier)
    inv_rounds = max(-(-job.total_steps // job.invocation_steps), 1)
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=LIVE_P,
            platform=Platform.MLLESS,
            consistency=cons.ConsistencyConfig(
                model=cons.Model.ISP, isp=ISPConfig(v=job.isp_v)
            ),
            sparse_model=True,
            # predicted bytes read the SAME repro.wire codec formula the
            # live workers' encoder asserts against (DESIGN.md §10), and
            # the modelled store topology is the one the job ran
            wire_scheme=job.wire_scheme,
            n_redis=job.n_brokers,
            cold_start_s=COLD_START_S,
            invocations_per_worker=inv_rounds,
        ),
        grad_fn=wl.grad_fn,
        optimizer=optim.make(job.optimizer, job.lr),
        params=wl.params0,
        flops_per_sample=6 * rank * 3,
        update_nnz_fn=partial(
            lambda r, n, bsz: 2 * r * min(bsz, n), rank, wl.cfg["n_users"]
        ),
    )

    def batch_fn(step: int, n_workers: int):
        return wl.make_batch(wl.store.fetch_stacked(step, n_workers))

    simres = sim.run(
        batch_fn, wl.cfg["batch_size"], LIVE_STEPS,
        tuner=tuner(LIVE_P, interval=2.0),
    )

    # symmetric step-time comparison, steady state on BOTH sides: the
    # measured mean drops the compile/warm-up step (step 1), so the
    # predicted mean drops the first cold-start round and its step too —
    # later invocation-boundary stalls stay in both (a respawning peer
    # blocks the whole pool)
    n_rec = len(simres.records)
    predicted_step_incl = (
        simres.total_wall_s + COLD_START_S * inv_rounds
    ) / max(n_rec, 1)
    predicted_step = (
        simres.total_wall_s * max(n_rec - 1, 1) / max(n_rec, 1)
        + COLD_START_S * max(inv_rounds - 1, 0)
    ) / max(n_rec - 1, 1)
    steady = _steady(live["history"])
    measured_steady = (
        sum(r["dur_s"] for r in steady) / len(steady) if steady else None
    )
    phase_mean, phase_quant = _phase_stats(steady)
    payload = {
        "workload": dict(wl.cfg),
        "n_workers": LIVE_P,
        "steps": LIVE_STEPS,
        "isp_v": job.isp_v,
        "live": {
            # steady state (warm-up step excluded); the inclusive mean is
            # kept alongside for the cost/wall narratives it belongs to
            "measured_step_s_mean": measured_steady,
            "measured_step_s_mean_incl_warmup": live["measured_step_s"],
            "wall_s": live["wall_s"],
            "faas_cost_usd": live["bill"]["total"],
            "worker_seconds": live["bill"]["worker_seconds"],
            "final_loss": live["final_loss"],
            "final_pool": live["final_pool"],
            "n_scale_events": len(live["scale_events"]),
            "n_invocations": live["n_invocations"],
            "wire_scheme": live["wire_scheme"],
            "wire_bytes_total": live["wire_bytes_total"],
            "wire_bytes_by_scheme": wire_bytes_by_scheme,
            "invariant_max_err": live["invariant_max_err"],
            # per-phase data-path breakdown (steady-state seconds per
            # step) with tail percentiles, so a future regression is
            # attributable to encode/wire/decode/compute AND visible in
            # the tail even when the mean hides it
            "phase_s_mean": phase_mean,
            "phase_s_quantiles": phase_quant,
            "phase_s_mean_incl_warmup": live["phase_s_mean"],
            # measured loss/pool trajectory — fig7/fig8-style time-to-loss
            # and cost-to-loss curves from a LIVE run instead of the model
            "history": [
                {"step": r["step"], "loss": r["loss"],
                 "dur_s": r["dur_s"], "p_active": r["p_active"]}
                for r in live["history"]
            ],
        },
        "simulated": {
            "predicted_step_s_mean": predicted_step,
            "predicted_step_s_mean_incl_warmup": predicted_step_incl,
            "modelled_wall_s": simres.total_wall_s,
            "cold_start_s": COLD_START_S,
            "invocation_rounds": inv_rounds,
            "faas_cost_usd": simres.total_cost,
            "final_loss": simres.final_loss,
            "final_workers": simres.summary["final_workers"],
        },
        "ratios": {
            "step_time_measured_over_predicted": (
                (measured_steady or 0.0) / max(predicted_step, 1e-12)
            ),
            "step_time_measured_over_predicted_incl_warmup": (
                (live["measured_step_s"] or 0.0)
                / max(predicted_step_incl, 1e-12)
            ),
            "cost_measured_over_predicted": (
                live["bill"]["total"] / max(simres.total_cost, 1e-12)
            ),
        },
    }
    # the codec-backend cells ride in the MAIN run block: encode-phase
    # p50/p95 per impl on the same store-bound job, bit-identity asserted
    payload["live"]["encode_phase_by_impl"] = _run_encode_impl_compare()
    shard_sweep = _run_shard_sweep()
    payload["shard_sweep"] = shard_sweep
    # the tcp x {1,2} transport cells are byte-identical reruns of the
    # shard sweep's first two rows — reuse them instead of paying for
    # two more live multi-process jobs
    payload["transport_sweep"] = _run_transport_sweep(
        tcp_rows={
            r["n_brokers"]: r
            for r in shard_sweep["rows"]
            if r["n_brokers"] in TRANSPORT_SWEEP_BROKERS
        }
    )
    # BENCH_runtime.json is shared with fig9/fig11/encode_bench's
    # sections: overlay this payload's keys, preserve theirs
    root = os.path.join(os.path.dirname(__file__), "..")
    bench_path = os.path.join(root, "BENCH_runtime.json")
    doc = {}
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            doc = json.load(f)
    doc.update(payload)
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1)
    write_result("fig6_runtime_live", payload)
    return payload


def _run_encode_impl_compare() -> dict:
    """One deterministic store-bound run per codec backend (auto-tuner
    off, same seed): the encode phase is the only thing allowed to move —
    wire bytes and final parameters must be bit-identical, because the
    Pallas path is an implementation of the same codec, not a codec."""
    import tempfile

    from repro.runtime import FaaSJobConfig, final_params_digest, run_job

    cells = {}
    for impl in ENCODE_IMPLS:
        job = FaaSJobConfig(
            run_dir=tempfile.mkdtemp(prefix=f"bench_enc_{impl}_"),
            workload="pmf",
            workload_cfg=dict(SWEEP_WCFG),
            n_workers=SWEEP_P,
            total_steps=ENCODE_IMPL_STEPS,
            checkpoint_every=100,
            optimizer="nesterov",
            lr=0.1,
            isp_v=0.7,
            wire_impl=impl,
            autotune=False,
            deadline_s=480.0,
        )
        live = run_job(job)
        _, quant = _phase_stats(_steady(live["history"]))
        enc = quant.get("encode", {})
        cells[impl] = {
            "encode_s_p50": enc.get("p50"),
            "encode_s_p95": enc.get("p95"),
            "wire_bytes_total": live["wire_bytes_total"],
            "final_params_sha256": final_params_digest(job),
        }
    ref = cells[ENCODE_IMPLS[0]]
    return {
        **cells,
        "bit_identical": all(
            c["wire_bytes_total"] == ref["wire_bytes_total"]
            and c["final_params_sha256"] == ref["final_params_sha256"]
            for c in cells.values()
        ),
    }


def _run_store_bound(n_brokers: int, transport: str) -> dict:
    """One deterministic store-bound PMF run: auto-tuner off and a single
    invocation per worker, so every (transport, n_brokers) cell ships the
    IDENTICAL update stream — wire bytes and final parameters must be
    bit-equal across cells, and the only things that move are the wire
    phase and the ``n_redis == n_brokers`` infra bill."""
    import tempfile

    from repro.runtime import FaaSJobConfig, final_params_digest, run_job

    job = FaaSJobConfig(
        run_dir=tempfile.mkdtemp(prefix=f"bench_{transport}{n_brokers}_"),
        workload="pmf",
        workload_cfg=dict(SWEEP_WCFG),
        n_workers=SWEEP_P,
        total_steps=SWEEP_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.1,
        isp_v=0.7,
        wire_scheme="dense",  # store-bound: ship full dense updates
        n_brokers=n_brokers,
        transport=transport,
        shard_split_bytes=SWEEP_SPLIT_BYTES,
        autotune=False,
        deadline_s=480.0,
    )
    live = run_job(job)
    steady = _steady(live["history"])
    phase_mean, phase_quant = _phase_stats(steady)
    wire_q = phase_quant.get("wire", {})
    return {
        "n_brokers": n_brokers,
        "transport": transport,
        "measured_step_s_mean": (
            sum(r["dur_s"] for r in steady) / len(steady) if steady
            else live["measured_step_s"]
        ),
        "wire_phase_s_mean": phase_mean.get("wire"),
        "wire_phase_s_p50": wire_q.get("p50"),
        "wire_phase_s_p95": wire_q.get("p95"),
        "phase_s_mean": phase_mean,
        "phase_s_quantiles": phase_quant,
        "wire_bytes_total": live["wire_bytes_total"],
        "update_bytes_per_shard": live["broker_update_bytes_per_shard"],
        "dup_mismatches": live["dup_mismatches"],
        "faas_cost_usd": live["bill"]["total"],
        "infra_cost_usd": live["bill"]["infra_cost"],
        "n_redis_billed": live["bill"]["n_redis"],
        "final_params_sha256": final_params_digest(job),
    }


def _sweep_header() -> dict:
    return {
        "workload": dict(SWEEP_WCFG),
        "n_workers": SWEEP_P,
        "steps": SWEEP_STEPS,
        "wire_scheme": "dense",
        # PMF's two leaves are split into ~128 KiB chunks so every shard
        # owns bytes (topology-independent: bytes identical across rows)
        "shard_split_bytes": SWEEP_SPLIT_BYTES,
        # shard processes only parallelize up to the host's spare cores
        "host_cpus": os.cpu_count(),
    }


def _run_shard_sweep() -> dict:
    """The store-bound job at each update-store shard count
    (``runtime.sharding``): the wire phase (broker-side serialization,
    split and parallelized across shard processes) and the infra bill
    move; the bytes may not."""
    return {
        **_sweep_header(),
        "rows": [_run_store_bound(nb, "tcp") for nb in SWEEP_BROKERS],
    }


def _run_transport_sweep(tcp_rows: Optional[dict] = None) -> dict:
    """The store-bound job over {tcp, shm} x n_brokers (DESIGN.md §12.4):
    the zero-copy same-host claim as a measured number.  Every cell must
    ship bit-identical wire bytes, per-shard splits, and final params —
    asserted here, recorded in the payload.  ``tcp_rows`` (by broker
    count) lets the caller reuse the shard sweep's tcp runs instead of
    repeating them."""
    tcp_rows = tcp_rows or {}
    rows = [
        tcp_rows[nb] if tr == "tcp" and nb in tcp_rows
        else _run_store_bound(nb, tr)
        for tr in TRANSPORT_SWEEP
        for nb in TRANSPORT_SWEEP_BROKERS
    ]
    ref = rows[0]
    by = {(r["transport"], r["n_brokers"]): r for r in rows}
    bit_identical = all(
        r["wire_bytes_total"] == ref["wire_bytes_total"]
        and r["final_params_sha256"] == ref["final_params_sha256"]
        and sum(r["update_bytes_per_shard"]) == int(r["wire_bytes_total"])
        # the transport may never MOVE bytes between shards either
        and r["update_bytes_per_shard"]
        == by[("tcp", r["n_brokers"])]["update_bytes_per_shard"]
        and r["dup_mismatches"] == 0
        for r in rows
    )
    shm_wire_over_tcp = {
        str(nb): (
            by[("shm", nb)]["wire_phase_s_mean"]
            / max(by[("tcp", nb)]["wire_phase_s_mean"], 1e-12)
        )
        for nb in TRANSPORT_SWEEP_BROKERS
    }
    return {
        **_sweep_header(),
        "rows": rows,
        "bit_identical_across_cells": bit_identical,
        "shm_wire_over_tcp": shm_wire_over_tcp,
    }


def run(live: bool = False) -> dict:
    rows = []
    ratios = {}
    for kind in ("pmf", "lr_dense", "lr_sparse"):
        fixed = _run(kind, False)
        tuned = _run(kind, True)
        ratio = tuned["perf_per_dollar"] / max(fixed["perf_per_dollar"],
                                               1e-12)
        ratios[kind] = ratio
        rows += [fixed, tuned]
    write_result("fig6_autotuner", {"rows": rows, "perf_ratios": ratios})
    out = {"rows": rows, "perf_ratios": ratios}
    if live:
        out["runtime_live"] = _run_live()
    return out


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        lines.append(
            f"fig6,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
            f"perf/$={r['perf_per_dollar']:.3f},workers={r['final_workers']}"
        )
    for k, v in out["perf_ratios"].items():
        lines.append(f"fig6,{k}_perf_ratio,{v*1e6:.0f},tuned/fixed={v:.2f}x")
    rt = out.get("runtime_live")
    if rt:
        meas = rt["live"]["measured_step_s_mean"] or 0.0
        pred = rt["simulated"]["predicted_step_s_mean"]
        lines.append(
            f"fig6,runtime_live_step,{meas*1e6:.0f},"
            f"measured/predicted={rt['ratios']['step_time_measured_over_predicted']:.2f}x"
        )
        lines.append(
            f"fig6,runtime_live_cost,{rt['live']['faas_cost_usd']*1e6:.0f},"
            f"cost_ratio={rt['ratios']['cost_measured_over_predicted']:.2f}x"
        )
        ph = rt["live"].get("phase_s_mean") or {}
        if ph:
            breakdown = "/".join(f"{k}={v*1e3:.1f}ms" for k, v in ph.items())
            lines.append(f"fig6,runtime_live_phases,0,{breakdown}")
        impl_cells = rt["live"].get("encode_phase_by_impl") or {}
        for impl, cell in impl_cells.items():
            if not isinstance(cell, dict):
                continue
            p50 = cell.get("encode_s_p50") or 0.0
            p95 = cell.get("encode_s_p95") or 0.0
            lines.append(
                f"fig6,encode_impl_{impl},{p50*1e6:.0f},"
                f"encode_p50={p50*1e3:.2f}ms,p95={p95*1e3:.2f}ms,"
                f"bit_identical={impl_cells.get('bit_identical')}"
            )
        for scheme, b in (rt["live"].get("wire_bytes_by_scheme") or {}).items():
            lines.append(f"fig6,wire_bytes_{scheme},{b:.0f},bytes={b:.0f}")
        for row in (rt.get("shard_sweep") or {}).get("rows", []):
            w = row["wire_phase_s_mean"] or 0.0
            lines.append(
                f"fig6,shard_sweep_b{row['n_brokers']},{w*1e6:.0f},"
                f"wire={w*1e3:.1f}ms,step={row['measured_step_s_mean']*1e3:.0f}ms,"
                f"n_redis={row['n_redis_billed']}"
            )
        ts = rt.get("transport_sweep") or {}
        for row in ts.get("rows", []):
            w = row["wire_phase_s_mean"] or 0.0
            p95 = row["wire_phase_s_p95"] or 0.0
            lines.append(
                f"fig6,transport_{row['transport']}_b{row['n_brokers']},"
                f"{w*1e6:.0f},wire={w*1e3:.1f}ms,p95={p95*1e3:.1f}ms,"
                f"step={row['measured_step_s_mean']*1e3:.0f}ms"
            )
        for nb, ratio in (ts.get("shm_wire_over_tcp") or {}).items():
            lines.append(
                f"fig6,shm_wire_over_tcp_b{nb},{ratio*1e6:.0f},"
                f"shm/tcp={ratio:.2f}x,bit_identical="
                f"{ts.get('bit_identical_across_cells')}"
            )
    return lines
