"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the house convention
(us_per_call = the benchmark's primary time metric in microseconds of
modelled platform time; derived = the figure's headline ratio/metric).

    PYTHONPATH=src python -m benchmarks.run            # all figures
    PYTHONPATH=src python -m benchmarks.run fig5 fig9  # a subset
    PYTHONPATH=src python -m benchmarks.run --live fig6
        # fig6 additionally runs the PMF job on the real multi-process FaaS
        # runtime and emits BENCH_runtime.json (simulator-predicted vs
        # measured step durations and cost)
"""

from __future__ import annotations

import inspect
import sys
import time


def main() -> None:
    from benchmarks import (
        encode_bench,
        fig5_significance,
        fig6_autotuner,
        fig7_loss_vs_time,
        fig8_cost_vs_loss,
        fig9_ssp_vs_isp,
        fig10_scalability,
        fig11_multijob,
        fig12_topology,
        fig13_chaos,
        table3_weak_scaling,
    )

    suites = {
        "encode": encode_bench,
        "fig5": fig5_significance,
        "fig6": fig6_autotuner,
        "fig7": fig7_loss_vs_time,
        "fig8": fig8_cost_vs_loss,
        "fig9": fig9_ssp_vs_isp,
        "fig10": fig10_scalability,
        "fig11": fig11_multijob,
        "fig12": fig12_topology,
        "fig13": fig13_chaos,
        "table3": table3_weak_scaling,
    }
    argv = sys.argv[1:]
    live = "--live" in argv
    want = [a for a in argv if a != "--live"] or list(suites)
    print("name,us_per_call,derived")
    for key in want:
        mod = suites[key]
        t0 = time.time()
        kwargs = {}
        if live and "live" in inspect.signature(mod.run).parameters:
            kwargs["live"] = True
        out = mod.run(**kwargs)
        for line in mod.report(out):
            print(line, flush=True)
        print(f"{key}_harness,{(time.time()-t0)*1e6:.0f},host_seconds="
              f"{time.time()-t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
