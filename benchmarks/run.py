"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the house convention
(us_per_call = the benchmark's primary time metric in microseconds of
modelled platform time; derived = the figure's headline ratio/metric).

    PYTHONPATH=src python -m benchmarks.run            # all figures
    PYTHONPATH=src python -m benchmarks.run fig5 fig9  # a subset
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig5_significance,
        fig6_autotuner,
        fig7_loss_vs_time,
        fig8_cost_vs_loss,
        fig9_ssp_vs_isp,
        fig10_scalability,
        table3_weak_scaling,
    )

    suites = {
        "fig5": fig5_significance,
        "fig6": fig6_autotuner,
        "fig7": fig7_loss_vs_time,
        "fig8": fig8_cost_vs_loss,
        "fig9": fig9_ssp_vs_isp,
        "fig10": fig10_scalability,
        "table3": table3_weak_scaling,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for key in want:
        mod = suites[key]
        t0 = time.time()
        out = mod.run()
        for line in mod.report(out):
            print(line, flush=True)
        print(f"{key}_harness,{(time.time()-t0)*1e6:.0f},host_seconds="
              f"{time.time()-t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
