"""Fig. 13: chaos soak — determinism and billed cost under injected faults.

MLLess's economics depend on failures being CHEAP: a stateless function
that dies is re-invoked and replays forward from the update log, so a
fault costs the seconds of lost compute, not a coordinated restart.  This
soak runs the small deterministic PMF job twice:

* a fault-free reference (``run_job``), and
* the same job under a seeded randomized ``FaultPlan`` with at least one
  worker SIGKILL, broker SIGKILL, WAL tail corruption, transport stall
  and a supervisor self-kill (``faults.run_job_resilient`` re-executes
  the supervisor against its journal),

and holds the paper's determinism bar: the final parameters must be
**bit-identical** across the two runs (sha256 over every leaf) with
``dup_mismatches == 0`` — every replayed publish matched the stored copy
byte for byte.  The measured per-fault recovery time and the billed
overhead per fault land in ``BENCH_runtime.json`` under ``fig13_chaos``.

Without ``--live`` the suite checks the cheap half: seeded plan expansion
is a pure function of its arguments (the same seed always yields the same
schedule) and covers every requested fault kind.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import write_result

CHAOS_SEED = 1013

LIVE_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
LIVE_P = 3
LIVE_SHARDS = 2
LIVE_STEPS = 24
KINDS = ("worker_kill", "broker_kill", "wal_corrupt", "transport_stall",
         "supervisor_kill")


def _job(run_dir: str, chaos):
    from repro.runtime import FaaSJobConfig

    return FaaSJobConfig(
        run_dir=run_dir,
        workload="pmf",
        workload_cfg=dict(LIVE_WCFG),
        n_workers=LIVE_P,
        total_steps=LIVE_STEPS,
        checkpoint_every=4,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.5,
        n_brokers=LIVE_SHARDS,
        transport="tcp",
        autotune=False,
        deadline_s=480.0,
        chaos=chaos,
    )


def _run_soak(seed: int = CHAOS_SEED) -> dict:
    import tempfile

    from repro.runtime import final_params_digest, run_job
    from repro.runtime.faults import FaultPlan, run_job_resilient

    plan = FaultPlan.randomized(seed, LIVE_P, LIVE_SHARDS, LIVE_STEPS,
                                kinds=KINDS)
    ref_job = _job(tempfile.mkdtemp(prefix="bench_chaos_ref_"), None)
    ref = run_job(ref_job)
    ref_digest = final_params_digest(ref_job)

    chaos_job = _job(tempfile.mkdtemp(prefix="bench_chaos_soak_"),
                     plan.to_spec())
    res = run_job_resilient(chaos_job, verbose=False)
    chaos_digest = final_params_digest(chaos_job)

    fired = [e for e in res["chaos_events"] if "skipped" not in e]
    recoveries = {
        e["kind"]: e.get("recovery_s") for e in fired
    }
    n_faults = max(len(fired), 1)
    overhead = res["bill"]["total"] - ref["bill"]["total"]
    return {
        "seed": seed,
        "workload": dict(LIVE_WCFG),
        "n_workers": LIVE_P,
        "n_brokers": LIVE_SHARDS,
        "steps": LIVE_STEPS,
        "plan": plan.to_spec(),
        "events_fired": fired,
        "events_skipped": [e for e in res["chaos_events"] if "skipped" in e],
        "recovery_s_by_kind": recoveries,
        "supervisor_restarts": res["supervisor_restarts"],
        "supervisor_resumed": res["supervisor_resumed"],
        "wal_quarantined_bytes": res["wal_quarantined_bytes"],
        "dup_mismatches": res["dup_mismatches"],
        "ref_faas_cost_usd": ref["bill"]["total"],
        "chaos_faas_cost_usd": res["bill"]["total"],
        "billed_overhead_usd": overhead,
        "billed_overhead_per_fault_usd": overhead / n_faults,
        "ref_wall_s": ref["wall_s"],
        "chaos_wall_s": res["wall_s"],
        "final_params_sha256_ref": ref_digest,
        "final_params_sha256_chaos": chaos_digest,
        "bit_identical": ref_digest == chaos_digest,
    }


def _merge_into_bench_runtime(soak: dict) -> None:
    """BENCH_runtime.json is shared with the other live payloads:
    load-merge-write so whichever benchmark ran last keeps the rest."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_runtime.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["fig13_chaos"] = soak
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def run(live: bool = False) -> dict:
    from repro.runtime.faults import FaultPlan

    # plan expansion is a pure function of (seed, pool, steps): the same
    # seed must always yield the same schedule, covering every kind
    plans = [
        FaultPlan.randomized(CHAOS_SEED, LIVE_P, LIVE_SHARDS, LIVE_STEPS,
                             kinds=KINDS)
        for _ in range(2)
    ]
    deterministic = plans[0] == plans[1]
    counts = plans[0].counts()
    covered = all(counts.get(k, 0) >= 1 for k in KINDS)
    out = {
        "plan": plans[0].to_spec(),
        "plan_deterministic": deterministic,
        "kinds_covered": covered,
    }
    if not (deterministic and covered):
        raise SystemExit(f"fig13: plan expansion broken: {out}")
    if live:
        soak = _run_soak()
        out["soak"] = soak
        _merge_into_bench_runtime(soak)
        if not soak["bit_identical"] or soak["dup_mismatches"] != 0:
            raise SystemExit(
                f"fig13: chaos run diverged from the fault-free reference "
                f"(bit_identical={soak['bit_identical']}, "
                f"dup_mismatches={soak['dup_mismatches']})")
    write_result("fig13_chaos", out)
    return out


def report(out: dict) -> list[str]:
    lines = [
        f"fig13,plan_expansion,0,"
        f"deterministic={out['plan_deterministic']},"
        f"kinds_covered={out['kinds_covered']}"
    ]
    soak = out.get("soak")
    if soak:
        for e in soak["events_fired"]:
            rec = e.get("recovery_s")
            rec_txt = f"{rec:.2f}s" if rec is not None else "job-end"
            lines.append(
                f"fig13,recover_{e['kind']},"
                f"{(rec or 0.0)*1e6:.0f},recovery={rec_txt}"
            )
        lines.append(
            f"fig13,soak,{soak['chaos_wall_s']*1e6:.0f},"
            f"bit_identical={soak['bit_identical']},"
            f"dup={soak['dup_mismatches']},"
            f"restarts={soak['supervisor_restarts']},"
            f"overhead_per_fault=${soak['billed_overhead_per_fault_usd']:.6f}"
        )
    return lines
