"""Kernel-adjusted memory terms for the §Perf hillclimb cells.

The dry-run compiles for the CPU backend, whose fusion granularity
materializes attention logits tiles and sLSTM per-step gate tensors to
"HBM" — on a real TPU those live in VMEM inside the Pallas kernels
(kernels/flash_attention.py, kernels/slstm_scan.py). This script:

1. measures the interior bytes of those regions from the cached optimized
   HLO (trip-count-aware, matched by op_name scope), and
2. replaces them with the kernels' analytic DMA traffic (from their
   BlockSpecs), giving the memory term the TPU target would see.

    PYTHONPATH=src python -m benchmarks.kernel_adjusted
"""

from __future__ import annotations

import re

import zstandard as zstd

from repro.launch.hloanalysis import HloCost, _METADATA_RE, _BODY_RE, _COND_RE, _CALLS_RE, _TRIP_CFG_RE
from repro.launch.roofline import HBM_BW

CELLS = {
    "mixtral-8x22b__train_4k__single__bsp": {
        # attention-interior scopes (the chunked-core einsum/softmax chain)
        "patterns": (r"bqkgd", r"bqkgc", r"_where", r"/exp", r"squeeze",
                     r"online", r"reduce_max", r"reduce_sum"),
        # flash-attention DMA per layer-pass (bq=bk=1024 tiles):
        #   q*n_k + (k+v)*n_q + o   = 50MB*4 + 100MB*4 + 50MB ~ 0.65 GB
        # x 56 layers x 3 passes
        "kernel_bytes": 0.65e9 * 56 * 3,
        "what": "Pallas flash attention (VMEM-resident logits)",
    },
    "xlstm-1.3b__train_4k__single__bsp": {
        # sLSTM scan interior (per-step gate chains, 24576 trips)
        "patterns": (r"shard_map/while/body", r"shard_map/closed_call/while"),
        # fused scan DMA per layer-pass: xg in + h out + R ~ 0.17 GB
        # x 6 sLSTM layers x 3 passes (+ mLSTM unchanged)
        "kernel_bytes": 0.17e9 * 6 * 3,
        "what": "Pallas fused sLSTM scan (state in VMEM across 4096 steps)",
    },
}


def _walk_costs(hc: HloCost):
    """(bytes, op_name) per instruction, trip-multiplied (top_costs logic
    without truncation)."""
    hc.analyze()
    mult = {hc.entry: 1.0}
    frontier = [hc.entry]
    rows = []
    while frontier:
        cname = frontier.pop()
        comp = hc.comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                bm = _BODY_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                tm = _TRIP_CFG_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
                for tgt, mm in ((bm, m * trip), (cm, m)):
                    if tgt and (tgt.group(1) not in mult
                                or mult[tgt.group(1)] < mm):
                        mult[tgt.group(1)] = mm
                        frontier.append(tgt.group(1))
            elif ins.opcode in ("call", "conditional"):
                cm2 = _CALLS_RE.search(ins.rest)
                if cm2 and cm2.group(1) not in mult:
                    mult[cm2.group(1)] = m
                    frontier.append(cm2.group(1))
            else:
                c = hc._instr_cost(ins, comp)
                if c.bytes > 0:
                    md = _METADATA_RE.search(ins.rest)
                    rows.append((c.bytes * m, md.group(1) if md else ""))
    return rows


def adjusted(cell: str) -> dict:
    spec = CELLS[cell]
    hlo = zstd.ZstdDecompressor().decompress(
        open(f"results/dryrun/{cell}.hlo.zst", "rb").read()
    ).decode()
    hc = HloCost(hlo)
    total = hc.analyze().bytes
    rows = _walk_costs(hc)
    pats = [re.compile(p) for p in spec["patterns"]]
    interior = sum(b for b, name in rows if any(p.search(name) for p in pats))
    adj_bytes = total - interior + spec["kernel_bytes"]
    return {
        "cell": cell,
        "what": spec["what"],
        "memory_term_s": total / HBM_BW,
        "interior_share": interior / total,
        "adjusted_memory_term_s": adj_bytes / HBM_BW,
    }


def main() -> None:
    for cell in CELLS:
        try:
            r = adjusted(cell)
        except FileNotFoundError:
            print(f"{cell}: no cached HLO")
            continue
        print(f"{r['cell']}")
        print(f"  {r['what']}")
        print(f"  memory term {r['memory_term_s']:.1f}s "
              f"(interior {r['interior_share']*100:.0f}%) -> "
              f"adjusted {r['adjusted_memory_term_s']:.1f}s")


if __name__ == "__main__":
    main()
