"""CI wire-bytes regression guard (DESIGN.md §10.5, §11.5, §12.5).

Runs the PMF smoke workload on the LIVE FaaS runtime — single-broker,
sharded over two broker processes (``--n-brokers 2``), and sharded over
the shared-memory transport (``--transport shm``) — plus the simulator's
cost model for each topology, then compares against the checked-in
baseline (``benchmarks/wire_baseline.json``):

* ``wire_bytes_total`` and ``final_params_sha256`` — bit-deterministic
  at a fixed seed with the auto-tuner off (same updates -> same nnz ->
  same codec bytes -> same replicas), so the default ISP path must match
  the checked-in baseline EXACTLY: an opt-in feature (SSP, a new codec,
  a transport) that shifts a single byte or bit of the default path
  fails here;
* the SHARDED run's wire bytes must equal the single-broker run's EXACTLY
  (the leaf-key partition moves bytes between shards, it never changes
  them) and its per-shard broker-measured split must sum to the total —
  the topology-invariance guard;
* the SHM run's accounted wire bytes, per-shard split AND final
  parameters must be bit-identical to the TCP runs' — the transport
  must never change a byte or a bit of the math (§12's invariant);
* the MULTIJOB leg packs the same smoke job with an LR co-tenant on one
  fleet pool (DESIGN.md §14): job-namespaced keys mean the co-tenant may
  not change a byte of the smoke job's update stream nor a bit of its
  final parameters — both gate against the single-job leg;
* ``cost_measured_over_predicted`` (its ``_sharded`` twin billing
  ``n_redis == 2``, and its ``_shm`` twin on the same topology) — the
  live/model cost calibration; a >10% regression over the baseline
  (which carries documented headroom for host variance) means the live
  data path got structurally slower.  The gate applies to BOTH
  transports.

Exit codes: 0 pass, 1 regression, 2 could not run.

    PYTHONPATH=src python benchmarks/wire_guard.py            # check
    PYTHONPATH=src python benchmarks/wire_guard.py --update   # re-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

BASELINE = os.path.join(os.path.dirname(__file__), "wire_baseline.json")
TOLERANCE = 0.10  # the >10% rule

# deterministic smoke job: no auto-tuner (no scale events -> the update
# stream, and therefore the wire bytes, are a pure function of the seed),
# single invocation per worker (no respawn stalls in the cost number)
SMOKE_WCFG = {
    "n_users": 120,
    "n_movies": 150,
    "n_ratings": 6000,
    "rank": 4,
    "batch_size": 64,
}
SMOKE_P = 2
SMOKE_STEPS = 12
SMOKE_SHARDS = 2  # the sharded leg of the guard
COLD_START_S = 2.0  # same runtime-init constant as benchmarks/fig6


def _encode_p50(history: list) -> float:
    """Median per-step encode-phase seconds, step 1 (JIT warmup) dropped —
    the statistic the encode regression gate and fig6's impl compare use."""
    import statistics

    xs = [
        r["phase"]["encode"]
        for r in history
        if r.get("phase") and r["phase"].get("encode") is not None
        and r.get("step", 0) != 1
    ]
    return statistics.median(xs) if xs else 0.0


def run_smoke(
    n_brokers: int = 1, transport: str = "tcp", wire_impl: str = "numpy",
    partitioner: str = "greedy", shard_split_bytes: int = 0,
) -> dict:
    from functools import partial

    from repro import optim
    from repro.core import consistency as cons
    from repro.core.isp import ISPConfig
    from repro.core.simulator import (
        Platform, ServerlessSimulator, SimulatorConfig,
    )
    from repro.runtime import (
        FaaSJobConfig, build_workload, final_params_digest, run_job,
    )

    job = FaaSJobConfig(
        run_dir=tempfile.mkdtemp(
            prefix=f"wire_guard_{transport}{n_brokers}_{wire_impl}_"
        ),
        workload="pmf",
        workload_cfg=dict(SMOKE_WCFG),
        n_workers=SMOKE_P,
        total_steps=SMOKE_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.7,
        n_brokers=n_brokers,
        transport=transport,
        wire_impl=wire_impl,
        autotune=False,
        partitioner=partitioner,
        shard_split_bytes=shard_split_bytes,
        deadline_s=240.0,
    )
    wl = build_workload(job.workload, job.workload_cfg)
    live = run_job(job)

    rank = wl.cfg["rank"]
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=SMOKE_P,
            platform=Platform.MLLESS,
            consistency=cons.ConsistencyConfig(
                model=cons.Model.ISP, isp=ISPConfig(v=job.isp_v)
            ),
            sparse_model=True,
            wire_scheme=job.wire_scheme,
            n_redis=job.n_brokers,  # predicted topology == live topology
            cold_start_s=COLD_START_S,
            invocations_per_worker=1,
        ),
        grad_fn=wl.grad_fn,
        optimizer=optim.make(job.optimizer, job.lr),
        params=wl.params0,
        flops_per_sample=6 * rank * 3,
        update_nnz_fn=partial(
            lambda r, n, bsz: 2 * r * min(bsz, n), rank, wl.cfg["n_users"]
        ),
    )

    def batch_fn(step: int, n_workers: int):
        return wl.make_batch(wl.store.fetch_stacked(step, n_workers))

    simres = sim.run(batch_fn, wl.cfg["batch_size"], SMOKE_STEPS)
    return {
        "transport": transport,
        "wire_impl": wire_impl,
        "partitioner": partitioner,
        "topology_events": live["topology_events"],
        "topology_gen": live["topology_gen"],
        "encode_s_p50": _encode_p50(live["history"]),
        "wire_bytes_total": float(live["wire_bytes_total"]),
        "update_bytes_per_shard": live["broker_update_bytes_per_shard"],
        "dup_mismatches": live["dup_mismatches"],
        "chaos_events": live["chaos_events"],
        "wal_quarantined_bytes": live["wal_quarantined_bytes"],
        "final_params_sha256": final_params_digest(job),
        "cost_measured_over_predicted": (
            live["bill"]["total"] / max(simres.total_cost, 1e-12)
        ),
        "n_redis_billed": live["bill"]["n_redis"],
        "measured_step_s": live["measured_step_s"],
        "phase_s_mean": live["phase_s_mean"],
    }


def run_multijob_smoke() -> dict:
    """The fleet leg (DESIGN.md §14): the SAME smoke job packed with a
    second tenant on one shared pool.  Per-job key namespaces mean the
    co-tenant may not perturb a byte of job A's update stream nor a bit
    of its final parameters — both gate against the single-job leg."""
    from repro.runtime import (
        FaaSJobConfig, FleetConfig, final_params_digest, run_fleet,
    )

    root = tempfile.mkdtemp(prefix="wire_guard_fleet_")
    job_a = FaaSJobConfig(
        run_dir=os.path.join(root, "jobs", "a"),
        workload="pmf",
        workload_cfg=dict(SMOKE_WCFG),
        n_workers=SMOKE_P,
        total_steps=SMOKE_STEPS,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.08,
        isp_v=0.7,
        autotune=False,
        deadline_s=240.0,
    )
    job_b = FaaSJobConfig(
        run_dir=os.path.join(root, "jobs", "b"),
        workload="lr",
        workload_cfg={"n_samples": 2000, "batch_size": 128},
        n_workers=2,
        total_steps=6,
        checkpoint_every=100,
        optimizer="nesterov",
        lr=0.05,
        isp_v=0.7,
        autotune=False,
        deadline_s=240.0,
    )
    res = run_fleet(FleetConfig(
        run_dir=root, jobs={"a": job_a, "b": job_b},
    ))
    a = res["jobs"]["a"]
    return {
        "wire_bytes_total": float(a["wire_bytes_total"]),
        "dup_mismatches": res["dup_mismatches"],
        "final_params_sha256": final_params_digest(job_a),
        "packed_with": "lr",
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run")
    ap.add_argument("--headroom", type=float, default=2.0,
                    help="host-variance headroom recorded on the cost "
                    "ratios when updating the baseline (wire bytes are "
                    "deterministic and get none). The ratios scale with "
                    "host speed — re-record with --update on the runner "
                    "class that gates merges")
    ap.add_argument("--impl", default="pallas",
                    choices=("pallas", "auto", "none"),
                    help="codec backend for the alternate-impl leg: it "
                    "must reproduce the numpy leg's bytes AND final "
                    "parameters bit-for-bit ('none' skips the leg)")
    args = ap.parse_args()

    try:
        single = run_smoke(n_brokers=1)
        sharded = run_smoke(n_brokers=SMOKE_SHARDS)
        shm = run_smoke(n_brokers=SMOKE_SHARDS, transport="shm")
        ring = run_smoke(n_brokers=SMOKE_SHARDS, partitioner="ring",
                         shard_split_bytes=1024)
        multijob = run_multijob_smoke()
        alt_impl = (run_smoke(n_brokers=1, wire_impl=args.impl)
                    if args.impl != "none" else None)
    except Exception as e:  # noqa: BLE001 - CI wants a clean signal
        print(f"wire_guard: smoke run failed: {e}", file=sys.stderr)
        return 2

    cur = {
        "wire_bytes_total": single["wire_bytes_total"],
        "cost_measured_over_predicted": (
            single["cost_measured_over_predicted"]
        ),
        "wire_bytes_total_sharded": sharded["wire_bytes_total"],
        "cost_measured_over_predicted_sharded": (
            sharded["cost_measured_over_predicted"]
        ),
        "wire_bytes_total_shm": shm["wire_bytes_total"],
        "cost_measured_over_predicted_shm": (
            shm["cost_measured_over_predicted"]
        ),
    }
    print(json.dumps(
        {"single": single, "sharded": sharded, "shm": shm, "ring": ring,
         "multijob": multijob, "alt_impl": alt_impl},
        indent=1,
    ))

    # structural invariants need no baseline: neither the topology nor the
    # transport may change a byte (or a bit of the final parameters), the
    # per-shard split must be exact, and the replay ledger clean
    ok = True
    for name, run in (("sharded", sharded), ("shm", shm)):
        if run["wire_bytes_total"] != single["wire_bytes_total"]:
            print(
                f"wire_guard: REGRESSION: {name} wire_bytes_total "
                f"{run['wire_bytes_total']} != single-broker "
                f"{single['wire_bytes_total']} "
                f"({'transport' if name == 'shm' else 'topology'} "
                "changed the bytes)",
                file=sys.stderr,
            )
            ok = False
        if sum(run["update_bytes_per_shard"]) != int(
            run["wire_bytes_total"]
        ):
            print(
                f"wire_guard: REGRESSION: {name} per-shard broker-measured "
                f"bytes {run['update_bytes_per_shard']} do not sum to "
                f"{run['wire_bytes_total']}",
                file=sys.stderr,
            )
            ok = False
    if shm["update_bytes_per_shard"] != sharded["update_bytes_per_shard"]:
        print(
            "wire_guard: REGRESSION: shm per-shard split "
            f"{shm['update_bytes_per_shard']} != tcp sharded split "
            f"{sharded['update_bytes_per_shard']}",
            file=sys.stderr,
        )
        ok = False
    digests = {
        name: run["final_params_sha256"]
        for name, run in (("single", single), ("sharded", sharded),
                          ("shm", shm))
    }
    if len(set(digests.values())) != 1:
        print(
            "wire_guard: REGRESSION: final params differ across "
            f"transports/topologies: {digests}",
            file=sys.stderr,
        )
        ok = False
    if sharded["dup_mismatches"] or single["dup_mismatches"] \
            or shm["dup_mismatches"] or ring["dup_mismatches"] \
            or multijob["dup_mismatches"]:
        print("wire_guard: REGRESSION: dup_mismatches != 0",
              file=sys.stderr)
        ok = False
    # the chaos-dormancy guard (DESIGN.md §17): no --chaos means the fault
    # plane must be provably inert — zero fault events, zero quarantined WAL
    # bytes — on every default leg, so the exact-byte baseline below also
    # certifies that the injection hooks cost nothing when disarmed
    for name, run in (("single", single), ("sharded", sharded),
                      ("shm", shm), ("ring", ring)):
        if run["chaos_events"] or run["wal_quarantined_bytes"]:
            print(
                f"wire_guard: REGRESSION: {name} leg ran without --chaos "
                f"yet saw fault-plane activity (events="
                f"{run['chaos_events']}, wal_quarantined="
                f"{run['wal_quarantined_bytes']} B)",
                file=sys.stderr,
            )
            ok = False
    # the tuner-off guard (DESIGN.md §16): with --topology-tune off the
    # topology machinery must be provably inert on every default leg — no
    # re-shard events, generation pinned at 0 — so the exact-baseline gates
    # below really do certify the untouched default path
    for name, run in (("single", single), ("sharded", sharded),
                      ("shm", shm)):
        if run["topology_events"] or run["topology_gen"] != 0:
            print(
                f"wire_guard: REGRESSION: {name} leg ran with the tuner "
                f"off yet saw topology activity (events="
                f"{run['topology_events']}, gen={run['topology_gen']})",
                file=sys.stderr,
            )
            ok = False
    # the ring-layout leg: the consistent-hash partitioner + chunked
    # encoding (split=1024 B) legitimately changes WHERE bytes go and the
    # per-chunk codec choices (so wire_bytes_total differs from the
    # whole-leaf baseline by design) — but the math is layout-invariant:
    # identical final parameters, exact per-shard accounting, clean ledger
    if ring["final_params_sha256"] != single["final_params_sha256"]:
        print(
            "wire_guard: REGRESSION: ring-partitioner final params "
            f"{ring['final_params_sha256']} != greedy layout "
            f"{single['final_params_sha256']} (the shard layout leaked "
            "into the math)",
            file=sys.stderr,
        )
        ok = False
    if sum(ring["update_bytes_per_shard"]) != int(ring["wire_bytes_total"]):
        print(
            "wire_guard: REGRESSION: ring per-shard broker-measured bytes "
            f"{ring['update_bytes_per_shard']} do not sum to "
            f"{ring['wire_bytes_total']}",
            file=sys.stderr,
        )
        ok = False
    # the fleet leg: packing a co-tenant onto the pool may not change a
    # byte of the smoke job's update stream nor a bit of its parameters
    if multijob["wire_bytes_total"] != single["wire_bytes_total"]:
        print(
            "wire_guard: REGRESSION: multijob wire_bytes_total "
            f"{multijob['wire_bytes_total']} != single-job "
            f"{single['wire_bytes_total']} (a co-tenant changed the "
            "smoke job's bytes)",
            file=sys.stderr,
        )
        ok = False
    if multijob["final_params_sha256"] != single["final_params_sha256"]:
        print(
            "wire_guard: REGRESSION: multijob final params "
            f"{multijob['final_params_sha256']} != single-job "
            f"{single['final_params_sha256']} (a co-tenant perturbed "
            "the smoke job's math)",
            file=sys.stderr,
        )
        ok = False
    # the codec-impl leg (DESIGN.md §15): the fused Pallas encode/decode
    # path is an implementation of the SAME codec — identical bytes on the
    # wire, identical final parameters, same per-shard accounting.  A
    # kernel that rounds, orders, or packs one bit differently fails here.
    if alt_impl is not None:
        impl = alt_impl["wire_impl"]
        if alt_impl["wire_bytes_total"] != single["wire_bytes_total"]:
            print(
                f"wire_guard: REGRESSION: impl={impl} wire_bytes_total "
                f"{alt_impl['wire_bytes_total']} != numpy leg "
                f"{single['wire_bytes_total']} (the codec backend changed "
                "the bytes)",
                file=sys.stderr,
            )
            ok = False
        if (alt_impl["update_bytes_per_shard"]
                != single["update_bytes_per_shard"]):
            print(
                f"wire_guard: REGRESSION: impl={impl} per-shard split "
                f"{alt_impl['update_bytes_per_shard']} != numpy leg "
                f"{single['update_bytes_per_shard']}",
                file=sys.stderr,
            )
            ok = False
        if (alt_impl["final_params_sha256"]
                != single["final_params_sha256"]):
            print(
                f"wire_guard: REGRESSION: impl={impl} final params "
                f"{alt_impl['final_params_sha256']} != numpy leg "
                f"{single['final_params_sha256']} (the codec backend "
                "perturbed the math)",
                file=sys.stderr,
            )
            ok = False
        if alt_impl["dup_mismatches"]:
            print(f"wire_guard: REGRESSION: impl={impl} "
                  "dup_mismatches != 0", file=sys.stderr)
            ok = False
        print(
            f"wire_guard: encode p50 numpy {single['encode_s_p50'] * 1e3:.2f}"
            f" ms vs {impl} {alt_impl['encode_s_p50'] * 1e3:.2f} ms"
        )

    if args.update or not os.path.exists(BASELINE):
        base = {
            "wire_bytes_total": cur["wire_bytes_total"],
            "final_params_sha256": single["final_params_sha256"],
            "cost_measured_over_predicted": (
                cur["cost_measured_over_predicted"] * args.headroom
            ),
            "cost_measured_over_predicted_sharded": (
                cur["cost_measured_over_predicted_sharded"] * args.headroom
            ),
            "cost_measured_over_predicted_shm": (
                cur["cost_measured_over_predicted_shm"] * args.headroom
            ),
            "encode_s_p50": single["encode_s_p50"] * args.headroom,
            "note": (
                "wire_bytes_total is exact (deterministic seed, no "
                "auto-tuner; the sharded AND shm runs must match it "
                "bit-for-bit); the cost ratios carry the --headroom "
                "factor over the recording host's run"
            ),
        }
        with open(BASELINE, "w") as f:
            json.dump(base, f, indent=1)
        print(f"wire_guard: baseline written to {BASELINE}")
        return 0 if ok else 1

    with open(BASELINE) as f:
        base = json.load(f)
    # the bit-identity gates: the DEFAULT (isp) data path must reproduce
    # the recorded bytes and final parameters exactly — features that are
    # opt-in (SSP slack, codecs, transports) may add paths, never perturb
    # this one
    exact = {
        "wire_bytes_total": single["wire_bytes_total"],
        "final_params_sha256": single["final_params_sha256"],
    }
    for key, val in exact.items():
        if key not in base:
            print(f"wire_guard: baseline missing {key}; re-record "
                  "with --update", file=sys.stderr)
            ok = False
        elif val != base[key]:
            print(
                f"wire_guard: REGRESSION: default-path {key} {val!r} != "
                f"baseline {base[key]!r} (the default ISP data path must "
                "be bit-identical to the recorded baseline)",
                file=sys.stderr,
            )
            ok = False
        else:
            print(f"wire_guard: {key} bit-identical to baseline")
    checks = {
        "cost_measured_over_predicted": (
            cur["cost_measured_over_predicted"]
        ),
        "cost_measured_over_predicted_sharded": (
            cur["cost_measured_over_predicted_sharded"]
        ),
        "cost_measured_over_predicted_shm": (
            cur["cost_measured_over_predicted_shm"]
        ),
        # both alternate-leg byte totals gate against the SAME baseline
        # entry — they are required to be bit-equal to the single-broker
        # bytes
        "wire_bytes_total_sharded": cur["wire_bytes_total_sharded"],
        "wire_bytes_total_shm": cur["wire_bytes_total_shm"],
        # default-path encode-phase p50: a codec change that slows the
        # reference encoder structurally (not host noise — the baseline
        # carries --headroom) fails here
        "encode_s_p50": single["encode_s_p50"],
    }
    for key, val in checks.items():
        base_key = ("wire_bytes_total" if key.startswith("wire_bytes_total")
                    else key)
        if base_key not in base:
            print(f"wire_guard: baseline missing {base_key}; re-record "
                  "with --update", file=sys.stderr)
            ok = False
            continue
        ref = base[base_key]
        limit = ref * (1.0 + TOLERANCE)
        if val > limit:
            print(
                f"wire_guard: REGRESSION in {key}: "
                f"{val:.6g} > {ref:.6g} * {1 + TOLERANCE}\n"
                "wire_guard: if this host class legitimately differs from "
                "the baseline's, re-record with --update",
                file=sys.stderr,
            )
            ok = False
        else:
            print(f"wire_guard: {key} ok ({val:.6g} <= {limit:.6g})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
