"""Fig. 8: best loss achievable under a fixed budget, per system.

For each budget we run each system until its cumulative cost exceeds the
budget and record the best loss reached (and the max affordable execution
time — the numbers above the bars in the paper's figure).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    tuner,
    write_result,
)
from repro.core import billing as billing_lib
from repro.core import consistency as cons
from repro.core.simulator import Platform

P = 8
B = 2048
BUDGETS = (0.0005, 0.001, 0.002, 0.004)
MAX_STEPS = 150


def _cost_at(records, platform, n_workers_series, wall_series) -> np.ndarray:
    """Cumulative cost after each step under the platform's billing."""
    worker_s = np.cumsum(
        [r.wall_s * r.active_workers for r in records]
    )
    wall = np.cumsum([r.wall_s for r in records])
    if platform is Platform.SERVERFUL:
        return np.asarray([billing_lib.iaas_cost(P, w) for w in wall])
    return np.asarray([
        billing_lib.faas_cost([ws], w, 1).total
        for ws, w in zip(worker_s, wall)
    ])


def run() -> dict:
    systems = {
        "pytorch_like": dict(platform=Platform.SERVERFUL,
                             model=cons.Model.BSP, tuned=False),
        "pywren_like": dict(platform=Platform.PYWREN, model=cons.Model.BSP,
                            tuned=False),
        "mlless_bsp": dict(platform=Platform.MLLESS, model=cons.Model.BSP,
                           tuned=False),
        "mlless_all": dict(platform=Platform.MLLESS, model=cons.Model.ISP,
                           tuned=True),
    }
    rows = []
    for name, s in systems.items():
        sim = pmf_sim(P, platform=s["platform"], model=s["model"])
        res = sim.run(
            pmf_batch_fn(B), B, max_steps=MAX_STEPS,
            eval_fn=pmf_eval_fn(), tuner=tuner(P) if s["tuned"] else None,
        )
        cost = _cost_at(res.records, s["platform"], None, None)
        losses = np.asarray([r.loss for r in res.records])
        wall = np.cumsum([r.wall_s for r in res.records])
        for budget in BUDGETS:
            within = cost <= budget
            if not np.any(within):
                rows.append({"name": name, "budget": budget,
                             "best_loss": None, "max_time_s": 0.0})
                continue
            rows.append({
                "name": name,
                "budget": budget,
                "best_loss": float(losses[within].min()),
                "max_time_s": float(wall[within].max()),
            })
    write_result("fig8_cost_vs_loss", {"rows": rows})
    return {"rows": rows}


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        loss = "n/a" if r["best_loss"] is None else f"{r['best_loss']:.4f}"
        lines.append(
            f"fig8,{r['name']}@{r['budget']}$,{r['max_time_s']*1e6:.0f},"
            f"best_loss={loss}"
        )
    return lines
