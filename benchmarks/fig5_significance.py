"""Fig. 5: normalized execution time until convergence vs significance
threshold v, for PMF (MovieLens-like) and LR (Criteo-like dense + sparse).

Expectation (paper §6.2.1): time-to-loss drops as v grows (fewer bytes per
step), with diminishing/reversing returns once filtering hurts convergence;
the dense-LR job benefits more than sparse-LR (whose updates are already
sparse — the 'intrinsic filter').
"""

from __future__ import annotations

from benchmarks.common import (
    lr_batch_fn,
    lr_sim,
    pmf_batch_fn,
    pmf_eval_fn,
    pmf_sim,
    summarize,
    write_result,
)
from repro.core import consistency as cons

P = 8
B = 2048
THRESHOLDS = (0.0, 0.1, 0.3, 0.7, 1.5)


def _pmf_time(v: float) -> dict:
    model = cons.Model.BSP if v == 0.0 else cons.Model.ISP
    sim = pmf_sim(P, model=model, v=v)
    res = sim.run(pmf_batch_fn(B), B, max_steps=150, loss_threshold=1.05,
                  eval_fn=pmf_eval_fn())
    return summarize(f"pmf_v{v}", res)


def _lr_time(sparse: bool, v: float) -> dict:
    model = cons.Model.BSP if v == 0.0 else cons.Model.ISP
    sim = lr_sim(sparse, P, model=model, v=v)
    res = sim.run(lr_batch_fn(sparse, B), B, max_steps=150,
                  loss_threshold=0.55)
    tag = "sparse" if sparse else "dense"
    return summarize(f"lr_{tag}_v{v}", res)


def run() -> dict:
    rows = []
    for v in THRESHOLDS:
        rows.append(_pmf_time(v))
    for sparse in (False, True):
        for v in THRESHOLDS:
            rows.append(_lr_time(sparse, v))
    base = {r["name"]: r["time_to_loss_s"] for r in rows}
    for r in rows:
        job = r["name"].rsplit("_v", 1)[0]
        r["normalized_time"] = r["time_to_loss_s"] / base[f"{job}_v0.0"]
    write_result("fig5_significance", {"rows": rows})
    return {"rows": rows}


def report(out: dict) -> list[str]:
    lines = []
    for r in out["rows"]:
        lines.append(
            f"fig5,{r['name']},{r['time_to_loss_s']*1e6:.0f},"
            f"norm={r['normalized_time']:.3f}"
        )
    return lines
