"""Elastic pool transitions: auto-tuner evictions -> DP-axis re-meshing.

The MLLess auto-tuner (``core.autotuner``, paper §4.2) decides *when* to
shrink the worker pool; this module decides *what that means* on a pod
runtime:

1. **Weak-scaling batch contract** (paper §3.2): the global batch is always
   ``B_g = P * B`` — evicting a pod shrinks the batch, it never redistributes
   the evicted pod's shard (each worker owns its slice of the dataset).
2. **Mesh schedule**: a pool of P pods trains on mesh ``(P, data, model)``;
   P == 1 drops the pod axis entirely (``mesh_shape_for``), so the single-pod
   program contains no degenerate collectives.
3. **Reintegration** (paper §4.2 eviction policy): the leaving worker's
   state is folded back in before the re-mesh —
   * replica semantics: mean-preserving model averaging
     (``reintegrate_replicas``): survivors absorb the evicted replica with
     weight 1/P_old, so the pool-mean parameter vector is unchanged;
   * error-feedback semantics (the pod path): the evicted pods' residuals
     are flushed into the shared parameters (``apply_transition``), so no
     accumulated update mass is lost across the transition.
4. **Checkpoint-mediated restore**: a transition IS a restore — save under
   the old mesh, rebuild the smaller mesh, restore with the new shardings
   (``resharded_restore`` -> ``checkpoint.store.restore_with_sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.checkpoint import store as ckpt_store

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Static description of an elastic training pool.

    Attributes:
      initial_pods: P at job start (the auto-tuner only ever shrinks).
      per_pod_batch: B, each pod's fixed local batch (weak scaling).
      data: within-pod data-parallel axis size.
      model: within-pod tensor/expert-parallel axis size.
      min_pods: the auto-tuner's floor (paper: never below 1).
    """

    initial_pods: int
    per_pod_batch: int
    data: int = 1
    model: int = 1
    min_pods: int = 1

    def __post_init__(self):
        if self.initial_pods < 1 or self.per_pod_batch < 1:
            raise ValueError("initial_pods and per_pod_batch must be >= 1")
        if not 1 <= self.min_pods <= self.initial_pods:
            raise ValueError(
                f"min_pods must be in [1, {self.initial_pods}], "
                f"got {self.min_pods}"
            )

    def global_batch(self, pods: int) -> int:
        """B_g = P * B — the weak-scaling contract (paper §3.2)."""
        self.validate_pool(pods)
        return pods * self.per_pod_batch

    def mesh_shape(self, pods: int) -> tuple[int, ...]:
        self.validate_pool(pods)
        return mesh_shape_for(pods, data=self.data, model=self.model)

    def mesh_axes(self, pods: int) -> tuple[str, ...]:
        self.validate_pool(pods)
        return mesh_axes_for(pods)

    def validate_pool(self, pods: int) -> None:
        if not self.min_pods <= pods <= self.initial_pods:
            raise ValueError(
                f"pool size {pods} outside "
                f"[{self.min_pods}, {self.initial_pods}]"
            )


def mesh_shape_for(pods: int, data: int = 16, model: int = 16) -> tuple[int, ...]:
    """Device-mesh shape for a pool of ``pods``; P == 1 drops the pod axis."""
    if pods < 1:
        raise ValueError(f"pods must be >= 1, got {pods}")
    if pods == 1:
        return (data, model)
    return (pods, data, model)


def mesh_axes_for(pods: int) -> tuple[str, ...]:
    """Axis names matching ``mesh_shape_for``."""
    if pods == 1:
        return ("data", "model")
    return ("pod", "data", "model")


def make_mesh_for(pods: int, data: int = 1, model: int = 1):
    """Build the jax Mesh for a pool size (delegates to launch.mesh so the
    jax-version compat shim lives in exactly one place)."""
    from repro.launch.mesh import make_mesh

    return make_mesh(mesh_shape_for(pods, data, model), mesh_axes_for(pods))


@dataclasses.dataclass(frozen=True)
class PoolTransition:
    """One scale-in step: everything the runtime needs to re-mesh."""

    old_pods: int
    new_pods: int
    evicted: tuple[int, ...]  # pod indices leaving (highest indices first)
    old_global_batch: int
    new_global_batch: int
    old_mesh_shape: tuple[int, ...]
    new_mesh_shape: tuple[int, ...]


def plan_transition(
    plan: ElasticPlan, old_pods: int, new_pods: int
) -> PoolTransition:
    """Describe the old_pods -> new_pods shrink (evicts the top slots)."""
    plan.validate_pool(old_pods)
    plan.validate_pool(new_pods)
    if new_pods >= old_pods:
        raise ValueError(
            f"elastic transitions only shrink: {old_pods} -> {new_pods}"
        )
    return PoolTransition(
        old_pods=old_pods,
        new_pods=new_pods,
        evicted=tuple(range(new_pods, old_pods)),
        old_global_batch=plan.global_batch(old_pods),
        new_global_batch=plan.global_batch(new_pods),
        old_mesh_shape=plan.mesh_shape(old_pods),
        new_mesh_shape=plan.mesh_shape(new_pods),
    )


def transition_schedule(
    plan: ElasticPlan, pool_sizes: Sequence[int]
) -> list[PoolTransition]:
    """The full monotone shrink schedule through ``pool_sizes``.

    ``pool_sizes`` must start at ``plan.initial_pods`` and decrease; the
    auto-tuner produces exactly such a sequence (it never scales out).
    """
    sizes = list(pool_sizes)
    if not sizes or sizes[0] != plan.initial_pods:
        raise ValueError(
            f"schedule must start at initial_pods={plan.initial_pods}"
        )
    return [
        plan_transition(plan, a, b) for a, b in zip(sizes[:-1], sizes[1:])
    ]


# -- state surgery ------------------------------------------------------------


def shrink_pod_state(tree_pod: PyTree, new_pods: int) -> PyTree:
    """Keep the first ``new_pods`` slices of every (P, ...) leaf."""

    return jax.tree.map(lambda x: x[:new_pods], tree_pod)


def reintegrate_into(
    own: PyTree, leaving: PyTree, pool_before: jax.Array | float
) -> PyTree:
    """One survivor's mean-preserving pull of a leaving replica.

        x' = x + (x_leaving - x) / P_old

    Applied by every survivor, the pool-mean parameter vector is unchanged
    exactly (paper §4.2 eviction policy, mean-preserving form). This is the
    per-replica view of ``reintegrate_replicas``; the FaaS runtime's worker
    processes apply it to the flush payload a leaving peer publishes
    through the broker (``runtime.worker``).
    """
    return jax.tree.map(lambda x, l: x + (l - x) / pool_before, own, leaving)


def reintegrate_replicas(
    replicas: PyTree, evicted: int, active_mask: jax.Array
) -> PyTree:
    """Mean-preserving model averaging on eviction (replica semantics).

    The paper averages the leaving replica into every survivor; weighting
    the pull by 1/P_old keeps the pool-mean parameter vector invariant:

        x_p' = x_p + (x_evicted - x_p) / P_old
        mean_active(x') = mean_pool(x)   (exactly)

    ``replicas`` leaves have leading worker axis (P, ...); ``active_mask``
    is a bool (P,) with the evicted worker already cleared.
    """
    p_old = active_mask.shape[0]

    def leaf(x):
        leaving = jnp.broadcast_to(x[evicted][None], x.shape)
        mask = active_mask.reshape((-1,) + (1,) * (x.ndim - 1))
        pulled = reintegrate_into(x, leaving, p_old)
        return jnp.where(mask, pulled, x)

    return jax.tree.map(leaf, replicas)


def apply_transition(
    tr: PoolTransition,
    params: PyTree,
    opt_state_pod: PyTree,
    residual_pod: PyTree,
) -> tuple[PyTree, PyTree, PyTree]:
    """Error-feedback reintegration + state surgery for one shrink.

    The evicted pods' residuals are the update mass they accumulated but
    never sent; flushing them into the shared parameters is the error-
    feedback form of the paper's leaving-worker model averaging (nothing is
    lost across the re-mesh). Survivor slices of the per-pod optimizer
    state and residual are kept verbatim.
    """

    def flush(p, r):
        mass = jnp.sum(
            r[tr.new_pods:].astype(jnp.float32), axis=0
        )
        return (p.astype(jnp.float32) + mass).astype(p.dtype)

    params = jax.tree.map(flush, params, residual_pod)
    return (
        params,
        shrink_pod_state(opt_state_pod, tr.new_pods),
        shrink_pod_state(residual_pod, tr.new_pods),
    )


# -- checkpoint-mediated re-mesh ---------------------------------------------


def resharded_restore(
    directory: str,
    step: int,
    like: PyTree,
    pods: int,
    *,
    data: int = 1,
    model: int = 1,
    specs: Optional[PyTree] = None,
):
    """Restore a checkpoint under the mesh of a (possibly different) pool.

    Builds the ``mesh_shape_for(pods)`` mesh and places every leaf under a
    NamedSharding on it (replicated by default, or per-leaf ``specs``).
    This is the scale-in mechanism end-to-end: save under mesh A, shrink,
    restore under mesh B — ``jax.device_put`` reshards.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh_for(pods, data=data, model=model)
    if specs is None:
        specs = jax.tree.map(lambda _: P(), like)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return ckpt_store.restore_with_sharding(directory, step, like, shardings)
