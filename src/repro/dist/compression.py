"""Compressed ISP collectives over the pod axis (DESIGN.md §2).

This module is the error-feedback form of the MLLess significance filter:
parameters are shared across the data-parallel pod axis, every pod keeps a
private residual, and only the *significant* part of ``residual + update``
crosses the wire. The exchange is pure data-flow — a leading tensor dim of
size ``n_pods`` stands in for the pod collective, so the same function runs
under ``vmap`` on one chip, under GSPMD on a real multi-pod mesh, and in
unit tests with ``n_pods == 1`` (where Corollary 1 makes it BSP-exact at
v = 0).

Exchange schemes (``CompressionConfig.scheme``):

* ``dense``  — the filtered update is exchanged as a full dense tensor
  (all-reduce over 'pod'). Exact filter semantics, no wire saving — the
  paper's observation that arbitrary-sparsity updates don't compress a
  dense collective. This is the correctness baseline.
* ``topk``   — per pod, per ``block``-sized block, keep the ``budget``
  fraction of entries with the largest magnitude; everything else returns
  to the residual (error feedback — no update mass is ever lost).
* ``bitmap`` — exchange the significant entries as (bitmask, packed
  values): numerically identical to ``dense`` (the same entries move),
  only the wire encoding differs — the paper's Redis sparse encoding,
  collective form.

Byte accounting is NOT hand-rolled here: each scheme maps to a
``repro.wire`` codec (dense→dense, topk→sparse, bitmap→bitmap; override
with ``CompressionConfig.wire``) and the per-step ``wire_bytes`` stat is
computed from ``repro.wire.codec.leaf_nbytes`` — the same formula the
live FaaS runtime's encoder asserts against, so the bytes this module
reports to the simulator/auto-tuner equal the bytes the runtime would
measure, by construction (DESIGN.md §10).

The significance split itself reuses ``core.isp.significance_split`` (jnp
reference) or the fused Pallas kernel ``kernels.significance`` (the hot
path: one VMEM pass instead of >= 8 HBM passes), selected per call.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.isp import significance_split
from repro.kernels import wire_pack
from repro.kernels.significance import significance_filter
from repro.wire import codec as wire_codec

PyTree = Any

_SCHEMES = ("dense", "topk", "bitmap")
# exchange scheme -> default repro.wire encoding of what crosses the pod axis
_WIRE_OF = {"dense": "dense", "topk": "sparse", "bitmap": "bitmap"}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static exchange configuration (hashable: closed over by jit).

    Attributes:
      scheme: exchange scheme — 'dense', 'topk', or 'bitmap' (module doc).
      budget: topk only — fraction of entries kept per block (0 < b <= 1).
      block: topk only — block size for the block-local top-k (TPU-friendly
        multiples of 128; the compaction granularity of the exchange).
      wire: ``repro.wire`` codec the byte accounting charges for
        ('dense'|'sparse'|'bitmap'); None derives it from ``scheme``.
      fused: route the significance split through the Pallas kernel
        (``kernels.significance``) instead of the jnp reference.
      interpret: run the Pallas kernel in interpret mode (CPU validation).
    """

    scheme: str = "dense"
    budget: float = 0.01
    block: int = 128
    wire: Optional[str] = None
    fused: bool = False
    interpret: bool = False

    def __post_init__(self):
        if self.scheme not in _SCHEMES:
            raise ValueError(
                f"scheme must be one of {_SCHEMES}, got {self.scheme!r}"
            )
        if self.wire is not None and self.wire not in wire_codec.SCHEMES:
            raise ValueError(
                f"wire must be one of {wire_codec.SCHEMES}, got {self.wire!r}"
            )
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"budget must be in (0, 1], got {self.budget}")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")

    @property
    def wire_scheme(self) -> str:
        """The ``repro.wire`` codec this exchange is accounted as."""
        return self.wire or _WIRE_OF[self.scheme]

    def k_per_block(self, block: Optional[int] = None) -> int:
        """Entries kept per block under the topk budget (always >= 1)."""
        b = self.block if block is None else block
        return max(1, min(b, int(round(b * self.budget))))


def split_significant(
    u: jax.Array,
    x: jax.Array,
    r: jax.Array,
    v_t: jax.Array,
    *,
    floor: float = 1e-8,
    fused: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(sig, res) with sig + res == r + u; |acc| > v_t * max(|x|, floor).

    ``x`` may have fewer leading dims than ``u``/``r`` (shared params vs a
    pod-stacked update): it is broadcast. The fused path flattens the whole
    (pod-stacked) tensor into one Pallas grid, so the pod dim rides the
    same kernel launch.
    """
    x_b = jnp.broadcast_to(x, u.shape)
    if fused:
        return significance_filter(
            u, x_b, r, jnp.asarray(v_t, jnp.float32), floor=floor,
            interpret=interpret,
        )
    sig, res, _ = significance_split(r + u, x_b, v_t, floor)
    return sig, res


def _block_topk_mask(sig: jax.Array, cfg: CompressionConfig) -> jax.Array:
    """Boolean keep-mask of the per-block top-k |entries| of one pod slice.

    Flattens to (nb, block) with zero padding; padded entries have |0| and
    can only be selected when a block is all-zero, where keeping them is a
    no-op (0 moves 0 mass). Returns a mask of ``sig.shape``.
    """
    n = sig.size
    block = min(cfg.block, max(n, 1))
    k = cfg.k_per_block(block)
    flat = sig.reshape(-1)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    _, idx = jax.lax.top_k(jnp.abs(blocks), k)  # (nb, k)
    keep = jnp.zeros_like(blocks, dtype=jnp.bool_)
    keep = jnp.put_along_axis(keep, idx, True, axis=-1, inplace=False)
    # padded entries are never real mass; drop them from the mask
    if pad:
        valid = (jnp.arange(flat.shape[0]) < n).reshape(-1, block)
        keep = keep & valid
    return keep.reshape(-1)[:n].reshape(sig.shape)


def topk_combine(cfg: CompressionConfig, sig_pod: PyTree, n_pods: int) -> PyTree:
    """Row-top-k compact exchange, GSPMD-auto and sharding-preserving.

    Per leaf: (n_pods, *shape) pod-sharded significant updates -> per-pod
    top-k per LAST-AXIS ROW (values, indices) -> scan over pods slicing the
    compact arrays (only compact bytes cross 'pod') -> put_along_axis into
    a dense accumulator that keeps the leaf's natural leading-dim sharding.

    Two refuted formulations led here (EXPERIMENTS.md §Perf c2/c3): a
    replicated (nb, block) accumulator makes GSPMD reshard the dense tensor
    per pod, and ANY full flatten (`reshape(n_pods, -1)`) collapses the 2D
    parameter sharding, which GSPMD resolves by gathering the entire f32
    update across pods (51 GB/chip measured). Rows along the original last
    axis preserve every sharded dim.
    """

    def leaf(s):
        last = s.shape[-1]
        kk = cfg.k_per_block(last)
        _, idx = jax.lax.top_k(jnp.abs(s), kk)  # (P, *lead, kk)
        vals = jnp.take_along_axis(s, idx, axis=-1)

        def add_pod(acc, pi):
            v = jax.lax.dynamic_index_in_dim(vals, pi, 0, keepdims=False)
            i = jax.lax.dynamic_index_in_dim(idx, pi, 0, keepdims=False)
            upd = jnp.put_along_axis(
                jnp.zeros_like(acc), i, v, axis=-1, inplace=False
            )
            return acc + upd, None

        acc, _ = jax.lax.scan(
            add_pod, jnp.zeros(s.shape[1:], s.dtype), jnp.arange(n_pods)
        )
        return acc

    return jax.tree.map(leaf, sig_pod)


def isp_compressed_step(
    cfg: CompressionConfig,
    updates_pod: PyTree,
    params: PyTree,
    residual_pod: PyTree,
    v_t: jax.Array,
    *,
    floor: float = 1e-8,
) -> tuple[PyTree, PyTree, dict[str, jax.Array]]:
    """One error-feedback ISP exchange over the leading pod axis.

    Args:
      cfg: wire encoding configuration.
      updates_pod: per-pod local updates u_p, every leaf shaped (P, *s).
      params: shared parameters x (no pod axis) — the significance
        denominator AND the broadcast target.
      residual_pod: per-pod carried residuals r_p, leaves (P, *s).
      v_t: scalar significance threshold (v = 0 reduces to BSP exactly).
      floor: absolute-magnitude floor for |x| ~ 0 denominators.

    Returns:
      ``(combined, new_residual_pod, stats)`` where ``combined`` has the
      shape of ``params`` (the summed communicated mass to apply), and the
      invariant ``sent_p + new_residual_p == residual_p + update_p`` holds
      per pod for every leaf — error feedback conserves update mass under
      every scheme. ``stats`` carries ``sent_fraction`` (communicated
      entries / total entries) and ``wire_bytes`` under
      ``cfg.wire_scheme``'s ``repro.wire`` encoding — per pod, per leaf,
      the exact bytes the live runtime's encoder would produce.
    """
    treedef = jax.tree.structure(params)
    u_leaves = treedef.flatten_up_to(updates_pod)
    x_leaves = jax.tree.leaves(params)
    r_leaves = treedef.flatten_up_to(residual_pod)

    wire_scheme = cfg.wire_scheme
    combined, new_res = [], []
    n_sent = jnp.asarray(0.0, jnp.float32)
    n_total = 0
    wire = jnp.asarray(0.0, jnp.float32)
    for u, x, r in zip(u_leaves, x_leaves, r_leaves):
        sig, res = split_significant(
            u, x, r, v_t, floor=floor, fused=cfg.fused,
            interpret=cfg.interpret,
        )
        if cfg.scheme == "topk":
            keep = jax.vmap(lambda s: _block_topk_mask(s, cfg))(sig)
            sent = jnp.where(keep, sig, jnp.zeros_like(sig))
            res = res + (sig - sent)  # unsent significant mass feeds back
        else:
            sent = sig
        combined.append(jnp.sum(sent.astype(jnp.float32), axis=0)
                        .astype(x.dtype))
        new_res.append(res)
        if cfg.fused and sent.size > 0:
            # same count, via the pack kernel's tiled reduction — keeps the
            # whole hit-accounting path on the fused kernels when they are
            # selected (kernels/wire_pack.py, bit-identical to the jnp sum)
            hits = wire_pack.wire_nnz(
                sent.reshape(-1), interpret=cfg.interpret
            ).astype(jnp.float32)
        else:
            hits = jnp.sum((sent != 0).astype(jnp.float32))
        n_sent = n_sent + hits
        n_total += sent.size
        # shared-codec accounting (works on traced scalars): each pod ships
        # one encoded leaf, so the step costs P * fixed-part (dense bytes /
        # bitmap mask) plus the marginal per-entry bytes times total hits
        n_pods, leaf_n = sent.shape[0], int(sent.size // sent.shape[0])
        itemsize = x.dtype.itemsize
        fixed = wire_codec.leaf_nbytes(wire_scheme, leaf_n, 0, itemsize)
        marginal = (
            wire_codec.leaf_nbytes(wire_scheme, leaf_n, 1, itemsize) - fixed
        )
        wire = wire + jnp.asarray(
            float(n_pods * fixed), jnp.float32
        ) + hits * float(marginal)

    stats = {
        "sent_fraction": n_sent / jnp.maximum(float(n_total), 1.0),
        "wire_bytes": wire,
    }
    return (
        treedef.unflatten(combined),
        treedef.unflatten(new_res),
        stats,
    )


def apply_combined(params: PyTree, combined: PyTree) -> PyTree:
    """x' = x + sum_p sent_p in fp32, cast back to each leaf's dtype."""
    return jax.tree.map(
        lambda p, c: (
            p.astype(jnp.float32) + c.astype(jnp.float32)
        ).astype(p.dtype),
        params, combined,
    )
