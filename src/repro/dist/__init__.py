"""SPMD "pod" distribution layer: compressed collectives + elastic re-meshing.

MLLess's two contributions live in ``core`` in substrate-agnostic form (the
ISP significance filter, the scale-in auto-tuner). This package adapts them
to the accelerator runtime:

* ``dist.compression`` — the error-feedback ISP exchange across a leading
  pod axis, with scheme-dependent wire encodings (dense / topk / bitmap).
* ``dist.elastic``     — pool-size transitions: the auto-tuner's eviction
  decisions mapped onto DP-axis re-meshing, model-averaging reintegration,
  and the weak-scaling batch contract B_g = P * B.
"""

from repro.dist import compression, elastic

__all__ = ["compression", "elastic"]
