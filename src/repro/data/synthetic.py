"""Synthetic, statistically-matched stand-ins for the paper's datasets."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.lr import DenseBatch, SparseBatch
from repro.models.pmf import RatingsBatch


@dataclasses.dataclass(frozen=True)
class CriteoLikeConfig:
    """Criteo display-ads lookalike (paper: 47M samples, 13 num + 26 cat).

    We generate a planted-model classification task: a ground-truth weight
    vector draws labels through a logistic link, so BCE genuinely decreases
    under training and convergence thresholds are meaningful.
    """

    n_samples: int = 200_000
    n_numerical: int = 13
    n_categorical: int = 26
    hash_dim: int = 100_000  # paper's 1e5 hashing trick
    label_noise: float = 0.08
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MovieLensLikeConfig:
    """MovieLens lookalike: Zipf-popular users/movies, low-rank ground truth."""

    n_users: int = 10_681  # ML-10M dimensions by default
    n_movies: int = 71_567
    n_ratings: int = 400_000
    rank: int = 20
    rating_noise: float = 0.25
    seed: int = 0


def make_criteo_dense(cfg: CriteoLikeConfig) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x, y): x (N, 13) min-max-normalised, y (N,) in {0,1}."""
    rng = np.random.default_rng(cfg.seed)
    x = rng.lognormal(0.0, 1.0, size=(cfg.n_samples, cfg.n_numerical)).astype(
        np.float32
    )
    # min-max scaling — the paper's PyWren-IBM preprocessing step
    x = (x - x.min(0)) / np.maximum(x.max(0) - x.min(0), 1e-9)
    w_true = rng.normal(0.0, 2.0, size=cfg.n_numerical).astype(np.float32)
    logits = x @ w_true - (x @ w_true).mean()
    p = 1.0 / (1.0 + np.exp(-logits))
    y = (rng.uniform(size=cfg.n_samples) < p).astype(np.float32)
    flip = rng.uniform(size=cfg.n_samples) < cfg.label_noise
    y = np.where(flip, 1.0 - y, y).astype(np.float32)
    return x, y


def make_criteo_sparse(
    cfg: CriteoLikeConfig,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (idx, val, y): fixed-width hashed-sparse rows.

    Each sample has 13 numerical coordinates (indices 0..12) plus 26
    categorical hashes (Zipf-distributed over the remaining hash space),
    mirroring the paper's 'hashing trick' construction.
    """
    rng = np.random.default_rng(cfg.seed + 1)
    n, nnz = cfg.n_samples, cfg.n_numerical + cfg.n_categorical
    num_idx = np.tile(np.arange(cfg.n_numerical, dtype=np.int32), (n, 1))
    num_val = rng.lognormal(0.0, 1.0, size=(n, cfg.n_numerical)).astype(np.float32)
    num_val = (num_val - num_val.min(0)) / np.maximum(
        num_val.max(0) - num_val.min(0), 1e-9
    )
    # Zipf-ish categorical hashes (heads are hot, like real ad categoricals)
    zipf = rng.zipf(1.3, size=(n, cfg.n_categorical)).astype(np.int64)
    cat_idx = (
        cfg.n_numerical + (zipf * 2654435761 % (cfg.hash_dim - cfg.n_numerical))
    ).astype(np.int32)
    cat_val = np.ones((n, cfg.n_categorical), np.float32)
    idx = np.concatenate([num_idx, cat_idx], axis=1)
    val = np.concatenate([num_val, cat_val], axis=1)
    # planted model over the hashed space
    w_true = rng.normal(0.0, 1.0, size=cfg.hash_dim).astype(np.float32)
    logits = (w_true[idx] * val).sum(1)
    logits -= logits.mean()
    p = 1.0 / (1.0 + np.exp(-logits))
    y = (rng.uniform(size=n) < p).astype(np.float32)
    flip = rng.uniform(size=n) < cfg.label_noise
    y = np.where(flip, 1.0 - y, y).astype(np.float32)
    assert idx.shape == (n, nnz)
    return idx, val, y


def make_movielens(
    cfg: MovieLensLikeConfig,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (user, movie, rating) triples with a planted low-rank model."""
    rng = np.random.default_rng(cfg.seed + 2)
    # Zipf popularity for users and movies (heavy-tailed, like MovieLens)
    u = rng.zipf(1.2, size=cfg.n_ratings) % cfg.n_users
    m = rng.zipf(1.1, size=cfg.n_ratings) % cfg.n_movies
    U = rng.normal(0, 1.0 / np.sqrt(cfg.rank), size=(cfg.n_users, cfg.rank))
    M = rng.normal(0, 1.0 / np.sqrt(cfg.rank), size=(cfg.n_movies, cfg.rank))
    base = (U[u] * M[m]).sum(1)
    # map to the 0.5..5.0 star scale
    r = 2.75 + 1.5 * np.tanh(base) + rng.normal(0, cfg.rating_noise, cfg.n_ratings)
    r = np.clip(np.round(r * 2) / 2, 0.5, 5.0).astype(np.float32)
    return u.astype(np.int32), m.astype(np.int32), r


def dense_batch(x: np.ndarray, y: np.ndarray, sl: slice) -> DenseBatch:
    import jax.numpy as jnp

    return DenseBatch(x=jnp.asarray(x[sl]), y=jnp.asarray(y[sl]))


def sparse_batch(
    idx: np.ndarray, val: np.ndarray, y: np.ndarray, sl: slice
) -> SparseBatch:
    import jax.numpy as jnp

    return SparseBatch(
        idx=jnp.asarray(idx[sl]), val=jnp.asarray(val[sl]), y=jnp.asarray(y[sl])
    )


def ratings_batch(u: np.ndarray, m: np.ndarray, r: np.ndarray, sl: slice) -> RatingsBatch:
    import jax.numpy as jnp

    return RatingsBatch(
        user=jnp.asarray(u[sl]), movie=jnp.asarray(m[sl]), rating=jnp.asarray(r[sl])
    )
