"""Data pipeline: synthetic dataset generators + minibatch store.

The container is offline, so the paper's datasets (Criteo display ads,
MovieLens-10M/20M) are replaced by statistically-matched synthetic generators
(DESIGN.md §8.6): same dimensionality, hashing-trick sparsity, Zipf-heavy
user/item popularity. The *minibatch store* mimics the paper's IBM-COS layout:
the dataset is pre-partitioned into fixed-size minibatches addressed by index,
and workers fetch batches by (worker_id, step) — which is exactly the access
pattern the simulator's cost model charges for.
"""

from repro.data.synthetic import (  # noqa: F401
    CriteoLikeConfig,
    MovieLensLikeConfig,
    make_criteo_dense,
    make_criteo_sparse,
    make_movielens,
)
from repro.data.store import MinibatchStore  # noqa: F401
from repro.data.tokens import TokenPipeline, synthetic_token_batch  # noqa: F401
