"""Minibatch store — the object-storage access pattern of the paper.

MLLess pre-partitions the dataset into fixed-size minibatches in IBM COS and
each worker fetches ``batch[(worker_id * step) % n_batches]`` style slices per
iteration. We reproduce that layout: arrays are chunked once, then addressed
by integer batch id. Fetches are free on CPU but the *simulator* charges the
COS latency from ``core.billing.CommModel.cos_fetch_s`` per fetch, which is
what the paper's step-time decomposition needs.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np


class MinibatchStore:
    """Deterministic, shardable minibatch addressing over numpy arrays."""

    def __init__(self, arrays: Sequence[np.ndarray], batch_size: int):
        n = arrays[0].shape[0]
        for a in arrays:
            if a.shape[0] != n:
                raise ValueError("all arrays must share the leading dimension")
        self.arrays = list(arrays)
        self.batch_size = int(batch_size)
        self.n_samples = n
        self.n_batches = max(n // self.batch_size, 1)

    def fetch(self, batch_id: int) -> list[np.ndarray]:
        b = int(batch_id) % self.n_batches
        sl = slice(b * self.batch_size, (b + 1) * self.batch_size)
        return [a[sl] for a in self.arrays]

    def batch_for(self, worker: int, step: int, n_workers: int) -> int:
        """Round-robin partitioning: worker w at step t reads batch
        t * P + w — disjoint coverage per step, wrap-around epochs."""
        return (step * n_workers + worker) % self.n_batches

    def fetch_stacked(self, step: int, n_workers: int) -> list[np.ndarray]:
        """All P workers' minibatches for one step, stacked on axis 0:
        returns arrays shaped (P, B, ...) — the simulator's vmapped layout."""
        per_worker = [
            self.fetch(self.batch_for(w, step, n_workers)) for w in range(n_workers)
        ]
        return [np.stack([pw[i] for pw in per_worker]) for i in range(len(self.arrays))]

    def bytes_per_batch(self) -> int:
        return int(sum(a[: self.batch_size].nbytes for a in self.arrays))
