"""Token pipeline for the LM architectures.

Synthetic-but-structured token streams (Zipf unigram + Markov bigram mixing)
so LM training loss genuinely decreases during smoke runs, plus the
ShapeDtypeStruct factories used by the multi-pod dry-run. On a real cluster
this module is where a sharded sequence loader (e.g. array_record + per-host
sharding) plugs in; the interface — ``next_batch(step) -> dict`` with
(global_batch, seq_len) int32 arrays — is what the training loop consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    """Deterministic synthetic LM data with a learnable bigram structure."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order_mix: float = 0.7  # fraction of tokens drawn from bigram table

    def _tables(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # structure lives in a small head space
        unigram = 1.0 / np.arange(1, v + 1) ** 1.1
        unigram /= unigram.sum()
        succ = rng.integers(0, v, size=(v, 4))  # 4 plausible successors each
        return unigram, succ

    def next_batch(self, step: int) -> dict[str, jax.Array]:
        rng = np.random.default_rng(hash((self.seed, step)) % (2**31))
        unigram, succ = self._tables()
        v = unigram.size
        b, s = self.global_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=b, p=unigram)
        use_bigram = rng.uniform(size=(b, s)) < self.markov_order_mix
        succ_pick = rng.integers(0, succ.shape[1], size=(b, s))
        iid = rng.choice(v, size=(b, s), p=unigram)
        for t in range(s):
            prev = toks[:, t]
            bi = succ[prev, succ_pick[:, t]]
            toks[:, t + 1] = np.where(use_bigram[:, t], bi, iid[:, t])
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }


def synthetic_token_batch(
    vocab_size: int, seq_len: int, global_batch: int, seed: int = 0
) -> dict[str, jax.Array]:
    return TokenPipeline(vocab_size, seq_len, global_batch, seed).next_batch(0)
