"""phi3.5-moe-42b-a6.6b [moe]: 32L, d_model=4096, 32H (GQA kv=8), d_ff=6400,
vocab=32064. MoE 16 experts top-2, full attention.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.models.config import (
    ArchConfig, BlockSpec, FF, Mixer, MoEConfig, uniform_groups,
)

_SB = BlockSpec(Mixer.GLOBAL_ATTN, FF.MOE)

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    groups=uniform_groups(_SB, 32),
    moe=MoEConfig(n_experts=16, top_k=2),
    sub_quadratic=False,  # full attention -> long_500k skipped
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    groups=uniform_groups(_SB, 2),
    moe=MoEConfig(n_experts=4, top_k=2),
    max_seq_len=128,
    sub_quadratic=False,
)
