"""phi4-mini-3.8b [dense]: 32L, d_model=3072, 24H (GQA kv=8), d_ff=8192,
vocab=200064. RoPE + SwiGLU + GQA. [arXiv:2412.08905; hf]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, uniform_groups

_SB = BlockSpec(Mixer.GLOBAL_ATTN, FF.SWIGLU)

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    groups=uniform_groups(_SB, 32),
    sub_quadratic=False,
)

SMOKE = ArchConfig(
    name="phi4-mini-smoke",
    family="dense",
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    groups=uniform_groups(_SB, 2),
    max_seq_len=128,
    sub_quadratic=False,
)
