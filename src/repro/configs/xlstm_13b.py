"""xlstm-1.3b [ssm]: 48 blocks, d_model=2048, 4H, vocab=50304, d_ff=0
(blocks carry internal up/down projections). 7:1 mLSTM:sLSTM pattern
(xLSTM[7:1]); 48 = 6 superblocks of (7 mLSTM + 1 sLSTM).
[arXiv:2405.04517; unverified]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, pattern_groups

_M = BlockSpec(Mixer.MLSTM, FF.NONE, rope_base=None)
_S = BlockSpec(Mixer.SLSTM, FF.NONE, rope_base=None)
_PATTERN = (_M,) * 7 + (_S,)

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    groups=pattern_groups(_PATTERN, 48),
    max_seq_len=1_048_576,  # constant-size recurrent state
    sub_quadratic=True,
    # pf=1.0 puts per-block params at ~6*d^2 -> 1.33B total, matching the
    # 1.3b nameplate (xLSTM's pf=2 with low-rank qk would need rank plumbing)
    lstm_proj_factor=1.0,
)

SMOKE = ArchConfig(
    name="xlstm-smoke",
    family="ssm",
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    groups=pattern_groups((_M, _S), 2),
    max_seq_len=128,
    sub_quadratic=True,
    lstm_proj_factor=2.0,
)
