"""qwen1.5-32b [dense]: 64L, d_model=5120, 40H (MHA kv=40), d_ff=27392,
vocab=152064. QKV bias (the Qwen1.5 signature), RoPE, SwiGLU.
[hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, uniform_groups

_SB = BlockSpec(Mixer.GLOBAL_ATTN, FF.SWIGLU)

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    groups=uniform_groups(_SB, 64),
    sub_quadratic=False,
)

SMOKE = ArchConfig(
    name="qwen1.5-smoke",
    family="dense",
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    groups=uniform_groups(_SB, 2),
    max_seq_len=128,
    sub_quadratic=False,
)
