"""paligemma-3b [vlm]: 18L gemma backbone, d_model=2048, 8H (MQA kv=1),
d_ff=16384, vocab=257216. SigLIP vision tower is a STUB (input_specs provides
256 precomputed patch embeddings); prefix-LM masking over the vision prefix.
[arXiv:2407.07726; hf]"""

from repro.models.config import (
    ArchConfig, BlockSpec, EncoderConfig, FF, Mixer, uniform_groups,
)

_SB = BlockSpec(Mixer.GLOBAL_ATTN, FF.GEGLU)

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    groups=uniform_groups(_SB, 18),
    encoder=EncoderConfig(n_layers=0, ctx_len=256),  # stub: embeds arrive
    prefix_lm=True,
    sub_quadratic=False,  # full attention -> long_500k skipped
)

SMOKE = ArchConfig(
    name="paligemma-smoke",
    family="vlm",
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    groups=uniform_groups(_SB, 2),
    encoder=EncoderConfig(n_layers=0, ctx_len=8),
    prefix_lm=True,
    max_seq_len=128,
    sub_quadratic=False,
)
