"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H, d_ff=2048,
vocab=51865. Encoder-decoder; conv audio frontend is a STUB (input_specs
provides precomputed 1500-frame embeddings). LayerNorm + GELU, no RoPE
(whisper uses sinusoidal enc + learned dec positions; we use sinusoidal both
sides — positional-table choice does not affect shapes/flops).
[arXiv:2212.04356; unverified]
"""

from repro.models.config import (
    ArchConfig, BlockSpec, EncoderConfig, FF, Mixer,
)

# decoder layer = self-attn, cross-attn, then GELU FF
_DEC_SB = (
    BlockSpec(Mixer.GLOBAL_ATTN, FF.NONE, rope_base=None),
    BlockSpec(Mixer.CROSS_ATTN, FF.GELU, rope_base=None),
)

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    groups=((_DEC_SB, 6),),
    norm="layernorm",
    encoder=EncoderConfig(n_layers=6, ctx_len=1500),
    tie_embeddings=True,
    max_seq_len=32_768,  # assigned shapes exceed whisper's native 448
    sub_quadratic=False,  # full attention -> long_500k skipped
)

SMOKE = ArchConfig(
    name="whisper-base-smoke",
    family="audio",
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    groups=((_DEC_SB, 2),),
    norm="layernorm",
    encoder=EncoderConfig(n_layers=2, ctx_len=16),
    max_seq_len=128,
    sub_quadratic=False,
)
