"""recurrentgemma-9b [hybrid]: 38L, d_model=4096, 16H (MQA kv=1), d_ff=12288,
vocab=256000. Griffin pattern: (RG-LRU, RG-LRU, local-attn) repeated —
1 attention : 2 recurrent; window 2048; GeGLU. 38 = 12*3 + 2 remainder
recurrent layers (pattern_groups handles the tail).
[arXiv:2402.19427; unverified]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, pattern_groups

_REC = BlockSpec(Mixer.RGLRU, FF.GEGLU, rope_base=None)
_ATT = BlockSpec(Mixer.LOCAL_ATTN, FF.GEGLU, window=2048)
_PATTERN = (_REC, _REC, _ATT)

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    groups=pattern_groups(_PATTERN, 38),
    max_seq_len=1_048_576,  # recurrent state is O(1) in sequence length
    sub_quadratic=True,
)

_SM = (
    BlockSpec(Mixer.RGLRU, FF.GEGLU, rope_base=None),
    BlockSpec(Mixer.LOCAL_ATTN, FF.GEGLU, window=16),
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    groups=pattern_groups(_SM, 4),
    max_seq_len=128,
    sub_quadratic=True,
)
