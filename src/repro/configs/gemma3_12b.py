"""gemma3-12b [dense]: 48L, d_model=3840, 16H (GQA kv=8), d_ff=15360,
vocab=262144. 5:1 local:global attention (window 1024, RoPE base 10k local /
1M global), 128k context, head_dim=256, GeGLU.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, pattern_groups

_LOCAL = BlockSpec(Mixer.LOCAL_ATTN, FF.GEGLU, window=1024, rope_base=10_000.0)
_GLOBAL = BlockSpec(Mixer.GLOBAL_ATTN, FF.GEGLU, rope_base=1_000_000.0)
_PATTERN = (_LOCAL,) * 5 + (_GLOBAL,)  # 5:1, 48 layers = 8 superblocks

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15_360,
    vocab_size=262_144,
    head_dim=256,
    groups=pattern_groups(_PATTERN, 48),
    max_seq_len=131_072,
    # SWA-dominant (5/6 of layers); global layers are O(S) per decode step
    sub_quadratic=True,
)

_SM_PATTERN = (
    BlockSpec(Mixer.LOCAL_ATTN, FF.GEGLU, window=16, rope_base=10_000.0),
    BlockSpec(Mixer.GLOBAL_ATTN, FF.GEGLU, rope_base=1_000_000.0),
)

SMOKE = ArchConfig(
    name="gemma3-smoke",
    family="dense",
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    groups=pattern_groups(_SM_PATTERN, 4),
    max_seq_len=128,
    sub_quadratic=True,
)
