"""starcoder2-7b [dense]: 32L, d_model=4608, 36H (GQA kv=4), d_ff=18432,
vocab=49152. GQA + RoPE; plain GELU MLP + LayerNorm (starcoder2 family).
[arXiv:2402.19173; hf]"""

from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, uniform_groups

_SB = BlockSpec(Mixer.GLOBAL_ATTN, FF.GELU)

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    norm="layernorm",
    groups=uniform_groups(_SB, 32),
    sub_quadratic=False,
)

SMOKE = ArchConfig(
    name="starcoder2-smoke",
    family="dense",
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    norm="layernorm",
    groups=uniform_groups(_SB, 2),
    max_seq_len=128,
    sub_quadratic=False,
)
