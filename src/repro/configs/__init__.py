"""Architecture registry: the 10 assigned archs + the paper's own jobs.

``get_arch(name)`` returns the full-size ArchConfig; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests (small width/depth,
few experts, tiny vocab) — the full configs are exercised only via the
allocation-free dry-run.
"""

from __future__ import annotations

from repro.models.config import ArchConfig

from repro.configs import (
    whisper_base,
    phi4_mini,
    gemma3_12b,
    qwen15_32b,
    starcoder2_7b,
    mixtral_8x22b,
    phi35_moe,
    recurrentgemma_9b,
    xlstm_13b,
    paligemma_3b,
)

_MODULES = {
    "whisper-base": whisper_base,
    "phi4-mini-3.8b": phi4_mini,
    "gemma3-12b": gemma3_12b,
    "qwen1.5-32b": qwen15_32b,
    "starcoder2-7b": starcoder2_7b,
    "mixtral-8x22b": mixtral_8x22b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "recurrentgemma-9b": recurrentgemma_9b,
    "xlstm-1.3b": xlstm_13b,
    "paligemma-3b": paligemma_3b,
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    cfg = _MODULES[name].CONFIG
    cfg.validate()
    return cfg


def get_smoke(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    cfg = _MODULES[name].SMOKE
    cfg.validate()
    return cfg
