"""mixtral-8x22b [moe]: 56L, d_model=6144, 48H (GQA kv=8), d_ff=16384,
vocab=32768. MoE 8 experts top-2, sliding-window attention (4096).
This is the paper-representative sparse-regime arch (DESIGN.md §4): expert
gradients are step-sparse exactly like MLLess's hashing-trick LR.
[arXiv:2401.04088; hf]"""

from repro.models.config import (
    ArchConfig, BlockSpec, FF, Mixer, MoEConfig, uniform_groups,
)

_SB = BlockSpec(Mixer.LOCAL_ATTN, FF.MOE, window=4096)

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16_384,
    vocab_size=32_768,
    groups=uniform_groups(_SB, 56),
    moe=MoEConfig(n_experts=8, top_k=2),
    max_seq_len=65_536,
    sub_quadratic=True,  # SWA
)

SMOKE = ArchConfig(
    name="mixtral-smoke",
    family="moe",
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    groups=uniform_groups(
        BlockSpec(Mixer.LOCAL_ATTN, FF.MOE, window=16), 2
    ),
    moe=MoEConfig(n_experts=4, top_k=2),
    max_seq_len=128,
    sub_quadratic=True,
)
