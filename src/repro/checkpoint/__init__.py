"""Checkpointing: deterministic step-indexed save/restore with manifests.

Fault-tolerance contract (DESIGN.md §5): training can be killed at any step
boundary and resumed bit-exactly from the latest complete checkpoint; elastic
re-meshing (the scale-in auto-tuner's mechanism) is "restore under a
different mesh" — arrays are saved mesh-agnostic (fully addressable numpy)
and re-placed with the new mesh's NamedSharding at load.

Layout:  <dir>/step_<n>/manifest.json + arrays.npz
Writes are atomic: tmp dir + rename, so a crash mid-write never corrupts the
latest checkpoint.
"""

from repro.checkpoint.store import (  # noqa: F401
    CheckpointCorruption,
    all_steps,
    latest_step,
    restore,
    restore_latest_valid,
    save,
    restore_with_sharding,
)
