"""Atomic pytree checkpoint store (npz + json manifest)."""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(directory: str, step: int, tree: PyTree, extra: Optional[dict] = None) -> str:
    """Atomically write ``tree`` as checkpoint ``step``. Returns the path."""
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    # npz cannot hold bfloat16: store the raw bits as uint16; the true
    # dtype is in the manifest and restored on load
    stored = {
        k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
        for k, v in flat.items()
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    dtypes = manifest.get("dtypes", {})
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in flat_like:
        key = _SEP.join(_path_part(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint {path} missing {key}")
        arr = arrays[key]
        if dtypes.get(key) == "bfloat16":  # stored as uint16 bits
            import ml_dtypes  # via jax

            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint {arr.shape} != expected {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out)


def restore_with_sharding(
    directory: str, step: int, like: PyTree, shardings: PyTree
) -> PyTree:
    """Elastic restore: place restored arrays under (possibly new) shardings.

    This is the scale-in / scale-out mechanism: save under mesh A, build mesh
    B, restore with B's NamedShardings — jax.device_put reshards.
    """
    host = restore(directory, step, like)
    return jax.tree.map(jax.device_put, host, shardings)


def manifest_extra(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:010d}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("extra", {})
