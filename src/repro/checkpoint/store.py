"""Atomic pytree checkpoint store (npz + json manifest).

Concurrency contract (exercised by the FaaS runtime, where several worker
*processes* write and restore snapshots concurrently):

* **Writers never collide**: each ``save`` stages into a private
  ``step_XXX.tmp-<pid>-<nonce>`` directory and installs it with an atomic
  ``os.rename`` — two processes saving the same tag can interleave freely
  and the final directory is always one writer's complete output, never a
  torn mix.
* **Readers never see partial state**: ``restore`` only ever opens the
  installed directory; a reader racing a replace (rename-aside + rename-in)
  can momentarily observe the tag missing and retries briefly.
* ``latest_step`` ignores staging/aside directories, so a crash mid-save
  (SIGKILL'd worker) leaves at worst dead ``.tmp`` litter, never a
  half-visible checkpoint.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import time
import uuid
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "/"
_STEP_RE = re.compile(r"^step_(\d{10})$")


class CheckpointCorruption(Exception):
    """A checkpoint's stored arrays do not match their manifest digest."""


# fault-injection seam (runtime/faults.py, DESIGN.md §17): called with the
# staging directory after the npz is written but BEFORE the atomic
# install.  Raising OSError here simulates ENOSPC at the worst moment —
# the staged bytes exist but must never become visible.  None = dormant.
_write_fault_hook = None


def install_write_fault_hook(fn) -> None:
    global _write_fault_hook
    _write_fault_hook = fn


def clear_write_fault_hook() -> None:
    global _write_fault_hook
    _write_fault_hook = None


def _content_digest(stored: dict[str, np.ndarray]) -> str:
    """sha256 over the stored (npz-encoded) arrays in sorted key order —
    the integrity witness verified on every restore."""
    h = hashlib.sha256()
    for k in sorted(stored):
        h.update(k.encode("utf-8"))
        v = stored[k]
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()


def path_key(path) -> str:
    """Canonical '/'-joined key of one tree_flatten_with_path entry.

    The single source of truth for pytree-leaf naming: checkpoint manifests
    and the runtime's wire metadata (``runtime.protocol``) both use it, so
    the two layouts can never drift apart.
    """
    return _SEP.join(_path_part(p) for p in path)


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_key(path)] = np.asarray(leaf)
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _install(tmp: str, final: str) -> None:
    """Atomically make ``tmp`` the contents of ``final``.

    POSIX cannot rename over a non-empty directory, so replacing an
    existing checkpoint moves the old one aside first; a concurrent reader
    retries the brief not-found window, and a concurrent writer that loses
    the race simply installs over us the same way.
    """
    last: Optional[OSError] = None
    for _ in range(100):
        try:
            os.rename(tmp, final)
            return
        except OSError as e:
            last = e
        if os.path.isdir(final):
            aside = final + f".old-{uuid.uuid4().hex[:8]}"
            try:
                os.rename(final, aside)
            except OSError:
                continue  # another writer swapped in between; retry install
            shutil.rmtree(aside, ignore_errors=True)
        # else: a concurrent writer moved final aside between our failed
        # rename and now — the next rename attempt can win the slot
    raise OSError(f"could not install checkpoint at {final}") from last


def save(directory: str, step: int, tree: PyTree, extra: Optional[dict] = None) -> str:
    """Atomically write ``tree`` as checkpoint ``step``. Returns the path.

    Safe under concurrent writers of the same ``(directory, step)`` tag and
    under readers restoring while a writer replaces the tag.
    """
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + f".tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    try:
        flat = _flatten_with_paths(tree)
        # npz cannot hold bfloat16: store the raw bits as uint16; the true
        # dtype is in the manifest and restored on load
        stored = {
            k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
            for k, v in flat.items()
        }
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "digest": _content_digest(stored),
            "extra": extra or {},
        }
        # the manifest rides INSIDE the npz too: restore then needs a single
        # file open, so a concurrent replace can never hand it one version's
        # manifest with another version's arrays
        stored["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode("utf-8"), dtype=np.uint8
        )
        np.savez(os.path.join(tmp, "arrays.npz"), **stored)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if _write_fault_hook is not None:
            _write_fault_hook(tmp)
        _install(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m:  # staging (.tmp-*) and aside (.old-*) dirs never match
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _restore_once(path: str, like: PyTree) -> PyTree:
    arrays = np.load(os.path.join(path, "arrays.npz"))
    if "__manifest__" in arrays:  # single-open read: immune to replaces
        manifest = json.loads(arrays["__manifest__"].tobytes().decode("utf-8"))
    else:  # pre-embedding checkpoints
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    dtypes = manifest.get("dtypes", {})
    if "digest" in manifest:  # pre-digest checkpoints skip verification
        got = _content_digest(
            {k: arrays[k] for k in manifest["keys"] if k in arrays}
        )
        if got != manifest["digest"] or any(
            k not in arrays for k in manifest["keys"]
        ):
            raise CheckpointCorruption(
                f"checkpoint {path}: content digest mismatch "
                f"(manifest {manifest['digest'][:12]}…, stored {got[:12]}…)"
            )
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in flat_like:
        key = _SEP.join(_path_part(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint {path} missing {key}")
        arr = arrays[key]
        if dtypes.get(key) == "bfloat16":  # stored as uint16 bits
            import ml_dtypes  # via jax

            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint {arr.shape} != expected {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), out)


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated).

    Retries the brief not-found window a concurrent replace opens (the old
    directory moves aside before the new one moves in).
    """
    path = os.path.join(directory, f"step_{step:010d}")
    last: Optional[Exception] = None
    for _ in range(40):
        try:
            return _restore_once(path, like)
        except FileNotFoundError as e:
            last = e
            time.sleep(0.025)
    raise FileNotFoundError(f"checkpoint {path} never became readable") from last


def all_steps(directory: str) -> list[int]:
    """Every installed checkpoint generation, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def restore_latest_valid(
    directory: str, like: PyTree
) -> tuple[Optional[int], Optional[PyTree]]:
    """Restore the newest checkpoint whose content digest verifies,
    falling back generation by generation past corrupt ones (every
    generation is retained precisely so this walk has somewhere to go).
    Returns ``(step, tree)`` — ``(None, None)`` when no valid generation
    exists (cold start)."""
    for step in reversed(all_steps(directory)):
        path = os.path.join(directory, f"step_{step:010d}")
        try:
            return step, _restore_once(path, like)
        except FileNotFoundError:
            # racing a concurrent replace of this tag: the standard
            # retry window, then fall through to the previous generation
            try:
                return step, restore(directory, step, like)
            except (FileNotFoundError, CheckpointCorruption,
                    KeyError, ValueError):
                continue
        except (CheckpointCorruption, KeyError, ValueError) as e:
            print(f"checkpoint {path}: unusable ({e}); "
                  f"falling back to previous generation", flush=True)
            continue
    return None, None


def restore_with_sharding(
    directory: str, step: int, like: PyTree, shardings: PyTree
) -> PyTree:
    """Elastic restore: place restored arrays under (possibly new) shardings.

    This is the scale-in / scale-out mechanism: save under mesh A, build mesh
    B, restore with B's NamedShardings — jax.device_put reshards.
    """
    host = restore(directory, step, like)
    return jax.tree.map(jax.device_put, host, shardings)


def manifest_extra(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:010d}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("extra", {})
