import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first executable statements — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices. Do NOT export this flag anywhere else (smoke tests and
benchmarks must see 1 device).

Per cell this driver:
  1. builds the mesh (16x16 single-pod or 2x16x16 multi-pod),
  2. resolves abstract params/optimizer/cache/batch structs + shardings
     (zero allocation — everything is ShapeDtypeStruct),
  3. jit-lowers the real step function (the same one the drivers run),
  4. compiles, prints memory_analysis() (proof-of-fit) and cost_analysis(),
  5. parses collective wire bytes from the optimized HLO,
  6. writes the roofline record to results/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
      --mesh multi --mode isp-topk --budget 0.01
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES
from repro.core.isp import ISPConfig
from repro.dist.compression import CompressionConfig
from repro.launch.hloanalysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops, parse_collectives
from repro.launch.specs import build_cell, opt_state_defs
from repro.launch.steps import (
    make_decode_step,
    make_isp_train_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import params as pdefs
from repro.models.config import SHAPES, shape_applicable
from repro.configs import get_arch
from repro import optim


def _shardings(mesh, specs):
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def dataclasses_replace_policy_strip_pod(lm):
    """LM with 'pod' removed from every policy axis (for the ISP step's
    per-pod inner function, where 'pod' is shard_map-manual)."""
    import dataclasses

    def strip(ax):
        if ax == "pod":
            return None
        if isinstance(ax, tuple):
            kept = tuple(a for a in ax if a != "pod")
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return ax

    pol = lm.policy
    fields = {}
    for f in dataclasses.fields(pol):
        v = getattr(pol, f.name)
        if f.name in ("batch", "moe_group_ax", "kv_seq"):
            v = strip(v)
        fields[f.name] = v
    fields["moe_groups"] = (
        max(1, pol.moe_groups // lm.policy.mesh.shape.get("pod", 1))
        if pol.moe_groups > 1 else pol.moe_groups
    )
    return dataclasses.replace(lm, policy=type(pol)(**fields))


_ISP_SCHEMES = {"isp-dense": "dense", "isp-topk": "topk",
                "isp-bitmap": "bitmap"}


def lower_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    mode: str = "bsp",
    budget: float = 0.01,
    n_pods: Optional[int] = None,
):
    """Returns (lowered, compiled, cell, mesh). Raises on inapplicable.

    ``n_pods`` overrides the production mesh with an elastic pool size
    (``dist.elastic.mesh_shape_for`` at 16x16 chips per pod) — the shape a
    scaled-in job re-lowers for after an auto-tuner eviction.
    """
    from jax.sharding import PartitionSpec as P

    if n_pods is not None:
        from repro.dist.elastic import make_mesh_for

        mesh = make_mesh_for(n_pods, data=16, model=16)
        multi_pod = n_pods > 1
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch_name, shape_name, mesh)
    lm = cell.lm
    optimizer = optim.make("adam", 1e-3)

    p_structs = cell.param_structs()
    p_specs = cell.param_specs()
    b_shardings = _shardings(mesh, cell.batch_specs)

    if cell.shape.kind == "train":
        o_defs = opt_state_defs(cell.param_defs)
        o_structs = pdefs.to_struct(o_defs)
        o_specs = pdefs.to_specs(o_defs)
        if mode == "bsp":
            step = make_train_step(lm, optimizer)
            jitted = jax.jit(
                step,
                in_shardings=(
                    _shardings(mesh, p_specs),
                    _shardings(mesh, o_specs),
                    b_shardings,
                ),
                donate_argnums=(0, 1),
            )
            args = (p_structs, o_structs, cell.batch_structs)
        elif mode.startswith("isp"):
            assert multi_pod, "ISP mode compresses across the pod axis"
            n_pods = mesh.shape["pod"]
            scheme = _ISP_SCHEMES.get(mode, "dense")
            # inside shard_map over 'pod' the pod axis is MANUAL — the
            # model's sharding constraints must not mention it
            lm_inner = dataclasses_replace_policy_strip_pod(lm)
            step = make_isp_train_step(
                lm_inner, optimizer, mesh,
                ISPConfig(v=0.7),
                CompressionConfig(scheme=scheme, budget=budget),
            )
            lift = lambda d: pdefs.stack(d, n_pods)

            def podspec(defs):
                return jax.tree.map(
                    lambda x: type(x)(*(("pod",) + tuple(x)[1:])),
                    pdefs.to_specs(defs),
                    is_leaf=lambda s: isinstance(s, P),
                )

            o_defs_pod = lift(o_defs)
            r_defs_pod = lift(cell.param_defs)
            jitted = jax.jit(
                step,
                in_shardings=(
                    _shardings(mesh, p_specs),
                    _shardings(mesh, podspec(o_defs_pod)),
                    _shardings(mesh, podspec(r_defs_pod)),
                    b_shardings,
                ),
                donate_argnums=(0, 1, 2),
            )
            args = (
                p_structs,
                pdefs.to_struct(o_defs_pod),
                pdefs.to_struct(r_defs_pod),
                cell.batch_structs,
            )
        else:
            raise ValueError(mode)
    elif cell.shape.kind == "prefill":
        step = make_prefill_step(lm)
        c_structs = pdefs.to_struct(cell.cache_defs)
        c_specs = pdefs.to_specs(cell.cache_defs)
        jitted = jax.jit(
            step,
            in_shardings=(
                _shardings(mesh, p_specs),
                _shardings(mesh, c_specs),
                b_shardings,
            ),
            donate_argnums=(1,),
        )
        args = (p_structs, c_structs, cell.batch_structs)
    else:  # decode
        step = make_decode_step(lm)
        c_structs = pdefs.to_struct(cell.cache_defs)
        c_specs = pdefs.to_specs(cell.cache_defs)
        jitted = jax.jit(
            step,
            in_shardings=(
                _shardings(mesh, p_specs),
                _shardings(mesh, c_specs),
                b_shardings,
                None,
            ),
            donate_argnums=(1,),
        )
        args = (
            p_structs,
            c_structs,
            cell.batch_structs,
            jax.ShapeDtypeStruct((), jnp.int32),
        )

    with mesh:
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    timings = {"lower_s": t1 - t0, "compile_s": t2 - t1}
    return lowered, compiled, cell, mesh, timings


def analyze(compiled, cell, mesh, mode: str) -> dict:
    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # jax 0.4.x returns [dict]
        xla_cost = xla_cost[0] if xla_cost else {}
    hlo = compiled.as_text()
    # trip-count-aware analysis: XLA's cost_analysis visits while bodies
    # ONCE, undercounting every scanned layer (launch/hloanalysis.py)
    chips = mesh.devices.size
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    cpp = chips // n_pods if n_pods > 1 else 0
    cost = analyze_hlo(hlo, chips_per_pod=cpp)
    mf = model_flops(cell.arch, cell.shape, cell.lm.n_active_params())
    rl = Roofline(
        arch=cell.arch.name,
        shape=cell.shape.name,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        chips=chips,
        hlo_flops_per_chip=cost.flops,
        hlo_bytes_per_chip=cost.bytes,
        wire_bytes_per_chip=cost.wire_bytes,
        wire_bytes_dci_per_chip=cost.wire_bytes_dci,
        model_flops_total=mf,
        collectives={k: v for k, v in cost.collectives.items()},
        peak_vmem_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
        argument_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
    )
    rec = rl.to_dict()
    rec["mode"] = mode
    rec["collective_count"] = cost.collective_count
    rec["unknown_loops"] = cost.unknown_loops
    rec["xla_flops_per_chip_unscaled"] = float(xla_cost.get("flops", 0.0))
    rec["memory_analysis"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    # proof-of-fit: per-chip live bytes = args + temps (aliased args reuse)
    live = (
        rec["memory_analysis"]["argument_bytes"]
        + rec["memory_analysis"]["temp_bytes"]
        - rec["memory_analysis"]["alias_bytes"]
    )
    rec["fits_hbm_16gb"] = bool(live < 16e9)
    rec["live_bytes_per_chip"] = int(live)
    return rec


def _save_hlo(out_dir: str, cell_id: str, hlo: str) -> None:
    try:
        import zstandard as zstd

        with open(os.path.join(out_dir, cell_id + ".hlo.zst"), "wb") as f:
            f.write(zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass


def _load_hlo(out_dir: str, cell_id: str) -> Optional[str]:
    path = os.path.join(out_dir, cell_id + ".hlo.zst")
    if not os.path.exists(path):
        return None
    import zstandard as zstd

    return zstd.ZstdDecompressor().decompress(open(path, "rb").read()).decode()


def reanalyze_cell(
    arch_name: str, shape_name: str, multi_pod: bool, mode: str,
    out_dir: str, n_pods: Optional[int] = None,
) -> Optional[dict]:
    """Recompute the roofline record from the CACHED optimized HLO — no
    recompilation (the analyzer evolves faster than the compiler does)."""
    mesh_tag = (
        f"pods{n_pods}" if n_pods is not None
        else ("multi" if multi_pod else "single")
    )
    cell_id = f"{arch_name}__{shape_name}__{mesh_tag}__{mode}"
    out_path = os.path.join(out_dir, cell_id + ".json")
    hlo = _load_hlo(out_dir, cell_id)
    if hlo is None or not os.path.exists(out_path):
        return None
    with open(out_path) as f:
        old = json.load(f)
    if old.get("status") != "ok":
        return old
    if n_pods is not None:
        from repro.dist.elastic import make_mesh_for

        mesh = make_mesh_for(n_pods, data=16, model=16)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    cpp = mesh.devices.size // n_pods if n_pods > 1 else 0
    cost = analyze_hlo(hlo, chips_per_pod=cpp)
    cell = build_cell(arch_name, shape_name, mesh)
    mf = model_flops(cell.arch, cell.shape, cell.lm.n_active_params())
    rl = Roofline(
        arch=cell.arch.name, shape=cell.shape.name,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        chips=mesh.devices.size,
        hlo_flops_per_chip=cost.flops,
        hlo_bytes_per_chip=cost.bytes,
        wire_bytes_per_chip=cost.wire_bytes,
        wire_bytes_dci_per_chip=cost.wire_bytes_dci,
        model_flops_total=mf,
        collectives=dict(cost.collectives),
        peak_vmem_bytes=old.get("peak_vmem_bytes", 0.0),
        argument_bytes=old.get("argument_bytes", 0.0),
    )
    rec = rl.to_dict()
    for k in ("mode", "memory_analysis", "fits_hbm_16gb",
              "live_bytes_per_chip", "timings", "status",
              "xla_flops_per_chip_unscaled"):
        if k in old:
            rec[k] = old[k]
    rec["collective_count"] = cost.collective_count
    rec["unknown_loops"] = cost.unknown_loops
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[reanalyzed] {cell_id}: {rec['bottleneck']} "
          f"frac={rec['roofline_fraction']:.3f}")
    return rec


def run_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    mode: str,
    out_dir: str,
    budget: float = 0.01,
    force: bool = False,
    n_pods: Optional[int] = None,
) -> Optional[dict]:
    mesh_tag = (
        f"pods{n_pods}" if n_pods is not None
        else ("multi" if multi_pod else "single")
    )
    cell_id = f"{arch_name}__{shape_name}__{mesh_tag}__{mode}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            rec = json.load(f)
        print(f"[cached] {cell_id}: {rec.get('bottleneck')}")
        return rec

    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
               "mode": mode, "status": why}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {cell_id}: {why}")
        return rec

    print(f"[lower+compile] {cell_id} ...", flush=True)
    try:
        lowered, compiled, cell, mesh, timings = lower_cell(
            arch_name, shape_name, multi_pod, mode, budget, n_pods
        )
        _save_hlo(out_dir, cell_id, compiled.as_text())
        rec = analyze(compiled, cell, mesh, mode)
        rec["status"] = "ok"
        rec["timings"] = timings
        mem = compiled.memory_analysis()
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temps={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB "
              f"alias={mem.alias_size_in_bytes/1e9:.2f}GB", flush=True)
        print(f"  cost_analysis: flops/chip={rec['hlo_flops_per_chip']:.3e} "
              f"bytes/chip={rec['hlo_bytes_per_chip']:.3e} "
              f"wire/chip={rec['wire_bytes_per_chip']:.3e}")
        print(f"  terms: compute={rec['compute_term_s']*1e3:.2f}ms "
              f"memory={rec['memory_term_s']*1e3:.2f}ms "
              f"collective={rec['collective_term_s']*1e3:.2f}ms "
              f"-> {rec['bottleneck']} | roofline_frac={rec['roofline_fraction']:.3f}")
    except Exception as e:
        rec = {
            "arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
            "mode": mode, "status": f"error: {type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"  ERROR {cell_id}: {e}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--mode", default="bsp",
                    choices=("bsp",) + tuple(_ISP_SCHEMES))
    ap.add_argument("--budget", type=float, default=0.01)
    ap.add_argument("--pods", type=int, default=None,
                    help="elastic pool size (overrides --mesh; 16x16 "
                         "chips per pod, pod axis dropped at 1)")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analysis from cached HLO, no recompile")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for a, s in cells:
        for mp in meshes:
            if args.reanalyze:
                rec = reanalyze_cell(a, s, mp, args.mode, args.out,
                                     args.pods)
                if rec is None:
                    print(f"[no cached hlo] {a} {s}")
                    continue
                st = rec.get("status", "?")
                n_ok += st == "ok"
                continue
            rec = run_cell(a, s, mp, args.mode, args.out, args.budget,
                           args.force, args.pods)
            st = (rec or {}).get("status", "?")
            if st == "ok":
                n_ok += 1
            elif st.startswith("skip"):
                n_skip += 1
            else:
                n_skip += st.startswith("skipped")
                n_err += st.startswith("error")
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    main()
