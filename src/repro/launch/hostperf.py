"""Tuned host-process launch environment for FaaS workers (DESIGN.md §15.4).

The per-worker CPU substrate is part of the measured cost model: a worker
process that thrashes the allocator or oversubscribes BLAS threads inflates
every phase the runtime times.  This module builds the environment dict the
supervisor spawns workers with, following the production launcher recipes
in SNIPPETS.md (olmax / HomebrewNLP run scripts):

* **tcmalloc LD_PRELOAD** — XLA's host allocator churn is gperftools'
  bread and butter.  Detection is best-effort with a graceful fallback:
  we probe the distro paths (override with ``REPRO_TCMALLOC``); when no
  library exists the env is returned WITHOUT a preload and ``describe``
  records ``tcmalloc: None`` — the harness never turns a perf knob into
  a crash, and the honesty rule ("Towards Demystifying Serverless ML
  Training": record the config sweep, don't assume a winner) means the
  fallback is a recorded measurement condition, not an error.
* **XLA host flags** — ``--xla_cpu_multi_thread_eigen=false`` +
  ``intra_op_parallelism_threads=K`` pin per-process math threads (each
  worker models the paper's 1-vCPU function; oversubscription was the
  dominant measured compute overhead on small hosts), optionally
  ``--xla_force_host_platform_device_count=N`` (host device partitioning)
  and ``--xla_step_marker_location=1`` (step markers at the outer loop,
  the profiling contract of the reference launchers).
* **thread pinning** — OMP/OpenBLAS/MKL/numexpr thread caps, same reason.

Contract: ``build_env`` never unsets caller-provided keys except the ones
it owns (``XLA_FLAGS`` is REPLACED, not merged — the harness is the one
owner of the worker's XLA configuration when enabled); ``describe`` is the
honest record of what was actually applied, carried into the job result.
"""

from __future__ import annotations

import os
from typing import Optional

# distro locations of gperftools' allocator, most specific first; the
# plain .so names cover images that ship only the -dev symlinks
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib64/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc_minimal.so.4",
)

# silence tcmalloc's large-alloc spam on multi-GiB arena growth (the
# SNIPPETS.md launchers' value: effectively "never report")
LARGE_ALLOC_THRESHOLD = "60000000000"

THREAD_ENV_VARS = (
    "OMP_NUM_THREADS",
    "OPENBLAS_NUM_THREADS",
    "MKL_NUM_THREADS",
    "NUMEXPR_NUM_THREADS",
)


def find_tcmalloc() -> Optional[str]:
    """First tcmalloc shared object present on this host, or None.

    ``REPRO_TCMALLOC`` overrides the probe (set it to an existing .so to
    force a specific build, or to an empty string to disable preloading
    without disabling the rest of the harness).
    """
    override = os.environ.get("REPRO_TCMALLOC")
    if override is not None:
        return override if override and os.path.exists(override) else None
    for path in TCMALLOC_PATHS:
        if os.path.exists(path):
            return path
    return None


def xla_flags(
    threads: int = 1,
    host_devices: Optional[int] = None,
    step_marker: bool = True,
) -> str:
    """The worker's XLA_FLAGS string (single owner when the harness is on)."""
    flags = [
        "--xla_cpu_multi_thread_eigen=false",
        f"intra_op_parallelism_threads={threads}",
    ]
    if host_devices is not None and host_devices > 0:
        flags.append(
            f"--xla_force_host_platform_device_count={host_devices}"
        )
    if step_marker:
        # 1 = mark at the outer while loop (0 would mark every entry)
        flags.append("--xla_step_marker_location=1")
    return " ".join(flags)


def build_env(
    base: Optional[dict] = None,
    *,
    threads: int = 1,
    host_devices: Optional[int] = None,
    step_marker: bool = True,
    tcmalloc: bool = True,
) -> dict:
    """Build the tuned worker environment on top of ``base`` (a copy).

    Keys the harness owns are SET (not defaulted): XLA_FLAGS, the thread
    caps, and — when a tcmalloc library is found and ``tcmalloc`` is
    True — LD_PRELOAD (appended to any caller preloads, never replacing
    them) plus the large-alloc report threshold.  Missing tcmalloc
    degrades gracefully to no preload.
    """
    env = dict(base) if base is not None else dict(os.environ)
    env["XLA_FLAGS"] = xla_flags(
        threads=threads, host_devices=host_devices, step_marker=step_marker
    )
    for var in THREAD_ENV_VARS:
        env[var] = str(threads)
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    if tcmalloc:
        lib = find_tcmalloc()
        if lib is not None:
            prior = env.get("LD_PRELOAD", "")
            if lib not in prior.split(":"):
                env["LD_PRELOAD"] = f"{prior}:{lib}".strip(":")
            env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = (
                LARGE_ALLOC_THRESHOLD
            )
    return env


def describe(env: dict) -> dict:
    """The honest record of what the harness actually applied — carried
    into the job result so a benchmark row states its own substrate
    (tcmalloc present or absent, the exact XLA flags, thread caps)."""
    preload = env.get("LD_PRELOAD", "")
    return {
        "tcmalloc": next(
            (p for p in preload.split(":") if "tcmalloc" in p), None
        ),
        "xla_flags": env.get("XLA_FLAGS"),
        "threads": {
            var: env.get(var) for var in THREAD_ENV_VARS if var in env
        },
    }
