"""Roofline extraction: HLO parsing + the three-term model (assignment spec).

    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes_accessed / (chips x 819e9 B/s HBM)
    collective term = wire_bytes / (chips x 50e9 B/s ICI link)

``compiled.cost_analysis()`` is per-device for SPMD executables (the module
IS the per-device program), so the per-chip division is already done for the
compute/memory terms; we keep the formulas in per-device form. Collective
wire bytes come from parsing the post-optimization HLO: every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute instruction's
shapes, with ring-algorithm multipliers:

    all-gather:  (G-1)/G x out_bytes      (receives everyone else's shard)
    all-reduce:  2 x (G-1)/G x out_bytes  (reduce-scatter + all-gather)
    reduce-scatter: (G-1)/G x in_bytes
    all-to-all:  (G-1)/G x out_bytes
    collective-permute: out_bytes

where G is the replica-group size parsed from the instruction.

MODEL_FLOPS uses the standard 6*N_active*D (+ attention term) accounting, so
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/causal-mask/dispatch waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import numpy as np

from repro.models.config import ArchConfig, Mixer, ShapeConfig

# ---- hardware constants (TPU v5e, assignment spec) ---------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
# cross-pod (DCI/DCN) effective bandwidth per chip: pods are not ICI-linked;
# 1/8 of ICI is the documented modeling assumption (typical v5e multislice)
DCI_BW = ICI_BW / 8.0

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>.+?)\s+(?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<suffix>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>\w+?)\[(?P<dims>[\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [a,b]<=[N]...: replica groups are the rows of an
        # (a, b) reshape -> group size b
        return int(m.group(2))
    return 0


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, bytes_: float):
        self.by_op[op] = self.by_op.get(op, 0.0) + bytes_
        self.wire_bytes += bytes_
        self.count += 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum wire bytes over all collective instructions in optimized HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue  # -done re-states the -start result; count once
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("rtype"))
        g = _group_size(line) or 8
        frac = (g - 1) / g
        if op == "all-reduce":
            wire = 2.0 * frac * out_bytes
        elif op == "reduce-scatter":
            # out is the scattered shard; ring moves ~(G-1) shards
            wire = frac * out_bytes * g
        elif op == "collective-permute":
            wire = float(out_bytes)
        else:  # all-gather, all-to-all
            wire = frac * out_bytes
        stats.add(op, wire)
    return stats


# ---- MODEL_FLOPS accounting ----------------------------------------------------


def model_flops(
    arch: ArchConfig, shape: ShapeConfig, n_active_params: int
) -> float:
    """Useful-work FLOPs for one step of this cell (whole job, all chips).

    train: 6*N*D matmul flops (fwd 2 + bwd 4) + attention score/value flops;
    prefill: 2*N*D + fwd attention; decode: 2*N*B + attention over the cache.
    Attention per layer (fwd): 4*B*H*Sq*Skv_eff*Dh, causal halves Skv_eff,
    SWA caps it at the window.
    """
    b, s = shape.global_batch, shape.seq_len
    h, dh = arch.n_heads, arch.resolved_head_dim
    tokens = b * (s if shape.kind != "decode" else 1)
    if shape.kind == "train":
        flops = 6.0 * n_active_params * tokens
        mult = 3.0  # fwd + bwd
    elif shape.kind == "prefill":
        flops = 2.0 * n_active_params * tokens
        mult = 1.0
    else:
        flops = 2.0 * n_active_params * tokens
        mult = 1.0

    attn = 0.0
    for sb, reps in arch.groups:
        for spec in sb:
            if spec.mixer not in (Mixer.GLOBAL_ATTN, Mixer.LOCAL_ATTN,
                                  Mixer.CROSS_ATTN):
                continue
            if shape.kind == "decode":
                skv = s if spec.mixer is Mixer.GLOBAL_ATTN else min(
                    s, spec.window or s
                )
                attn += reps * 4.0 * b * h * 1 * skv * dh
            else:
                if spec.mixer is Mixer.LOCAL_ATTN and spec.window:
                    skv_eff = min(spec.window, s)
                    attn += reps * mult * 4.0 * b * h * s * skv_eff * dh
                elif spec.mixer is Mixer.CROSS_ATTN:
                    enc = arch.encoder.ctx_len if arch.encoder else s
                    attn += reps * mult * 4.0 * b * h * s * enc * dh
                else:
                    attn += reps * mult * 4.0 * b * h * s * (s / 2.0) * dh
    return flops + attn


# ---- the three terms -------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_total: float
    collectives: dict
    peak_vmem_bytes: float = 0.0
    argument_bytes: float = 0.0
    wire_bytes_dci_per_chip: float = 0.0  # subset crossing pod boundaries

    @property
    def compute_term_s(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_term_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_term_s(self) -> float:
        """Within-pod (ICI) wire time."""
        return (
            self.wire_bytes_per_chip - self.wire_bytes_dci_per_chip
        ) / ICI_BW

    @property
    def dci_term_s(self) -> float:
        """Cross-pod wire time at DCI bandwidth (0 on single-pod meshes)."""
        return self.wire_bytes_dci_per_chip / DCI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_term_s,
            "memory": self.memory_term_s,
            "collective": self.collective_term_s,
            "dci": self.dci_term_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time bound: max of the terms (perfect overlap)."""
        return max(self.compute_term_s, self.memory_term_s,
                   self.collective_term_s, self.dci_term_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the step-time bound:
        MODEL_FLOPS / (chips * peak * step_time). This is the MFU the cell
        would sustain if it ran exactly at its dominant-term bound."""
        denom = self.chips * PEAK_FLOPS * self.step_time_s
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "compute_term_s": self.compute_term_s,
            "memory_term_s": self.memory_term_s,
            "collective_term_s": self.collective_term_s,
            "dci_term_s": self.dci_term_s,
            "wire_bytes_dci_per_chip": self.wire_bytes_dci_per_chip,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
            "peak_vmem_bytes": self.peak_vmem_bytes,
            "argument_bytes": self.argument_bytes,
        }
