"""Batched serving driver: continuous-batching prefill + decode loop.

The inference-side counterpart of launch/train.py, exercising the same
``LM.prefill`` / ``LM.decode_step`` entry points the decode/prefill dry-run
cells lower. Slot-based continuous batching: a fixed decode batch of
``--slots`` sequences; finished sequences release their slot and the next
queued request is prefilled into it (cache rows are written per-slot, so
admission never re-lowers).

Usage (CPU example):
  python -m repro.launch.serve --arch xlstm-1.3b --smoke --requests 8 \
      --slots 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch, get_smoke
from repro.data.tokens import TokenPipeline
from repro.models.transformer import LM


def _frontend_inputs(cfg, b: int) -> dict:
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.zeros(
            (b, cfg.encoder.ctx_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.zeros(
            (b, cfg.encoder.ctx_len, cfg.d_model), jnp.float32
        )
    return extra


def serve(args) -> dict:
    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen_len
    slots = args.slots

    cache = lm.init_cache(slots, max_len)
    extra = _frontend_inputs(cfg, slots)

    prefill = jax.jit(lm.prefill)
    decode = jax.jit(lm.decode_step)

    # request queue: synthetic prompts
    pipe = TokenPipeline(cfg.vocab_size, args.prompt_len, args.requests,
                         seed=args.seed)
    prompts = np.asarray(pipe.next_batch(0)["tokens"])

    # -- admit the first `slots` requests with one batched prefill
    t0 = time.time()
    first = jnp.asarray(prompts[:slots])
    logits, cache = prefill(params, cache, {"tokens": first, **extra})
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    prefill_s = time.time() - t0

    slot_req = list(range(slots))  # which request occupies each slot
    generated: dict[int, list[int]] = {i: [] for i in range(args.requests)}
    remaining: list[int] = list(range(slots, args.requests))
    done = 0
    decode_steps = 0
    t1 = time.time()
    pos = args.prompt_len
    while done < args.requests and pos < max_len:
        tok_in = next_tok[:, None]
        logits, cache = decode(
            params, cache, {"tokens": tok_in, **extra},
            jnp.asarray(pos, jnp.int32),
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        decode_steps += 1
        toks = np.asarray(next_tok)
        for s, r in enumerate(slot_req):
            if r is None:
                continue
            generated[r].append(int(toks[s]))
            if len(generated[r]) >= args.gen_len:
                done += 1
                # slot release + admission (cache row reuse); the new
                # request restarts the slot's sequence positions, so in this
                # simple driver admission happens between decode batches
                slot_req[s] = remaining.pop(0) if remaining else None
        pos += 1
    decode_s = time.time() - t1

    total_new = sum(len(v) for v in generated.values())
    result = {
        "arch": cfg.name,
        "requests": args.requests,
        "slots": slots,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "decode_steps": decode_steps,
        "new_tokens": total_new,
        "decode_tokens_per_s": total_new / max(decode_s, 1e-9),
        "prefill_tokens_per_s": slots * args.prompt_len / max(prefill_s, 1e-9),
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-1.3b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    print(json.dumps(serve(args), indent=1))


if __name__ == "__main__":
    main()
