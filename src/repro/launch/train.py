"""End-to-end training driver over pluggable execution substrates.

Two registries keep ``main()`` flat as substrates accumulate (DESIGN.md §9):

* ``RUNTIMES`` — *where* the job runs: ``inproc`` (this process: the jitted
  single-host loop below) or ``faas`` (real multi-process serverless
  runtime, ``repro.runtime``).
* ``MODES`` — the in-process consistency/exchange mode: ``bsp``, ``isp``
  (error-feedback filter on the update), ``isp-pod`` (per-pod divergent
  state + compressed collective exchange). Each mode bundles its step
  builder and its scale-in transition, so the training loop calls one
  registry hook instead of branching.

The in-process runtime realizes the MLLess loop as a pod would (DESIGN.md
§2): data-parallel training with the ISP significance filter on the
gradient exchange and the scale-in auto-tuner driving *elastic weak
scaling* — evicting a worker shrinks the global batch (B_g = P*B, paper
§3.2) and the step is re-lowered for the smaller pool, exactly the
checkpoint -> re-mesh -> restore transition a pod would perform.

Fault tolerance: deterministic step-indexed checkpoints (atomic rename);
``--restore`` resumes from the newest one, reproducing the optimizer/filter
state bit-exactly. Eviction writes a checkpoint first (the transition IS a
restore), so a node failure at any point costs at most one interval.

Usage (CPU example sizes):
  python -m repro.launch.train --arch lm-100m --steps 300 --workers 4 \
      --per-worker-batch 4 --seq 512 --mode isp --autotune \
      --checkpoint-dir /tmp/ckpt
  python -m repro.launch.train --arch xlstm-1.3b --smoke --steps 20
  python -m repro.launch.train --runtime faas --workload pmf --steps 60 \
      --workers 4 --autotune --run-dir /tmp/faas
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import store as ckpt
from repro.configs import ARCH_NAMES, get_arch, get_smoke
from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner
from repro.core.billing import CommModel, faas_cost
from repro.core.isp import ISPConfig, communicated_fraction
from repro.data.tokens import TokenPipeline
from repro.dist import elastic as dist_elastic
from repro.dist.compression import (
    CompressionConfig,
    apply_combined,
    isp_compressed_step,
)
from repro.models.config import ArchConfig, BlockSpec, FF, Mixer, uniform_groups
from repro.models.transformer import LM
from repro.optim import apply_updates, clip_by_global_norm

PyTree = Any

# the deliverable's "~100M model": 12L x d768 SwiGLU, 32k vocab -> ~103M
LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab_size=32_768,
    groups=uniform_groups(BlockSpec(Mixer.GLOBAL_ATTN, FF.SWIGLU), 12),
    max_seq_len=8192,
    sub_quadratic=False,
)

LM_8M = dataclasses.replace(
    LM_100M, name="lm-8m", d_model=256, n_heads=8, n_kv_heads=8, d_ff=512,
    vocab_size=8192,
    groups=uniform_groups(BlockSpec(Mixer.GLOBAL_ATTN, FF.SWIGLU), 4),
)

_EXTRA = {"lm-100m": LM_100M, "lm-8m": LM_8M}


def resolve_arch(name: str, smoke: bool) -> ArchConfig:
    if name in _EXTRA:
        return _EXTRA[name]
    return get_smoke(name) if smoke else get_arch(name)


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: Any
    residual: PyTree  # ISP error-feedback residual
    step: int
    pool: int  # current worker count (elastic weak scaling)


def make_step(lm: LM, optimizer, isp: Optional[ISPConfig], clip: float = 1.0):
    """One jitted train step for a fixed pool size.

    BSP: plain update. ISP: optimizer update -> residual accumulate ->
    significance split -> apply only the significant part (the residual
    stays local; on a pod the significant part is what crosses the pod
    axis — see dist.compression for the collective form).
    """

    def step_fn(params, opt_state, residual, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.train_loss, has_aux=True
        )(params, batch)
        if clip:
            grads = clip_by_global_norm(grads, clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        if isp is None:
            params = apply_updates(params, updates)
            sent_frac = jnp.asarray(1.0, jnp.float32)
        else:
            from repro.core.isp import significance_split

            v_t = isp.threshold(opt_state.step)

            def split(u, x, r):
                return significance_split(r + u, x, v_t, isp.absolute_floor)

            out = jax.tree.map(split, updates, params, residual)
            td = jax.tree.structure(params)
            ls = td.flatten_up_to(out)
            sig = td.unflatten([l[0] for l in ls])
            residual = td.unflatten([l[1] for l in ls])
            masks = td.unflatten([l[2] for l in ls])
            params = apply_updates(params, sig)
            sent_frac = communicated_fraction(masks)
        return params, opt_state, residual, loss, sent_frac

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def lift_pod(tree: PyTree, n_pods: int) -> PyTree:
    """Stack a shared tree into per-pod state: every leaf gains a leading
    (n_pods,) dim. Used for the divergent optimizer moments and residuals
    of the pod path (the paper's per-worker state)."""
    return jax.tree.map(lambda x: jnp.repeat(x[None], n_pods, axis=0), tree)


def make_pod_step(
    lm: LM,
    optimizer,
    isp: ISPConfig,
    comp: CompressionConfig,
    n_pods: int,
    clip: float = 1.0,
):
    """One jitted ISP-pod train step (DESIGN.md §2) for a fixed pool size.

    The global batch arrives as (P*B, ...) and is reshaped so dim 0 is the
    pod axis; each pod runs its own optimizer on its own shard (divergent
    moments), then the error-feedback compressed exchange
    (``dist.compression.isp_compressed_step``) combines the significant
    parts into the shared parameters. This is the single-host vmap
    analogue of the GSPMD formulation in ``launch.steps``; on a real
    multi-pod mesh the leading dim shards over 'pod'.
    """

    def step_fn(params, opt_pod, res_pod, batch):
        batch_p = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
            batch,
        )

        def pod_fn(opt_state, b):
            (loss, metrics), grads = jax.value_and_grad(
                lm.train_loss, has_aux=True
            )(params, b)
            if clip:
                grads = clip_by_global_norm(grads, clip)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return updates, opt_state, loss

        updates, opt_pod, losses = jax.vmap(pod_fn)(opt_pod, batch_p)
        v_t = isp.threshold(opt_pod.step[0])
        combined, res_pod, stats = isp_compressed_step(
            comp, updates, params, res_pod, v_t,
            floor=isp.absolute_floor,
        )
        params = apply_combined(params, combined)
        return (params, opt_pod, res_pod, jnp.mean(losses),
                stats["sent_fraction"])

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


# -- mode registry (DESIGN.md §9.5) -------------------------------------------
#
# A mode owns (a) how a train step is built for a pool size and (b) what a
# scale-in transition does to the train state. New exchange modes register
# here instead of adding branches to the training loop.


@dataclasses.dataclass(frozen=True)
class TrainMode:
    """One in-process exchange mode."""

    name: str
    pod: bool  # per-pod (lifted) optimizer/residual state
    build_step: Any  # (lm, optimizer, isp, comp, pool) -> jitted step_fn
    scale_in: Any  # (args, st, plan, isp) -> TrainState (pool shrunk by 1)


MODES: dict[str, TrainMode] = {}


def register_mode(mode: TrainMode) -> TrainMode:
    MODES[mode.name] = mode
    return mode


def _scale_in_flat(args, st: TrainState, plan, isp) -> TrainState:
    """bsp/isp scale-in: flush the ISP residual into the params (the paper's
    leaving-worker model averaging, error-feedback form — no update mass is
    lost), checkpoint, shrink the pool."""
    if isp is not None:
        st.params = apply_updates(st.params, st.residual)
        st.residual = jax.tree.map(jnp.zeros_like, st.residual)
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, st)
    st.pool -= 1
    return st


def _scale_in_pod(args, st: TrainState, plan, isp) -> TrainState:
    """isp-pod scale-in: dist.elastic owns the transition — the evicted
    pod's residual is flushed into the shared params and its optimizer/
    residual slices dropped; the transition IS a checkpoint restore under
    the smaller pool's mesh whenever this host can build it."""
    tr = dist_elastic.plan_transition(plan, st.pool, st.pool - 1)
    st.params, st.opt_state, st.residual = dist_elastic.apply_transition(
        tr, st.params, st.opt_state, st.residual
    )
    st.pool = tr.new_pods
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, st)
        if jax.device_count() >= int(np.prod(tr.new_mesh_shape)):
            tree = {"params": st.params, "opt": st.opt_state,
                    "residual": st.residual}
            out = dist_elastic.resharded_restore(
                args.checkpoint_dir, st.step, tree, tr.new_pods
            )
            st.params = out["params"]
            st.opt_state = out["opt"]
            st.residual = out["residual"]
    return st


register_mode(TrainMode(
    name="bsp", pod=False,
    build_step=lambda lm, opt, isp, comp, pool: make_step(lm, opt, None),
    scale_in=_scale_in_flat,
))
register_mode(TrainMode(
    name="isp", pod=False,
    build_step=lambda lm, opt, isp, comp, pool: make_step(lm, opt, isp),
    scale_in=_scale_in_flat,
))
register_mode(TrainMode(
    name="isp-pod", pod=True,
    build_step=lambda lm, opt, isp, comp, pool: make_pod_step(
        lm, opt, isp, comp, pool
    ),
    scale_in=_scale_in_pod,
))


def save_checkpoint(d: str, st: TrainState) -> str:
    return ckpt.save(
        d, st.step,
        {"params": st.params, "opt": st.opt_state, "residual": st.residual},
        extra={"pool": st.pool},
    )


def restore_checkpoint(d: str, st: TrainState) -> TrainState:
    step = ckpt.latest_step(d)
    if step is None:
        return st
    tree = ckpt.restore(
        d, step,
        {"params": st.params, "opt": st.opt_state, "residual": st.residual},
    )
    extra = ckpt.manifest_extra(d, step)
    return TrainState(
        params=tree["params"], opt_state=tree["opt"],
        residual=tree["residual"], step=step, pool=extra.get("pool", st.pool),
    )


def train(args) -> dict:
    cfg = resolve_arch(args.arch, args.smoke)
    lm = LM(cfg)
    key = jax.random.PRNGKey(args.seed)
    optimizer = optim.make(args.optimizer, args.lr)
    mode = MODES[args.mode]
    pod_mode = mode.pod
    isp = ISPConfig(v=args.isp_v) if args.mode.startswith("isp") else None
    # --wire-scheme overrides the byte-accounting codec (else it derives
    # from the exchange scheme); repro.wire either way. 'auto' is per-leaf
    # data-dependent — not resolvable inside jit — so the traced pod
    # accounting keeps the derived codec.
    wire_override = getattr(args, "wire_scheme", None)
    if wire_override == "auto":
        wire_override = None
    comp = (
        CompressionConfig(
            scheme=getattr(args, "scheme", "dense"),
            budget=getattr(args, "budget", 0.01),
            wire=wire_override,
        )
        if pod_mode
        else None
    )

    params = lm.init(key)
    n_params = lm.n_params()
    print(f"arch={cfg.name} params={n_params:,} mode={args.mode} "
          f"workers={args.workers}")

    def fresh_state(pool: int) -> TrainState:
        opt0 = optimizer.init(params)
        res0 = jax.tree.map(jnp.zeros_like, params)
        if pod_mode:  # per-pod divergent optimizer moments + residuals
            opt0, res0 = lift_pod(opt0, pool), lift_pod(res0, pool)
        return TrainState(params=params, opt_state=opt0, residual=res0,
                          step=0, pool=pool)

    st = fresh_state(args.workers)
    if args.restore and args.checkpoint_dir:
        step = ckpt.latest_step(args.checkpoint_dir)
        if step is not None and pod_mode:
            # per-pod state shapes depend on the checkpointed pool size —
            # rebuild the restore template at that pool first
            pool = ckpt.manifest_extra(args.checkpoint_dir, step).get(
                "pool", st.pool
            )
            st = fresh_state(pool)
        st = restore_checkpoint(args.checkpoint_dir, st)
        print(f"restored step={st.step} pool={st.pool}")

    # the weak-scaling contract B_g = P * B lives in the elastic plan
    plan = dist_elastic.ElasticPlan(
        initial_pods=max(args.workers, st.pool),
        per_pod_batch=args.per_worker_batch,
    )

    tuner = None
    if args.autotune:
        tuner = ScaleInAutoTuner(
            AutoTunerConfig(
                sched_interval_s=args.sched_interval,
                delta_s=args.sched_interval / 2,
                min_workers=1,
            ),
            st.pool,
        )

    def build_step(pool: int):
        return mode.build_step(lm, optimizer, isp, comp, pool)

    step_fn = build_step(st.pool)
    history = []
    worker_seconds = 0.0
    t_job0 = time.time()

    while st.step < args.steps:
        # weak scaling (paper §3.2): global batch = pool * per-worker batch
        gb = plan.global_batch(st.pool)
        pipe = TokenPipeline(cfg.vocab_size, args.seq, gb, seed=args.seed)
        batch = pipe.next_batch(st.step)
        t0 = time.time()
        st.params, st.opt_state, st.residual, loss, sent = step_fn(
            st.params, st.opt_state, st.residual, batch
        )
        loss = float(loss)
        dt = time.time() - t0
        worker_seconds += dt * st.pool
        st.step += 1
        history.append(
            {"step": st.step, "loss": loss, "sent_fraction": float(sent),
             "pool": st.pool, "step_s": dt}
        )
        if st.step % args.log_every == 0:
            print(f"step {st.step:5d} pool={st.pool:2d} loss={loss:.4f} "
                  f"sent={float(sent):.3f} {dt*1e3:.0f}ms")

        if args.checkpoint_dir and st.step % args.checkpoint_every == 0:
            save_checkpoint(args.checkpoint_dir, st)

        if tuner is not None:
            tuner.observe(st.step, loss, dt)
            if tuner.decide().remove_worker and st.pool > 1:
                # elastic scale-in: reintegrate -> checkpoint -> re-lower,
                # with the mode registry owning the transition semantics
                st = mode.scale_in(args, st, plan, isp)
                step_fn = build_step(st.pool)  # re-lower
                print(f"  [autotuner] scale-in -> pool={st.pool} "
                      f"(global batch {plan.global_batch(st.pool)})")

    wall = time.time() - t_job0
    # bill the modelled topology the job declares (paper: one Redis VM per
    # update-store shard), not a hardcoded single shard
    bill = faas_cost(
        [worker_seconds], wall, n_redis=getattr(args, "n_brokers", 1)
    )
    result = {
        "arch": cfg.name,
        "n_params": n_params,
        "final_loss": history[-1]["loss"] if history else None,
        "steps": st.step,
        "final_pool": st.pool,
        "wall_s": wall,
        "worker_seconds": worker_seconds,
        "mean_sent_fraction": float(
            np.mean([h["sent_fraction"] for h in history])
        ) if history else None,
        "faas_cost_usd": bill.total,
        "history": history,
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return result


# -- runtime registry ---------------------------------------------------------
#
# A runtime is a whole execution substrate: it receives the parsed args and
# returns the result dict. ``inproc`` is the jitted loop above; ``faas`` is
# the real multi-process serverless runtime.

RUNTIMES: dict[str, Any] = {}


def register_runtime(name: str):
    def deco(fn):
        RUNTIMES[name] = fn
        return fn

    return deco


register_runtime("inproc")(train)


def _parse_retunes(specs) -> tuple:
    """--retune STEP:JSON (repeatable) -> scripted_retunes tuples."""
    out = []
    for s in specs or ():
        step, sep, body = s.partition(":")
        if not sep:
            raise SystemExit(f"--retune {s!r}: expected STEP:JSON")
        try:
            out.append((int(step), json.loads(body)))
        except (ValueError, json.JSONDecodeError) as e:
            raise SystemExit(f"--retune {s!r}: expected STEP:JSON ({e})")
    return tuple(out)


def _topology_args(args) -> dict:
    """Resolve the topology-tuning CLI flags into FaaSJobConfig fields.

    Live re-sharding moves little data only when leaves are chunked, so
    when tuning is on and --shard-split-bytes was not given we default to
    the consistent-hash ring over 64 KiB chunks; the plain path keeps the
    greedy whole-leaf partitioner (bit-identical to prior releases).
    """
    retunes = _parse_retunes(getattr(args, "retune", None))
    topo = bool(getattr(args, "topology_tune", False))
    split = int(getattr(args, "shard_split_bytes", 0) or 0)
    partitioner = "greedy"
    if (topo or retunes) and split == 0:
        split = 65536
        partitioner = "ring"
    return {
        "topology_tune": topo,
        "scripted_retunes": retunes,
        "partitioner": partitioner,
        "shard_split_bytes": split,
    }


def _fleet_faas(args, run_dir: str) -> dict:
    """--jobs: N concurrent jobs on ONE shared pool (runtime.scheduler).

    The jobs file maps job id -> FaaSJobConfig field overrides (optionally
    under a top-level "jobs" key, with "pool_budget" alongside)::

        {"pool_budget": 4,
         "jobs": {"pmf0": {"workload": "pmf", "n_workers": 3,
                           "total_steps": 60},
                  "lr0": {"workload": "lr", "n_workers": 2,
                          "total_steps": 40, "consistency": "ssp"}}}

    CLI flags (--n-brokers, --transport, --wire-quant, ...) are the fleet
    defaults; per-job overrides win.  Pool topology must agree across jobs
    (they share the broker processes).
    """
    from repro.runtime import FaaSJobConfig, FleetConfig, run_fleet

    with open(args.jobs) as f:
        doc = json.load(f)
    specs = doc.get("jobs", doc) if isinstance(doc, dict) else None
    if not isinstance(specs, dict) or not specs:
        raise SystemExit(f"--jobs {args.jobs}: expected a job-id mapping")
    pool_budget = args.pool_budget
    if pool_budget is None and isinstance(doc, dict):
        pool_budget = doc.get("pool_budget")
    topo = _topology_args(args)
    if topo["scripted_retunes"]:
        raise SystemExit(
            "--retune is not supported with --jobs: the fleet's broker "
            "pool is shared, so no job may re-shard it live"
        )
    fields = {f.name for f in dataclasses.fields(FaaSJobConfig)}
    jobs = {}
    for jid, spec in specs.items():
        unknown = set(spec) - fields
        if unknown:
            raise SystemExit(
                f"--jobs job {jid!r}: unknown fields {sorted(unknown)}"
            )
        base = dict(
            run_dir=os.path.join(run_dir, "jobs", str(jid)),
            workload=args.workload,
            n_workers=args.workers,
            total_steps=args.steps,
            invocation_steps=args.invocation_steps,
            checkpoint_every=args.checkpoint_every,
            optimizer=args.optimizer,
            lr=args.lr,
            isp_v=args.isp_v,
            wire_scheme=args.wire_scheme or "auto",
            wire_quant=args.wire_quant,
            wire_impl=getattr(args, "wire_impl", "numpy"),
            hostperf=getattr(args, "hostperf", False),
            n_brokers=getattr(args, "n_brokers", 1),
            transport=getattr(args, "transport", "tcp"),
            consistency=getattr(args, "consistency", "isp"),
            slack=getattr(args, "slack", 3),
            autotune=args.autotune,
            # observe-only under the fleet: keep the exact layout the user
            # asked for (no ring/split default — the pool never re-shards)
            topology_tune=topo["topology_tune"],
            shard_split_bytes=int(getattr(args, "shard_split_bytes", 0) or 0),
            seed=args.seed,
        )
        base.update(spec)
        jobs[str(jid)] = FaaSJobConfig(**base)
    result = run_fleet(FleetConfig(
        run_dir=run_dir, jobs=jobs, pool_budget=pool_budget,
    ))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


@register_runtime("faas")
def train_faas(args) -> dict:
    """Run the job on the multi-process FaaS runtime (repro.runtime)."""
    import tempfile

    from repro.core.autotuner import AutoTunerConfig
    from repro.runtime import FaaSJobConfig, run_job

    run_dir = args.run_dir or args.checkpoint_dir or tempfile.mkdtemp(
        prefix="repro_faas_"
    )
    if getattr(args, "jobs", None):
        if getattr(args, "chaos", None):
            raise SystemExit(
                "--chaos is not supported with --jobs: a fault plan "
                "SIGKILLs pool processes shared by every tenant"
            )
        return _fleet_faas(args, run_dir)
    chaos_plan = None
    if getattr(args, "chaos", None):
        from repro.runtime.faults import parse_chaos_arg

        chaos_plan = parse_chaos_arg(
            args.chaos, n_workers=args.workers,
            n_shards=getattr(args, "n_brokers", 1), total_steps=args.steps,
        )
    topo = _topology_args(args)
    cfg = FaaSJobConfig(
        run_dir=run_dir,
        workload=args.workload,
        workload_cfg=json.loads(args.workload_cfg) if args.workload_cfg
        else {},
        n_workers=args.workers,
        total_steps=args.steps,
        invocation_steps=args.invocation_steps,
        checkpoint_every=args.checkpoint_every,
        optimizer=args.optimizer,
        lr=args.lr,
        isp_v=args.isp_v,
        wire_scheme=args.wire_scheme or "auto",
        wire_quant=args.wire_quant,
        wire_impl=getattr(args, "wire_impl", "numpy"),
        hostperf=getattr(args, "hostperf", False),
        n_brokers=getattr(args, "n_brokers", 1),
        transport=getattr(args, "transport", "tcp"),
        consistency=getattr(args, "consistency", "isp"),
        slack=getattr(args, "slack", 3),
        autotune=args.autotune,
        tuner=AutoTunerConfig(
            sched_interval_s=args.sched_interval,
            delta_s=args.sched_interval / 2,
        ),
        topology_tune=topo["topology_tune"],
        scripted_retunes=topo["scripted_retunes"],
        partitioner=topo["partitioner"],
        shard_split_bytes=topo["shard_split_bytes"],
        seed=args.seed,
        chaos=None if chaos_plan is None else chaos_plan.to_spec(),
    )
    if chaos_plan is not None and any(
        e.kind == "supervisor_kill" for e in chaos_plan.events
    ):
        # the supervisor will kill itself mid-job: drive it from outside
        # so it can be re-executed against its journal
        from repro.runtime.faults import run_job_resilient

        result = run_job_resilient(cfg)
    else:
        result = run_job(cfg)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--runtime", default="inproc",
                    choices=tuple(sorted(RUNTIMES)),
                    help="execution substrate (see module docstring)")
    ap.add_argument("--arch", default="lm-8m",
                    choices=tuple(_EXTRA) + ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mode", choices=tuple(sorted(MODES)), default="bsp")
    ap.add_argument("--isp-v", type=float, default=0.7)
    ap.add_argument("--scheme", choices=("dense", "topk", "bitmap"),
                    default="dense",
                    help="isp-pod exchange scheme (dist.compression)")
    ap.add_argument("--budget", type=float, default=0.01,
                    help="topk fraction kept per block")
    ap.add_argument("--wire-scheme", default=None,
                    choices=("auto", "dense", "sparse", "bitmap"),
                    help="repro.wire update codec, both runtimes: the faas "
                    "workers' encoder AND the isp-pod byte accounting "
                    "(default: auto for faas, derived from --scheme inproc)")
    ap.add_argument("--wire-quant", default="none",
                    choices=("none", "fp16", "bf16"),
                    help="faas: value quantization with error-feedback "
                    "residual (repro.wire)")
    ap.add_argument("--wire-impl", default="numpy",
                    choices=("numpy", "pallas", "auto"),
                    help="faas: codec backend — numpy reference, fused "
                    "Pallas kernels (bit-identical bytes), or per-leaf "
                    "auto selection (DESIGN.md §15)")
    ap.add_argument("--hostperf", action="store_true",
                    help="faas: spawn workers under the tuned host env "
                    "(launch/hostperf.py)")
    ap.add_argument("--optimizer", default="adam",
                    choices=("adam", "sgd", "nesterov"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--sched-interval", type=float, default=20.0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    # faas-runtime options
    ap.add_argument("--workload", default="pmf",
                    help="faas runtime workload (repro.runtime.workload)")
    ap.add_argument("--workload-cfg", default=None,
                    help="JSON overrides for the workload config")
    ap.add_argument("--invocation-steps", type=int, default=1_000_000,
                    help="faas: steps per function invocation")
    ap.add_argument("--n-brokers", type=int, default=1,
                    help="update-store shards (runtime.sharding): faas "
                    "spawns one broker process per shard; both runtimes "
                    "bill n_redis == n_brokers")
    ap.add_argument("--transport", default="tcp", choices=("tcp", "shm"),
                    help="faas: worker<->shard update-path channel "
                    "(repro.wire): persistent loopback TCP or zero-copy "
                    "shared-memory rings (same accounted bytes)")
    ap.add_argument("--consistency", default="isp", choices=("isp", "ssp"),
                    help="faas: pull-barrier model — 'isp' full per-step "
                    "barrier (default), 'ssp' bounded staleness (a pull at "
                    "step t waits only for steps <= t - slack - 1)")
    ap.add_argument("--slack", type=int, default=3,
                    help="faas: SSP staleness bound (ignored under isp)")
    ap.add_argument("--topology-tune", action="store_true",
                    help="faas: co-tune {n_brokers, transport, wire_scheme,"
                    " shard_split_bytes} online — explore-then-commit over "
                    "neighbouring cells with WAL-coordinated live "
                    "re-sharding at invocation boundaries (DESIGN.md §16); "
                    "requires --consistency isp, no --jobs re-shard")
    ap.add_argument("--chaos", default=None, metavar="SEED:SPEC",
                    help="faas: seeded fault-injection plan "
                         "(runtime/faults.py) — SEED:auto expands the "
                         "default randomized multi-fault schedule, "
                         "SEED:[{\"kind\":...,\"step\":...}] is explicit; "
                         "incompatible with --jobs")
    ap.add_argument("--retune", action="append", metavar="STEP:JSON",
                    help="faas: force one live re-shard when the frontier "
                    "reaches STEP, e.g. '4:{\"n_brokers\":2}' (repeatable; "
                    "disables the online tuner — scripted topologies only)")
    ap.add_argument("--shard-split-bytes", type=int, default=0,
                    help="faas: split update-store leaves into chunks of at "
                    "most this many bytes before sharding (0 = whole "
                    "leaves; tuning defaults this to 65536 with the "
                    "consistent-hash ring partitioner)")
    ap.add_argument("--run-dir", default=None,
                    help="faas: checkpoints + worker logs directory")
    ap.add_argument("--jobs", default=None,
                    help="faas: JSON file of N jobs to run CONCURRENTLY on "
                    "one shared broker/worker pool (runtime.scheduler); "
                    "maps job id -> FaaSJobConfig overrides")
    ap.add_argument("--pool-budget", type=int, default=None,
                    help="faas --jobs: max concurrent active (worker, job) "
                    "pairs; the scheduler evicts fair-share beyond it")
    args = ap.parse_args()
    res = RUNTIMES[args.runtime](args)
    slim = {k: v for k, v in res.items() if k not in ("history", "updates")}
    if isinstance(slim.get("jobs"), dict):  # fleet: per-job histories too
        slim["jobs"] = {
            j: {k: v for k, v in r.items() if k != "history"}
            for j, r in slim["jobs"].items()
        }
    print(json.dumps(slim, indent=1, default=str))


if __name__ == "__main__":
    main()
