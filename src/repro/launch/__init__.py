"""Launchers: production mesh, allocation-free dry-run, train/serve drivers."""
