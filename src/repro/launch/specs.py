"""Per-cell input specs: ShapeDtypeStructs + NamedShardings, no allocation.

``build_cell(arch_name, shape_name, mesh, ...)`` resolves everything a cell
needs: the LM with the right ShardingPolicy, abstract params/opt/cache/batch
structs, and the matching NamedShardings. The sharding POLICY varies by cell
kind (DESIGN.md §5):

  * train / prefill — batch over (pod, data); activations sequence-parallel
    over 'model' between blocks; attention heads / d_ff / experts over
    'model'; params + optimizer FSDP over 'data' and TP over 'model'.
  * decode_32k      — batch over (pod, data); full-attention KV caches
    sharded over 'model' on the SEQUENCE dim (flash-decode layout: softmax
    stats all-reduced over 'model'); ring buffers replicated on seq.
  * long_500k       — batch=1: KV/seq sharded over ('data','model');
    recurrent-state archs carry O(1) state and ignore kv_seq.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.models import params as pdefs
from repro.models.attention import ShardingPolicy
from repro.models.config import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from repro.models.transformer import LM
from repro.launch.mesh import batch_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    lm: LM
    param_defs: PyTree
    batch_structs: dict[str, jax.ShapeDtypeStruct]
    batch_specs: dict[str, P]
    cache_defs: Optional[PyTree]  # decode/prefill cells

    def param_structs(self) -> PyTree:
        return pdefs.to_struct(self.param_defs)

    def param_specs(self) -> PyTree:
        return pdefs.to_specs(self.param_defs)

    def shardings(self, mesh, tree_of_specs: PyTree) -> PyTree:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree_of_specs,
            is_leaf=lambda x: isinstance(x, P),
        )


def make_policy(mesh, shape: ShapeConfig, arch: ArchConfig) -> ShardingPolicy:
    """Resolve the activation-sharding policy for one cell.

    Scheme selection (napkin math in EXPERIMENTS.md §Perf — FSDP-vs-TP
    traffic per layer is ~3x params_bytes vs ~6x B_loc*S*D bytes; at the
    assigned 4k tokens/chip the weight-gather side wins for every arch):

    * train (B divisible by the whole chip count) — **FSDP-2D**: batch over
      every mesh axis, attention and recurrences fully local, parameters
      ZeRO-3-gathered per layer by GSPMD. MoE experts take the 'model' axis
      at the dispatch boundary (EP) with groups on the batch axes.
    * prefill (B < chips) — batch over the data axes; heads over 'model'
      when the head count divides it (Megatron attention), otherwise the
      residual stream is sequence-sharded over 'model' and attention runs
      the kv-chunk-only core (q never sliced).
    * decode — batch over data axes; KV caches sharded over 'model' on the
      sequence dim (flash-decode layout). long_500k (B=1): cache sharded
      over both axes.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    bax = batch_axes(mesh)
    batch = bax if len(bax) > 1 else (bax[0] if bax else None)
    n_batch_shards = 1
    for a in bax:
        n_batch_shards *= sizes[a]
    total_chips = n_batch_shards * model_size
    tokens = shape.global_batch * max(shape.seq_len, 1)
    heads_ok = model_size > 1 and arch.n_heads % model_size == 0
    ep_ok = False
    if arch.moe is not None and model_size > 1:
        from repro.models.moe import expert_split

        e_virt = arch.moe.n_experts * expert_split(arch)
        ep_ok = e_virt % model_size == 0

    if shape.kind == "decode":
        # decode MoE: EP over 'model' is safe — the dispatch buffers are a
        # few tokens, so even a GSPMD fallback reshard moves ~MBs
        moe_groups = n_batch_shards if tokens % n_batch_shards == 0 else 1
        if shape.global_batch == 1:  # long_500k
            return ShardingPolicy(batch=None, heads=None,
                                  kv_seq=("data", "model"), moe_groups=1,
                                  mesh=mesh)
        return ShardingPolicy(
            batch=batch, heads=None, kv_seq="model",
            moe_groups=moe_groups,
            moe_group_ax=batch if moe_groups > 1 else None,
            moe_ep_ax="model" if ep_ok else None,
            mesh=mesh,
        )

    if shape.global_batch % total_chips == 0:
        # FSDP-2D: batch over every axis. MoE dispatch is CHIP-LOCAL
        # (one group per chip, G = all chips): GSPMD cannot lower a
        # cross-'model' capacity scatter/gather to an all-to-all — it emits
        # full all-reduces of token-sized f32 tensors (measured 1655s
        # collective term for mixtral). Chip-local groups make dispatch
        # collective-free; expert weights are ZeRO-3-gathered per layer
        # like every other parameter. The shard_map a2a EP path is the
        # §Perf hillclimb on top of this baseline.
        full = tuple(bax) + ("model",)
        moe_groups = total_chips if tokens % total_chips == 0 else 1
        return ShardingPolicy(
            batch=full, heads=None, seq=None, kv_seq=None,
            moe_groups=moe_groups,
            moe_group_ax=full if moe_groups > 1 else None,
            moe_token_ax=None,
            moe_ep_ax=None,
            moe_a2a=bool(ep_ok and moe_groups > 1),
            mesh=mesh,
        )

    # small-batch train (multi-pod: 256 < 512 chips) or prefill: batch over
    # the data axes, heads over 'model' where divisible. The residual
    # stream is sequence-sharded when (a) heads cannot take 'model', or
    # (b) this is TRAINING (the scan carry must stay small per chip —
    # Megatron-SP at the block boundaries). Expert compute is f-sharded
    # over 'model' (groups sit on the data axes — no conflict).
    moe_groups = n_batch_shards if tokens % n_batch_shards == 0 else 1
    need_sp = (not heads_ok) or shape.kind == "train"
    seq_ax = "model" if (model_size > 1 and need_sp) else None
    return ShardingPolicy(
        batch=batch, heads="model" if heads_ok else None, kv_seq=None,
        seq=seq_ax,
        moe_groups=moe_groups,
        moe_group_ax=batch if moe_groups > 1 else None,
        moe_token_ax=None,
        moe_ep_ax=None,
        moe_f_ax="model" if model_size > 1 else None,
        mesh=mesh,
    )


def _token_specs(
    arch: ArchConfig, shape: ShapeConfig, policy: ShardingPolicy
) -> tuple[dict, dict]:
    """(structs, pspecs) for the data batch of this cell."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    bspec = policy.batch if b > 1 else None
    structs: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["tokens"] = P(bspec, None)
    if shape.kind == "train":
        structs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = P(bspec, None)
    if arch.family == "audio":
        structs["frames"] = jax.ShapeDtypeStruct(
            (b, arch.encoder.ctx_len, arch.d_model), jnp.float32
        )
        specs["frames"] = P(bspec, None, None)
    if arch.family == "vlm" and shape.kind != "decode":
        structs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, arch.encoder.ctx_len, arch.d_model), jnp.float32
        )
        specs["vision_embeds"] = P(bspec, None, None)
    return structs, specs


def build_cell(arch_name: str, shape_name: str, mesh) -> Cell:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        raise ValueError(f"{arch_name} x {shape_name}: {why}")
    policy = make_policy(mesh, shape, arch)
    lm = LM(arch, policy)
    param_defs = lm.param_defs()
    batch_structs, batch_specs = _token_specs(arch, shape, policy)
    cache_defs = None
    if shape.kind in ("prefill", "decode"):
        cache_defs = lm.cache_defs(shape.global_batch, shape.seq_len)
    return Cell(
        arch=arch,
        shape=shape,
        lm=lm,
        param_defs=param_defs,
        batch_structs=batch_structs,
        batch_specs=batch_specs,
        cache_defs=cache_defs,
    )


def opt_state_defs(param_defs: PyTree) -> PyTree:
    """OptState-shaped defs mirroring the params (Adam mu/nu).

    mu/nu must MATERIALIZE to zeros (optimizer.init semantics) — they
    mirror the params' shapes/shardings but not their init."""
    from repro.optim.optimizers import OptState

    zeroed = jax.tree.map(
        lambda d: dataclasses.replace(d, init="zeros"), param_defs,
        is_leaf=pdefs.is_def,
    )
    step = pdefs.ParamDef((), jnp.int32, (), "ones")
    return OptState(step=step, mu=zeroed, nu=zeroed)
