"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE: a
``lax.scan`` of 48 transformer blocks reports the FLOPs/bytes of one block
(empirically verified — an 8-iteration scan of matmuls reports exactly 1
matmul of FLOPs). Since this framework deliberately lowers depth as scans
(DESIGN.md §5: O(pattern) HLO keeps 512-device compiles tractable), the
built-in numbers undercount every roofline term by the trip count, and the
same undercount applies to collective wire bytes parsed from the HLO text
(the all-gather inside the while body executes ``reps`` times but appears
once).

This module re-derives the three roofline inputs from the optimized HLO:

* ``flops``      — dot FLOPs (2*M*N*K from result shape x contracting dims)
                   plus 1 flop/element for elementwise/reduce ops,
* ``bytes``      — per-instruction operand+result bytes at fusion
                   granularity (XLA's own convention for bytes-accessed),
* ``collectives``— ring-model wire bytes per op kind,

each multiplied by the product of enclosing while-loop trip counts. Trip
counts are extracted from the loop condition's ROOT compare against a
constant — the shape JAX's scan/fori_loop lowering always produces. Unknown
bounds conservatively count as 1 and are reported in ``unknown_loops``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

# one array type like bf16[16,4096,512]{2,1,0:T(8,128)} (layout stripped)
_ARRAY_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")

_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s+\((?P<params>.*?)\)\s*->"
)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s+=\s+(?P<type>\(.*?\)|\S+)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$"
)
_PARAM_RE = re.compile(r"%?(?P<name>[\w\.\-]+):\s*(?P<type>\([^)]*\)|[^,]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DIRECTION_RE = re.compile(r"direction=(\w+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_IOTA_FULL_RE = re.compile(
    r"replica_groups=\[(?P<dims>[\d,]+)\]<=\[(?P<src>[\d,]+)\]"
    r"(?:T\((?P<perm>[\d,]+)\))?"
)

_COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# opcodes that move no data / are free at runtime
_FREE_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "get-dimension-size", "opt-barrier", "custom-call",
})

# ~1 flop per output element
_ELEMENTWISE_HINT = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "not", "sign", "floor",
    "ceil", "round-nearest-afz", "clamp", "convert", "reduce", "map",
    "reduce-window", "exponential-minus-one", "log-plus-one", "cosine",
    "sine", "erf", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "stochastic-convert",
})


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        if m.group("dt") not in _DTYPE_BYTES:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _array_dims(type_str: str) -> list[int]:
    m = _ARRAY_RE.search(type_str)
    if not m or not m.group("dims"):
        return []
    return [int(d) for d in m.group("dims").split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the opening paren (operands + attrs)

    @property
    def operands(self) -> list[str]:
        # operand list ends at the first unbalanced ')'
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND_RE.findall(self.rest[:i])
        return _OPERAND_RE.findall(self.rest)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]  # instr/param name -> type string


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("{" in line) and "->" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group("name"), [], {})
                comps[cur.name] = cur
                for pm in _PARAM_RE.finditer(m.group("params")):
                    cur.shapes[pm.group("name")] = pm.group("type")
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group("name"), im.group("type"),
                        im.group("opcode"), im.group("rest"))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.type_str
    return comps


def _trip_count(cond: Computation) -> Optional[int]:
    """Extract the loop bound from the condition's compare-vs-constant."""
    consts: dict[str, int] = {}
    for ins in cond.instrs:
        cm = _CONST_RE.search(ins.rest)
        if ins.opcode == "constant" and cm:
            consts[ins.name] = int(cm.group(1))
    root = cond.instrs[-1] if cond.instrs else None
    for ins in cond.instrs:
        if ins.opcode == "compare":
            root = ins
    if root is None or root.opcode != "compare":
        return None
    dm = _DIRECTION_RE.search(root.rest)
    direction = dm.group(1) if dm else "LT"
    ops = root.operands
    bound = None
    for o in ops:
        if o in consts:
            bound = consts[o]
    if bound is None:
        return None
    if direction in ("LT", "GT"):
        return max(bound, 0)
    if direction in ("LE", "GE"):
        return max(bound + 1, 0)
    return None


@dataclasses.dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_bytes_dci: float = 0.0  # subset of wire crossing pod boundaries
    collectives: dict = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    unknown_loops: int = 0

    def scaled(self, k: float) -> "CostResult":
        return CostResult(
            self.flops * k, self.bytes * k, self.wire_bytes * k,
            self.wire_bytes_dci * k,
            {op: v * k for op, v in self.collectives.items()},
            self.collective_count, self.unknown_loops,
        )

    def add(self, other: "CostResult") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.wire_bytes += other.wire_bytes
        self.wire_bytes_dci += other.wire_bytes_dci
        for op, v in other.collectives.items():
            self.collectives[op] = self.collectives.get(op, 0.0) + v
        self.collective_count += other.collective_count
        self.unknown_loops += other.unknown_loops


def _group_size(rest: str) -> int:
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return 0


def _first_group_ids(rest: str) -> Optional[list[int]]:
    """Device ids of the first replica group (brace or iota format)."""
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return [int(x) for x in m.group(1).split(",")]
    m = _GROUPS_IOTA_FULL_RE.search(rest)
    if m:
        import numpy as _np

        dims = [int(x) for x in m.group("dims").split(",")]
        src = [int(x) for x in m.group("src").split(",")]
        n = 1
        for s in src:
            n *= s
        ids = _np.arange(n).reshape(src)
        if m.group("perm"):
            perm = [int(x) for x in m.group("perm").split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(dims)
        return list(ids[0 if len(dims) > 1 else slice(None)].reshape(-1)[: dims[-1]])
    return None


def crosses_pod(rest: str, chips_per_pod: int) -> bool:
    """True if the collective's replica groups span pod boundaries
    (device ids are pod-major in jax.make_mesh order)."""
    ids = _first_group_ids(rest)
    if not ids:
        return False
    pods = {i // chips_per_pod for i in ids}
    return len(pods) > 1


def _collective_wire(op: str, ins: Instr, comps, comp) -> float:
    """Ring-model wire bytes for one collective instruction (one execution).

    all-gather / all-reduce(-start) result types include the full gathered /
    reduced buffer; reduce-scatter's result is the scattered shard.
    """
    g = _group_size(ins.rest) or 8
    frac = (g - 1) / g
    out_bytes = _type_bytes(ins.type_str)
    if op == "all-reduce":
        return 2.0 * frac * out_bytes
    if op == "reduce-scatter":
        return frac * out_bytes * g
    if op == "collective-permute":
        return float(out_bytes)
    # all-gather, all-to-all
    return frac * out_bytes


class HloCost:
    """Walks the call graph multiplying while-loop trip counts."""

    def __init__(self, hlo_text: str, chips_per_pod: int = 0):
        self.chips_per_pod = chips_per_pod  # 0 = single pod (no DCI split)
        self.comps = parse_module(hlo_text)
        self._memo: dict[str, CostResult] = {}
        entry = None
        for name, c in self.comps.items():
            if re.match(r"main", name) or name.startswith("jit"):
                entry = name
        if entry is None and self.comps:
            # ENTRY is conventionally the last computation printed
            entry = list(self.comps)[-1]
        self.entry = entry

    def analyze(self) -> CostResult:
        if self.entry is None:
            return CostResult()
        return self._comp_cost(self.entry)

    # -- per-computation --------------------------------------------------

    def _comp_cost(self, name: str) -> CostResult:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        res = CostResult()
        if comp is None:
            self._memo[name] = res
            return res
        self._memo[name] = res  # break cycles defensively
        for ins in comp.instrs:
            res.add(self._instr_cost(ins, comp))
        return res

    def _operand_bytes(self, ins: Instr, comp: Computation) -> int:
        total = 0
        for o in ins.operands:
            t = comp.shapes.get(o)
            if t:
                total += _type_bytes(t)
        return total

    def _instr_cost(self, ins: Instr, comp: Computation) -> CostResult:
        op = ins.opcode
        res = CostResult()
        base = op.replace("-start", "")
        if op in _FREE_OPS or op.endswith("-done"):
            return res
        if base in _COLLECTIVE_OPS:
            wire = _collective_wire(base, ins, self.comps, comp)
            res.wire_bytes += wire
            res.collectives[base] = res.collectives.get(base, 0.0) + wire
            if self.chips_per_pod and crosses_pod(ins.rest,
                                                  self.chips_per_pod):
                res.wire_bytes_dci += wire
                res.collectives["dci:" + base] = (
                    res.collectives.get("dci:" + base, 0.0) + wire
                )
            res.collective_count += 1
            res.bytes += _type_bytes(ins.type_str)
            return res
        if op == "while":
            bm = _BODY_RE.search(ins.rest)
            cm = _COND_RE.search(ins.rest)
            if not bm:
                return res
            body = self._comp_cost(bm.group(1))
            # primary: XLA's own annotation on the while instruction
            tm = _TRIP_CFG_RE.search(ins.rest)
            trip = int(tm.group(1)) if tm else None
            if trip is None and cm and cm.group(1) in self.comps:
                trip = _trip_count(self.comps[cm.group(1)])
            if trip is None:
                res.unknown_loops += 1
                trip = 1
            res.add(body.scaled(float(trip)))
            return res
        if op == "fusion":
            cm = _CALLS_RE.search(ins.rest)
            in_place_root = False
            if cm:
                inner = self._comp_cost(cm.group(1))
                # fused elementwise/dot flops count; bytes are the fusion's
                # own operands+result (fusion internals stay in registers)
                res.flops += inner.flops
                res.wire_bytes += inner.wire_bytes
                for k, v in inner.collectives.items():
                    res.collectives[k] = res.collectives.get(k, 0.0) + v
                res.collective_count += inner.collective_count
                res.unknown_loops += inner.unknown_loops
                callee = self.comps.get(cm.group(1))
                if callee and callee.instrs:
                    in_place_root = callee.instrs[-1].opcode
            op_bytes = [
                _type_bytes(comp.shapes.get(o, "")) for o in ins.operands
            ]
            small = sum(op_bytes) - (max(op_bytes) if op_bytes else 0)
            result_b = _type_bytes(ins.type_str)
            if in_place_root in ("dynamic-update-slice", "scatter", "pad"):
                # writes a slice into a big (aliased / fused-consumer)
                # buffer: traffic = slice inputs in + slice out, NOT the
                # buffer twice (a scan backward accumulating d_xs would
                # otherwise charge the full stacked gradient PER STEP)
                res.bytes += 2.0 * small
            elif in_place_root in ("dynamic-slice", "slice", "gather"):
                # reads a slice of a big source: result + small operands
                res.bytes += 2.0 * result_b + small
            else:
                res.bytes += result_b + self._operand_bytes(ins, comp)
            return res
        if op in ("call", "conditional", "async-start"):
            cm = _CALLS_RE.search(ins.rest)
            if cm:
                res.add(self._comp_cost(cm.group(1)))
            return res
        if op == "dot":
            out_elems = _type_elems(ins.type_str)
            k_prod = 1
            ops_ = ins.operands
            lhs_t = comp.shapes.get(ops_[0]) if ops_ else None
            cm = _CONTRACT_RE.search(ins.rest)
            if lhs_t and cm and cm.group(1):
                dims = _array_dims(lhs_t)
                for di in cm.group(1).split(","):
                    i = int(di)
                    if i < len(dims):
                        k_prod *= dims[i]
            res.flops += 2.0 * out_elems * k_prod
            res.bytes += _type_bytes(ins.type_str) + self._operand_bytes(
                ins, comp
            )
            return res
        if op == "convolution":
            # not used by this framework (frontends are stubs); approximate
            res.flops += 2.0 * _type_elems(ins.type_str)
            res.bytes += _type_bytes(ins.type_str) + self._operand_bytes(
                ins, comp
            )
            return res
        if op in ("dynamic-update-slice", "scatter"):
            # executed in place on TPU (donated/aliased buffers): traffic is
            # the updated slice read+write, not the whole buffer twice
            upd_idx = 1 if op == "dynamic-update-slice" else 2
            upd_bytes = 0
            ops_ = ins.operands
            if len(ops_) > upd_idx:
                t = comp.shapes.get(ops_[upd_idx])
                if t:
                    upd_bytes = _type_bytes(t)
            res.bytes += 2.0 * upd_bytes
            return res
        if op in ("dynamic-slice", "slice", "gather"):
            # reads only the sliced/gathered rows, not the whole source
            # (a scan slicing its xs per step would otherwise charge the
            # full stacked input once PER ITERATION — petabytes of phantom
            # traffic for sLSTM's 32k-step scans)
            res.bytes += 2.0 * _type_bytes(ins.type_str)
            return res
        # default: elementwise-ish — 1 flop per output element, move bytes
        if base in _ELEMENTWISE_HINT:
            res.flops += float(_type_elems(ins.type_str))
        res.bytes += _type_bytes(ins.type_str) + self._operand_bytes(ins, comp)
        return res


def analyze_hlo(hlo_text: str, chips_per_pod: int = 0) -> CostResult:
    return HloCost(hlo_text, chips_per_pod=chips_per_pod).analyze()


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def top_costs(hlo_text: str, n: int = 25) -> list[dict]:
    """The n heaviest instructions by trip-multiplied bytes — the §Perf
    profiling view (no wall-clock on CPU; this is the structural profile).

    Returns records {bytes, flops, trips, opcode, name, op_name} sorted by
    bytes descending. Instructions inside while bodies are scaled by the
    product of enclosing trip counts.
    """
    hc = HloCost(hlo_text)
    hc.analyze()  # memoize
    # multiplier per computation: entry=1; while bodies scale by trip
    mult: dict[str, float] = {hc.entry: 1.0} if hc.entry else {}
    frontier = [hc.entry] if hc.entry else []
    while frontier:
        cname = frontier.pop()
        comp = hc.comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            callees: list[tuple[str, float]] = []
            if ins.opcode == "while":
                bm = _BODY_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                tm = _TRIP_CFG_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
                if bm:
                    callees.append((bm.group(1), m * trip))
                if cm:
                    callees.append((cm.group(1), m))
            elif ins.opcode in ("call", "conditional"):
                # NOT fusion: fused interiors never touch HBM; the fusion
                # instruction row already carries their flops
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    callees.append((cm.group(1), m))
            for cn, cm_ in callees:
                if cn not in mult or mult[cn] < cm_:
                    mult[cn] = cm_
                    frontier.append(cn)
    rows = []
    for cname, m in mult.items():
        comp = hc.comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in ("while", "call", "conditional"):
                continue
            c = hc._instr_cost(ins, comp)
            if c.bytes <= 0 and c.flops <= 0 and c.wire_bytes <= 0:
                continue
            md = _METADATA_RE.search(ins.rest)
            rows.append({
                "bytes": c.bytes * m,
                "flops": c.flops * m,
                "wire": c.wire_bytes * m,
                "trips": m,
                "opcode": ins.opcode,
                "name": ins.name,
                "op_name": md.group(1)[-120:] if md else "",
            })
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:n]
