"""Step-function builders: BSP train, ISP-compressed train, prefill, decode.

These are the exact functions the dry-run lowers and the drivers execute —
one definition, both uses (the anti-drift rule again).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.isp import ISPConfig
from repro.dist.compression import CompressionConfig, isp_compressed_step
from repro.models.transformer import LM
from repro.optim import Optimizer, apply_updates, clip_by_global_norm

PyTree = Any


def make_train_step(lm: LM, optimizer: Optimizer, clip_norm: float = 1.0):
    """BSP data-parallel train step (gradient reduction via GSPMD).

    This is the single-program analogue of the paper's BSP baseline: every
    shard's gradient contribution is summed every step, dense.
    """

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.train_loss, has_aux=True
        )(params, batch)
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics

    return train_step


def make_isp_train_step(
    lm: LM,
    optimizer: Optimizer,
    mesh,
    isp_cfg: ISPConfig,
    comp_cfg: CompressionConfig,
    clip_norm: float = 1.0,
):
    """ISP-over-pods train step (DESIGN.md §2), pure-GSPMD formulation.

    The pod dim is a LEADING TENSOR DIM sharded over 'pod' (a partial-manual
    shard_map over 'pod' with nested auto data/model trips an XLA SPMD
    partitioner CHECK — spmd_partitioner_util.cc:504). Per pod (vmap):
    local gradient -> local optimizer (divergent moments, the paper's
    per-worker state) -> significance split against the shared params ->
    compressed exchange -> apply. Exchange semantics by scheme:

    * dense — sum the filtered updates over the pod dim: GSPMD emits a
      dense all-reduce over 'pod' (the ISP-semantics baseline: exact filter,
      no wire saving — the paper's observation that arbitrary-sparsity
      updates don't compress a dense collective).
    * topk — per pod, compact (values, indices) block-top-k; a scan over
      pods dynamic-slices each pod's COMPACT arrays (GSPMD moves only those
      bytes across 'pod') and scatter-adds into a replicated accumulator.
      Wire per step ~ 2 * budget * n_params * 8B instead of 2 * n_params *
      4B — the paper's Redis byte reduction, ICI form.

    ``lm`` must carry a pod-stripped policy (launch.dryrun strips it).
    """
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)

    def pod_fn(params, opt_state, res, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.train_loss, has_aux=True
        )(params, batch)
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        v_t = isp_cfg.threshold(opt_state.step)
        from repro.core.isp import significance_split

        out = jax.tree.map(
            lambda u, x, r: significance_split(
                r + u, x, v_t, isp_cfg.absolute_floor
            ),
            updates, params, res,
        )
        td = jax.tree.structure(params)
        ls = td.flatten_up_to(out)
        sig = td.unflatten([l[0] for l in ls])
        res2 = td.unflatten([l[1] for l in ls])
        nz = sum(
            jnp.sum(l[2].astype(jnp.float32)) for l in ls
        )
        total = float(sum(l[2].size for l in ls))
        return sig, opt_state, res2, loss, nz / total

    def train_step(params, opt_pod, res_pod, batch):
        # (B, ...) -> (n_pods, B/n_pods, ...): dim0 shards over 'pod'
        batch_p = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods)
                                + x.shape[1:]),
            batch,
        )
        sig_pod, opt_pod, res_pod, losses, fracs = jax.vmap(
            pod_fn, in_axes=(None, 0, 0, 0)
        )(params, opt_pod, res_pod, batch_p)

        if comp_cfg.scheme in ("dense", "bitmap"):
            # bitmap is a wire ENCODING of the same numbers (mask + packed
            # values); the lowered collective is the dense sum either way
            combined = jax.tree.map(lambda s: jnp.sum(s, axis=0), sig_pod)
        else:  # topk: compact exchange over the pod dim
            combined = _topk_combine(comp_cfg, sig_pod, n_pods)
        new_params = jax.tree.map(
            lambda p_, c: (p_ + c).astype(p_.dtype), params, combined
        )
        return (new_params, opt_pod, res_pod, jnp.mean(losses),
                jnp.mean(fracs))

    return train_step


def _topk_combine(comp_cfg: CompressionConfig, sig_pod, n_pods: int):
    """Row-top-k compact exchange — canonical form in ``dist.compression``
    (``topk_combine``); kept under this name for the dry-run/test contract.
    """
    from repro.dist.compression import topk_combine

    return topk_combine(comp_cfg, sig_pod, n_pods)


def Pspec_replicated() -> P:
    return P()


def make_prefill_step(lm: LM):
    def prefill_step(params, cache, batch):
        return lm.prefill(params, cache, batch)

    return prefill_step


def make_decode_step(lm: LM):
    def decode_step(params, cache, batch, pos):
        return lm.decode_step(params, cache, batch, pos)

    return decode_step


def make_eval_step(lm: LM):
    def eval_step(params, batch):
        loss, metrics = lm.train_loss(params, batch)
        return metrics["xent"]

    return eval_step
