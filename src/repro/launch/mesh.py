"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets XLA_FLAGS before first init;
smoke tests must keep seeing 1 CPU device).

Mesh axes:
  * ``pod``   — pure data parallelism across pods/slices; the slow (DCI)
    axis. This is the MLLess *worker* axis: the ISP significance filter
    compresses gradient exchange across it (DESIGN.md §2).
  * ``data``  — within-pod data parallel + FSDP (params/optimizer sharded).
  * ``model`` — tensor/expert parallel + sequence parallel for activations.
"""

from __future__ import annotations

import enum
from typing import Optional

import jax

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType

    _HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x: every mesh axis is implicitly Auto

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPES = False


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh across the 0.4/0.5 axis_types API change."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target mesh: 16x16 single pod (256 chips) or
    2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic pool sizes, CPU smoke meshes)."""
    return _mk(shape, axes)


def make_elastic_mesh(n_pods: int, data: int = 16, model: int = 16):
    """Mesh for a scaled-in pool of ``n_pods`` pods (the auto-tuner's
    transition target). n_pods == 1 drops the pod axis entirely; the
    shape/axes schedule is owned by ``dist.elastic.mesh_shape_for``."""
    from repro.dist.elastic import mesh_axes_for, mesh_shape_for

    return make_mesh(
        mesh_shape_for(n_pods, data, model), mesh_axes_for(n_pods)
    )


def has_axis(mesh, name: str) -> bool:
    return name in mesh.axis_names


def batch_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of this mesh, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
