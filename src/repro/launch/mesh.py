"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets XLA_FLAGS before first init;
smoke tests must keep seeing 1 CPU device).

Mesh axes:
  * ``pod``   — pure data parallelism across pods/slices; the slow (DCI)
    axis. This is the MLLess *worker* axis: the ISP significance filter
    compresses gradient exchange across it (DESIGN.md §2).
  * ``data``  — within-pod data parallel + FSDP (params/optimizer sharded).
  * ``model`` — tensor/expert parallel + sequence parallel for activations.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target mesh: 16x16 single pod (256 chips) or
    2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic pool sizes, CPU smoke meshes)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_elastic_mesh(n_pods: int, data: int = 16, model: int = 16):
    """Mesh for a scaled-in pool of ``n_pods`` pods (the auto-tuner's
    transition target). n_pods == 1 drops the pod axis entirely."""
    if n_pods == 1:
        return make_mesh((data, model), ("data", "model"))
    return make_mesh((n_pods, data, model), ("pod", "data", "model"))


def has_axis(mesh, name: str) -> bool:
    return name in mesh.axis_names


def batch_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of this mesh, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
