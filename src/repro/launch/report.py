"""Roofline report: results/dryrun/*.json -> the EXPERIMENTS.md §Roofline table.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(results_dir: str, mesh_tag: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(f))
        name = os.path.basename(f)[: -len(".json")]
        parts = name.split("__")
        if len(parts) != 4 or parts[2] != mesh_tag:
            continue
        r["_cell"] = name
        r["_arch"], r["_shape"] = parts[0], parts[1]
        rows.append(r)
    return rows


_ARCH_ORDER = (
    "whisper-base", "phi4-mini-3.8b", "gemma3-12b", "qwen1.5-32b",
    "starcoder2-7b", "mixtral-8x22b", "phi3.5-moe-42b-a6.6b",
    "recurrentgemma-9b", "xlstm-1.3b", "paligemma-3b",
)
_SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def fmt_table(rows: list[dict]) -> str:
    idx = {(r["_arch"], r["_shape"]): r for r in rows}
    lines = [
        "| arch | shape | compute s | memory s | collective s | dci s |"
        " bottleneck | MODEL/HLO flops | roofline frac | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in _ARCH_ORDER:
        for s in _SHAPE_ORDER:
            r = idx.get((a, s))
            if r is None:
                lines.append(
                    f"| {a} | {s} | - | - | - | - | MISSING | - | - | - |"
                )
                continue
            st = r.get("status", "?")
            if st != "ok":
                lines.append(
                    f"| {a} | {s} | - | - | - | - | {st.split(':')[0]} |"
                    " - | - | - |"
                )
                continue
            lines.append(
                "| {a} | {s} | {c:.3f} | {m:.3f} | {k:.3f} | {d:.3f} |"
                " **{b}** | {u:.2f} | {f:.3f} | {fit} |".format(
                    a=a, s=s,
                    c=r["compute_term_s"], m=r["memory_term_s"],
                    k=r["collective_term_s"],
                    d=r.get("dci_term_s", 0.0),
                    b=r["bottleneck"],
                    u=r["useful_flops_ratio"], f=r["roofline_fraction"],
                    fit="yes" if r.get("fits_hbm_16gb") else "NO",
                )
            )
    return "\n".join(lines)


def summary_stats(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if str(r.get("status", "")).startswith("skip")]
    err = [r for r in rows if str(r.get("status", "")).startswith("error")]
    bn = {}
    for r in ok:
        bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
    return {
        "ok": len(ok), "skipped": len(skipped), "errors": len(err),
        "bottlenecks": bn,
        "worst": sorted(ok, key=lambda r: r["roofline_fraction"])[:3],
        "best": sorted(ok, key=lambda r: -r["roofline_fraction"])[:3],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(fmt_table(rows))
    st = summary_stats(rows)
    print(f"\nok={st['ok']} skipped={st['skipped']} errors={st['errors']} "
          f"bottlenecks={st['bottlenecks']}")
    if st["ok"]:
        print("worst roofline:",
              [(r["_cell"], round(r["roofline_fraction"], 4))
               for r in st["worst"]])
        print("best  roofline:",
              [(r["_cell"], round(r["roofline_fraction"], 4))
               for r in st["best"]])


if __name__ == "__main__":
    main()
