"""Update broker — the RabbitMQ/Redis stand-in of the FaaS runtime.

One process (or one thread of the supervisor) owns all shared state of a
training job; workers talk to it over *persistent* local TCP connections
(``repro.wire.framing``) — one connection per worker invocation, one
handler thread per connection, any number of framed request/response
round trips (DESIGN.md §10.3).  Responsibilities, mirroring MLLess's
messaging VM + KV store (paper §5):

* **update store / pub-sub**: workers publish their significance-filtered
  update for step t and pull the peers' updates for t; the pull blocks until
  the ISP barrier for t is met (every worker active at t has published, and
  every worker *evicted at* t has flushed).  Updates are retained so a
  respawned worker can replay any step — the store IS the fault-tolerance
  log, like the iteration keys MLLess leaves in Redis.
* **minibatch keys**: deterministic round-robin assignment
  ``((step - 1) * P + worker) % n_batches`` (steps are 1-indexed;
  ``data.store.MinibatchStore``'s partitioning), served per request like
  the COS key scheme of the paper.
* **membership**: the supervisor requests evictions; the broker picks the
  effective step ``e = max_published + 2`` so no worker can have computed a
  step with a stale pool size (a worker only begins step t after pulling
  t-1, and every response from here on carries the eviction table).
* **telemetry**: per-(step, worker) loss / duration / sent-fraction /
  conservation-error rows, aggregated per completed step for the
  supervisor's auto-tuner poll.
* **byte accounting**: per-message-type request/response byte counters —
  the measured analogue of ``core.billing.CommModel``.

The broker never decodes tensor payloads (workers own the math); it stores
raw bytes plus a digest so duplicate publishes from a replayed worker can be
verified bit-identical (``dup_mismatches`` must stay 0 — determinism check).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import socket
import socketserver
import threading
from typing import Optional

from repro.runtime import protocol


class BrokerCore:
    """All job state + request handling, guarded by one lock/condition."""

    def __init__(self, job: dict):
        self.job = dict(job)
        self.P = int(job["n_workers"])
        self.n_batches = int(job.get("n_batches", 1))
        self.total_steps = int(job["total_steps"])
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # step -> worker -> (meta, payload, digest)
        self.updates: dict[int, dict[int, tuple[list, bytes, str]]] = {}
        # step -> worker -> (meta, payload, digest)   (eviction flushes)
        self.flushes: dict[int, dict[int, tuple[list, bytes, str]]] = {}
        # (step, worker) -> telemetry dict
        self.telemetry: dict[tuple[int, int], dict] = {}
        self.evictions: dict[int, int] = {}  # worker -> effective step
        self.statuses: dict[int, str] = {w: "spawned" for w in range(self.P)}
        self.max_published = 0
        self.dup_mismatches = 0
        self._poll_cursor = 1  # next telemetry step the supervisor hasn't seen
        self.stats: dict[str, dict[str, int]] = {}
        self.shutting_down = False

    # -- membership -----------------------------------------------------------

    def active_at(self, step: int) -> list[int]:
        return [
            w
            for w in range(self.P)
            if w not in self.evictions or step < self.evictions[w]
        ]

    def _barrier_ready(self, step: int) -> bool:
        pubs = self.updates.get(step, {})
        if any(w not in pubs for w in self.active_at(step)):
            return False
        fl = self.flushes.get(step, {})
        return all(
            q in fl for q, e in self.evictions.items() if e == step
        )

    def _telemetry_complete(self, step: int) -> bool:
        return all(
            (step, w) in self.telemetry
            and "dur_s" in self.telemetry[(step, w)]
            for w in self.active_at(step)
        )

    # -- request dispatch -----------------------------------------------------

    def handle(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        kind = header.get("t", "?")
        fn = getattr(self, f"_op_{kind}", None)
        if fn is None:
            return {"ok": False, "error": f"unknown message type {kind!r}"}, b""
        return fn(header, payload)

    def _membership(self) -> dict:
        return {"evictions": {str(k): v for k, v in self.evictions.items()}}

    def _op_hello(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            w = int(h["worker"])
            self.statuses[w] = "running"
            resp = {"ok": True, "job": self.job, **self._membership()}
        return resp, b""

    def batch_key(self, step: int, worker: int) -> int:
        """Deterministic round-robin minibatch key for (step, worker)."""
        return ((step - 1) * self.P + worker) % self.n_batches

    def _op_batch(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        key = self.batch_key(step, worker)
        with self._lock:
            return {"ok": True, "key": key, **self._membership()}, b""

    def _op_publish(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        meta = h["meta"]
        digest = hashlib.sha1(
            json.dumps(meta, sort_keys=True).encode() + payload
        ).hexdigest()
        with self._cond:
            slot = self.updates.setdefault(step, {})
            dup = worker in slot
            if dup:
                if slot[worker][2] != digest:
                    self.dup_mismatches += 1
            else:
                slot[worker] = (meta, payload, digest)
                self.max_published = max(self.max_published, step)
            self.telemetry.setdefault((step, worker), {}).update(
                {
                    "loss": h.get("loss"),
                    "sent_fraction": h.get("sent_fraction"),
                    "inv_err": h.get("inv_err"),
                    "wire_bytes": protocol.wire_bytes(meta),
                }
            )
            self._cond.notify_all()
            return {"ok": True, "dup": dup, **self._membership()}, b""

    def _op_flush(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        digest = hashlib.sha1(
            json.dumps(h["meta"], sort_keys=True).encode() + payload
        ).hexdigest()
        with self._cond:
            slot = self.flushes.setdefault(step, {})
            dup = worker in slot
            if dup:
                # a replayed flush must be bit-identical too — survivors may
                # already have applied the first copy
                if slot[worker][2] != digest:
                    self.dup_mismatches += 1
            else:
                slot[worker] = (h["meta"], payload, digest)
            self._cond.notify_all()
        return {"ok": True, "dup": dup}, b""

    def _op_pull(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        timeout = float(h.get("timeout_s", 2.0))
        with self._cond:
            ready = self._cond.wait_for(
                lambda: self._barrier_ready(step) or self.shutting_down,
                timeout=timeout,
            )
            if self.shutting_down:
                return {"ok": False, "abort": True}, b""
            if not ready or not self._barrier_ready(step):
                return {"ok": True, "ready": False, **self._membership()}, b""
            parts = []
            for w in sorted(self.active_at(step)):
                if w == worker:
                    continue
                meta, blob, _ = self.updates[step][w]
                parts.append(({"worker": w, "meta": meta}, blob))
            for q in sorted(self.flushes.get(step, {})):
                if self.evictions.get(q) == step:
                    meta, blob, _ = self.flushes[step][q]
                    parts.append(
                        ({"worker": q, "meta": meta, "flush": True}, blob)
                    )
            descs, payload = protocol.pack_parts(parts)
            resp = {
                "ok": True,
                "ready": True,
                "parts": descs,
                # coalesced pull: piggyback the NEXT step's minibatch key so
                # the steady-state worker loop is exactly two round trips per
                # ISP barrier (publish + pull) instead of four one-shot RPCs
                "key_next": self.batch_key(step + 1, worker),
                **self._membership(),
            }
        return resp, payload

    def _op_report(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        with self._lock:
            cell = self.telemetry.setdefault((step, worker), {})
            cell["dur_s"] = float(h["dur_s"])
            if "phase" in h:  # per-phase data-path breakdown (DESIGN.md §10)
                cell["phase"] = {
                    k: float(v) for k, v in h["phase"].items()
                }
        return {"ok": True}, b""

    def _op_bye(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            self.statuses[int(h["worker"])] = f"bye:{h.get('reason', '?')}"
        return {"ok": True}, b""

    def _op_evict(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        worker = int(h["worker"])
        with self._cond:
            if worker in self.evictions:
                return {
                    "ok": True, "granted": True,
                    "evict_step": self.evictions[worker],
                }, b""
            # effective at a step no worker can have begun with the old
            # pool; distinct from every prior eviction's step — with ONE
            # leaver per step the survivors' sequential mean-preserving
            # pulls x += (flush - x)/P_old stay exact (two flushes at the
            # same step with the same divisor would drift the pool mean)
            step = max(
                self.max_published + 2,
                max(self.evictions.values(), default=0) + 1,
            )
            if step > self.total_steps:
                # the pool finishes before the eviction could take effect —
                # granting it would strand a flush no survivor ever pulls
                return {"ok": True, "granted": False,
                        "reason": "past-end"}, b""
            self.evictions[worker] = step
            self._cond.notify_all()
        return {"ok": True, "granted": True, "evict_step": step}, b""

    def _op_poll(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        # with a client-supplied cursor ('since') the poll is IDEMPOTENT —
        # a lost response replayed over a reconnecting wire.Connection
        # returns the same rows instead of dropping them; the server-side
        # cursor only backs cursor-less (legacy/debug) callers
        stateless = "since" in h
        with self._lock:
            rows = []
            step = int(h["since"]) if stateless else self._poll_cursor
            while step <= self.total_steps and self._telemetry_complete(step):
                active = self.active_at(step)
                cells = [self.telemetry[(step, w)] for w in active]
                row = {
                    "step": step,
                    "loss": _mean([c["loss"] for c in cells]),
                    "dur_s": _mean([c["dur_s"] for c in cells]),
                    "sent_fraction": _mean(
                        [c["sent_fraction"] for c in cells]
                    ),
                    "inv_err": max(
                        float(c["inv_err"] or 0.0) for c in cells
                    ),
                    "wire_bytes": float(
                        sum(c["wire_bytes"] for c in cells)
                    ),
                    "p_active": len(active),
                }
                phases = [c["phase"] for c in cells if "phase" in c]
                if phases:
                    row["phase"] = {
                        k: _mean([p.get(k) for p in phases])
                        for k in phases[0]
                    }
                rows.append(row)
                step += 1
            if not stateless:
                self._poll_cursor = step
            resp = {
                "ok": True,
                "rows": rows,
                "statuses": {str(k): v for k, v in self.statuses.items()},
                "max_published": self.max_published,
                "dup_mismatches": self.dup_mismatches,
                **self._membership(),
            }
        return resp, b""

    def _op_dump(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Test/debug hook: every stored update as one multi-part payload."""
        with self._lock:
            parts = []
            for step in sorted(self.updates):
                for w in sorted(self.updates[step]):
                    meta, blob, _ = self.updates[step][w]
                    parts.append(
                        ({"worker": w, "step": step, "meta": meta}, blob)
                    )
            descs, payload = protocol.pack_parts(parts)
        return {"ok": True, "parts": descs}, payload

    def _op_stats(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            return {"ok": True, "stats": self.stats}, b""

    def _op_shutdown(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._cond:
            self.shutting_down = True
            self._cond.notify_all()
            return {"ok": True, "stats": self.stats}, b""

    # -- accounting -----------------------------------------------------------

    def account(self, kind: str, bytes_in: int, bytes_out: int) -> None:
        with self._lock:
            row = self.stats.setdefault(
                kind, {"count": 0, "bytes_in": 0, "bytes_out": 0}
            )
            row["count"] += 1
            row["bytes_in"] += bytes_in
            row["bytes_out"] += bytes_out


def _mean(xs) -> Optional[float]:
    vals = [float(x) for x in xs if x is not None]
    return sum(vals) / len(vals) if vals else None


# -- TCP server shell ---------------------------------------------------------


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:  # one persistent connection, many requests
        core: BrokerCore = self.server.core  # type: ignore[attr-defined]
        try:
            self.request.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while True:
                header, payload = protocol.recv_msg(self.request)
                resp, blob = core.handle(header, payload)
                out = protocol.send_msg(self.request, resp, blob)
                hdr_len = len(json.dumps(header, separators=(",", ":")))
                core.account(
                    header.get("t", "?"), 8 + hdr_len + len(payload), out
                )
                if core.shutting_down:
                    break
        except (ConnectionError, ValueError, OSError):
            pass  # client vanished mid-stream; nothing to clean up


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class Broker:
    """Socket-server shell around ``BrokerCore``; in-thread or standalone."""

    def __init__(self, job: dict, host: str = "127.0.0.1", port: int = 0):
        self.core = BrokerCore(job)
        self._server = _Server((host, port), _Handler)
        self._server.core = self.core  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    @property
    def addr(self) -> tuple[str, int]:
        return self._server.server_address[:2]

    def start(self) -> tuple[str, int]:
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05},
            daemon=True,
        )
        self._thread.start()
        return self.addr

    def stop(self) -> None:
        with self.core._cond:
            self.core.shutting_down = True
            self.core._cond.notify_all()
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", required=True, help="job config JSON file")
    ap.add_argument("--port", type=int, default=0)
    args = ap.parse_args()
    with open(args.config) as f:
        job = json.load(f)
    broker = Broker(job, port=args.port)
    host, port = broker.start()
    print(f"broker listening on {host}:{port}", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        broker.stop()


if __name__ == "__main__":
    main()
