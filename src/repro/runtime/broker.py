"""Update broker shard — the sharded Redis stand-in of the FaaS runtime.

The paper scales its external store by sharding keys across Redis
instances (§5); here the live update store is partitioned by *leaf key*
(``runtime.sharding``) over N broker shards, each its own process running
this module's handler loop.  Workers talk to every shard over *persistent*
local TCP connections (``repro.wire.framing``) — one connection per shard
per worker invocation, one handler thread per connection, any number of
framed request/response round trips (DESIGN.md §10.3, §11).

Responsibilities of every shard:

* **update store / pub-sub** for the leaves it owns: workers publish their
  significance-filtered slice for step t and pull the peers' slices for t;
  the pull blocks until the shard's ISP barrier for t is met (every worker
  active at t has published its slice here, and every worker *evicted at*
  t has flushed its slice here).  Updates are retained so a respawned
  worker can replay any step — the store IS the fault-tolerance log, like
  the iteration keys MLLess leaves in Redis.
* **byte accounting**: per-message-type request/response byte counters
  plus ``update_bytes`` (codec-accounted published update bytes) — the
  measured analogue of ``core.billing.CommModel``, per shard.
* **write-ahead log**: every state-mutating request is appended (framed,
  synchronously, BEFORE the response) to an on-disk WAL; a respawned
  shard replays it and resumes bit-identically — acked means logged, so
  a SIGKILL loses at most unacknowledged requests, which the workers'
  idempotent RPC layer retries.

The request/response loop itself is *transport-generic* (DESIGN.md §12):
the same handler loop serves a persistent TCP connection (one thread per
socket) or a shared-memory ring-buffer channel (one thread per
``wire.shm`` segment, attached on a ``shm_serve`` control request from
the supervisor).  ``BrokerCore`` never sees the difference — headers,
payload bytes, WAL records and byte accounting are identical on both
transports by construction.  The supervisor's control plane (poll /
evict / shutdown / shm_serve itself) always rides TCP.

The *coordinator* (shard 0) additionally owns everything that must be
globally consistent — the paper's messaging-VM role:

* **minibatch keys**: deterministic round-robin assignment
  ``((step - 1) * P + worker) % n_batches`` served per request and
  piggybacked on ready pulls (``key_next``);
* **membership**: the supervisor requests evictions; the coordinator picks
  the effective step ``e = max_published + 2`` so no worker can have
  computed a step with a stale pool size (a worker only begins step t
  after pulling t-1 from the coordinator, and every coordinator response
  carries the eviction table).  The supervisor then installs the granted
  ``(worker, step)`` on the other shards via ``evict_apply`` — a shard
  with a not-yet-synced table merely blocks its step-e barrier
  conservatively (it still expects the leaver's publish), never serves it
  short;
* **telemetry**: per-(step, worker) loss / duration / sent-fraction /
  conservation-error rows, aggregated per completed step for the
  supervisor's auto-tuner poll.

No shard ever decodes tensor payloads (workers own the math); it stores
raw bytes plus a digest so duplicate publishes from a replayed worker can
be verified bit-identical (``dup_mismatches`` must stay 0 — the
determinism check, which a broker-shard respawn is also held to).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import socketserver
import struct
import threading
import zlib
from typing import Optional

from repro.runtime import protocol

# ops that mutate shard state — exactly what the WAL must persist.
# publish/flush log from inside their handlers (only non-dup records, with
# the store lock held, BEFORE the update becomes pullable); the rest log
# generically from handle().  The live-reshard ops (DESIGN.md §16) are
# parameter-complete in their headers, so generic log-then-apply replays
# them exactly; topo_begin mints its fence and logs the RESULT instead
# (mint-at-replay could diverge, like evict), and migrate_read is
# read-only.
_MUTATING = ("hello", "report", "bye", "evict_apply",
             "migrate_in", "migrate_drop", "topo_commit")

# header_len, payload_len, crc32(header bytes + payload bytes)
_WAL_HDR = struct.Struct("<III")
# a header JSON larger than this cannot have been written by append() —
# a full-size length word this absurd is a corrupted record, not a torn
# tail (tearing only truncates; it never rewrites committed bytes)
_WAL_MAX_HLEN = 1 << 24
_WAL_MAX_PLEN = 1 << 31


class WALCorruption(Exception):
    """A fully-present WAL record failed its CRC (or carries impossible
    lengths): the log was *altered*, not torn.  ``valid_end`` is the byte
    offset of the last record that verified."""

    def __init__(self, path: str, valid_end: int):
        super().__init__(
            f"WAL {path}: corrupt record after byte {valid_end}")
        self.path = path
        self.valid_end = valid_end


class WriteAheadLog:
    """Append-only framed (header JSON, payload) log with per-record CRC.

    A record is ``uint32 hlen | uint32 plen | uint32 crc32 | header |
    payload``, flushed per append.  Two distinct failure modes on
    replay (DESIGN.md §17.3):

    * **torn tail** — a short read mid-final-record.  A SIGKILL mid-
      append can truncate at most that record, which was never acked and
      will be retried by its sender: silently truncated.
    * **corruption** — a fully-present record whose CRC mismatches (a
      flipped byte anywhere in lengths/header/payload).  Replaying past
      it could rebuild *wrong* state behind acked responses, so the
      replay raises ``WALCorruption`` and the attach path quarantines
      the unreadable suffix instead of serving from it.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")
        self._lock = threading.Lock()

    def append(self, header: dict, payload: bytes) -> None:
        raw = json.dumps(header, separators=(",", ":")).encode("utf-8")
        crc = zlib.crc32(payload, zlib.crc32(raw))
        with self._lock:
            self._f.write(_WAL_HDR.pack(len(raw), len(payload), crc))
            self._f.write(raw)
            if payload:
                self._f.write(payload)
            self._f.flush()  # survive process death (not host death)

    def close(self) -> None:
        with self._lock:
            self._f.close()

    @staticmethod
    def iter_records_with_end(path: str):
        """Yield (header, payload, end_offset) records, stopping at a torn
        tail; ``end_offset`` is the byte offset just past the record.
        Raises ``WALCorruption`` on a CRC-failed (altered) record."""
        with open(path, "rb") as f:
            off = 0
            while True:
                head = f.read(_WAL_HDR.size)
                if len(head) < _WAL_HDR.size:
                    return
                hlen, plen, crc = _WAL_HDR.unpack(head)
                if hlen > _WAL_MAX_HLEN or plen > _WAL_MAX_PLEN:
                    raise WALCorruption(path, off)
                raw = f.read(hlen)
                payload = f.read(plen)
                if len(raw) < hlen or len(payload) < plen:
                    return  # torn tail: the op was never acked
                if zlib.crc32(payload, zlib.crc32(raw)) != crc:
                    raise WALCorruption(path, off)
                off += _WAL_HDR.size + hlen + plen
                yield json.loads(raw.decode("utf-8")), payload, off

    @staticmethod
    def iter_records(path: str):
        """Yield (header, payload) records, stopping at a torn tail."""
        for header, payload, _ in WriteAheadLog.iter_records_with_end(path):
            yield header, payload


def replay_wal(path: str, dispatch) -> tuple[int, int]:
    """Replay a WAL's valid prefix through ``dispatch(header, payload)``.

    Returns ``(records_replayed, quarantined_bytes)``.  A torn tail (an
    unacked final record) is silently truncated, exactly as before; a
    CRC-corrupt record quarantines everything from the corruption point
    on into ``path + ".quarantine"`` and truncates the live log to its
    valid prefix — the shard then serves the *prefix* state, never
    garbage, and the supervisor rolls the affected workers back to the
    surviving frontier (DESIGN.md §17.3).
    """
    replayed = 0
    quarantined = 0
    if not os.path.exists(path):
        return 0, 0
    valid_end = 0
    corrupt = False
    try:
        for header, payload, end in WriteAheadLog.iter_records_with_end(path):
            dispatch(header, payload)
            replayed += 1
            valid_end = end
    except WALCorruption:
        corrupt = True
    size = os.path.getsize(path)
    if valid_end < size:
        if corrupt:
            with open(path, "rb") as f:
                f.seek(valid_end)
                bad = f.read()
            with open(path + ".quarantine", "ab") as q:
                q.write(bad)
                q.flush()
            quarantined = len(bad)
            print(f"WAL {path}: quarantined {quarantined} corrupt bytes "
                  f"after record {replayed} (offset {valid_end})",
                  flush=True)
        # drop the bad/torn suffix BEFORE appending: a later record
        # after garbage bytes would be unreachable to the next replay,
        # silently voiding its 'acked => logged' guarantee
        with open(path, "r+b") as f:
            f.truncate(valid_end)
    return replayed, quarantined


class BrokerCore:
    """All shard state + request handling, guarded by one lock/condition.

    One core holds ONE job's store/barrier/telemetry state.  Under the
    multi-job control plane (DESIGN.md §14) the shard process hosts one
    core per admitted job and routes requests by their ``job`` header;
    ``job_tag`` is that routing id — it is stamped onto every WAL record
    this core writes (so a shared per-shard log replays back into the
    right core) and is ``None`` for a solo job, whose records stay
    byte-identical to the single-job build's.
    """

    def __init__(self, job: dict, shard_id: int = 0, n_shards: int = 1,
                 job_tag: Optional[str] = None):
        self.job = dict(job)
        self.job_tag = job_tag
        self.shard_id = int(shard_id)
        self.n_shards = int(n_shards)
        self.P = int(job["n_workers"])
        self.n_batches = int(job.get("n_batches", 1))
        self.total_steps = int(job["total_steps"])
        # consistency model for the pull barrier: 'isp' (default) is the
        # full per-step barrier; 'ssp' is bounded staleness — a pull at
        # step t blocks only until every update from steps <= t - slack - 1
        # is stored (DESIGN.md §13)
        self.consistency = str(job.get("consistency", "isp"))
        self.slack = int(job.get("slack", 3))
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # step -> worker -> (meta, payload, digest)
        self.updates: dict[int, dict[int, tuple[list, bytes, str]]] = {}
        # step -> worker -> (meta, payload, digest)   (eviction flushes)
        self.flushes: dict[int, dict[int, tuple[list, bytes, str]]] = {}
        # (step, worker) -> telemetry dict   (coordinator only)
        self.telemetry: dict[tuple[int, int], dict] = {}
        self.evictions: dict[int, int] = {}  # worker -> effective step
        # per-worker publish clocks: highest step each worker has stored
        # here.  Publishes from one worker are sequential over its
        # persistent connection (and WAL replay preserves that order), so
        # the max is also the contiguous durable frontier — the quantity
        # the SSP release rule is stated in.
        self.clocks: dict[int, int] = {}
        self.statuses: dict[int, str] = {w: "spawned" for w in range(self.P)}
        self.max_published = 0
        self.dup_mismatches = 0
        self.update_bytes = 0  # codec-accounted published update bytes
        # live-reshard state (DESIGN.md §16): a pending epoch fence (every
        # worker exits at loop-top t >= fence), the committed topology
        # generation, and the set of (gen, src) migrations already merged
        # (idempotency under supervisor retries / WAL replay)
        self.topo_fence: Optional[int] = None
        self.topo_gen = int(job.get("topo_gen", 0))
        self.migrations_applied: set[tuple[int, int]] = set()
        self._poll_cursor = 1  # next telemetry step the supervisor hasn't seen
        self.wal_quarantined_bytes = 0  # corrupt WAL suffix dropped at attach
        self.stats: dict[str, dict[str, int]] = {}
        self.shutting_down = False
        self.shutdown_event = threading.Event()
        self._wal: Optional[WriteAheadLog] = None
        self._replaying = False

    @property
    def is_coordinator(self) -> bool:
        return self.shard_id == 0

    # -- write-ahead log ------------------------------------------------------

    def attach_wal(self, path: str, replay: bool = True) -> int:
        """Replay an existing WAL (respawn path), then append to it.
        Returns the number of records replayed.

        Per-message socket ``stats`` are NOT reconstructed (the WAL holds
        requests, not responses) — they restart per process; the codec
        meter ``update_bytes`` IS rebuilt exactly, and is the number the
        per-shard accounting invariant is stated in.
        """
        replayed = 0
        if replay and os.path.exists(path):
            self._replaying = True
            try:
                replayed, self.wal_quarantined_bytes = replay_wal(
                    path, self.handle)
            finally:
                self._replaying = False
        self._wal = WriteAheadLog(path)
        return replayed

    def _log(self, header: dict, payload: bytes = b"") -> None:
        if self._wal is not None and not self._replaying:
            if self.job_tag is not None and "job" not in header:
                # coordinator-minted records (evict_apply grants,
                # dup_mismatch markers) have no worker-supplied job
                # header; stamp the core's tag so a shared fleet WAL
                # replays them back into this core
                header = {**header, "job": self.job_tag}
            self._wal.append(header, payload)

    # -- membership -----------------------------------------------------------

    def active_at(self, step: int) -> list[int]:
        return [
            w
            for w in range(self.P)
            if w not in self.evictions or step < self.evictions[w]
        ]

    def _barrier_ready(self, step: int) -> bool:
        pubs = self.updates.get(step, {})
        if any(w not in pubs for w in self.active_at(step)):
            return False
        fl = self.flushes.get(step, {})
        return all(
            q in fl for q, e in self.evictions.items() if e == step
        )

    def _ssp_ready(self, d: int) -> bool:
        """Staleness-bounded release: every update from steps <= d is
        stored here.  Evicted workers stop publishing at e - 1 and hand
        off via a flush at e, so their obligation is capped there."""
        if d < 1:
            return True
        for w in range(self.P):
            e = self.evictions.get(w)
            lim = d if e is None else min(d, e - 1)
            if self.clocks.get(w, 0) < lim:
                return False
            if e is not None and e <= d and w not in self.flushes.get(e, {}):
                return False
        return True

    def _parts_at(self, step: int, worker: int) -> list:
        """The deliverable parts of one step: peers' update slices (in
        ascending worker order — the fixed float-summation order every
        replica relies on) plus any eviction flush effective at it."""
        parts = []
        for w in sorted(self.active_at(step)):
            if w == worker:
                continue
            meta, blob, _ = self.updates[step][w]
            parts.append(({"worker": w, "meta": meta}, blob))
        for q in sorted(self.flushes.get(step, {})):
            if self.evictions.get(q) == step:
                meta, blob, _ = self.flushes[step][q]
                parts.append(
                    ({"worker": q, "meta": meta, "flush": True}, blob)
                )
        return parts

    def _telemetry_complete(self, step: int) -> bool:
        return all(
            (step, w) in self.telemetry
            and "dur_s" in self.telemetry[(step, w)]
            for w in self.active_at(step)
        )

    # -- request dispatch -----------------------------------------------------

    def handle(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        kind = header.get("t", "?")
        fn = getattr(self, f"_op_{kind}", None)
        if fn is None:
            return {"ok": False, "error": f"unknown message type {kind!r}"}, b""
        if kind in _MUTATING:
            # log-then-apply: an acked mutation is always in the WAL, so a
            # respawned shard replays exactly what the workers believe
            # happened; an unacked one is retried by the idempotent RPC
            self._log(header, payload)
        return fn(header, payload)

    def _membership(self) -> dict:
        out = {"evictions": {str(k): v for k, v in self.evictions.items()}}
        if self.topo_fence is not None:
            # piggybacked like evictions: every pull/publish response
            # carries the fence once minted, and the pull that releases a
            # worker into step fence-1's successor is necessarily sent
            # after the mint (the mint guarantees barrier(fence-1) was
            # incomplete), so no worker can publish past the fence.  The
            # key is absent when unset — default-path response bytes are
            # untouched.
            out["topo_fence"] = self.topo_fence
        return out

    def _op_hello(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            w = int(h["worker"])
            if not h.get("warm"):
                # a warm hello (pre-warmed respawn) only fetches the job
                # config — the PREVIOUS invocation still owns the slot's
                # status until it says bye, or the reaper would
                # misclassify its clean exit as a crash
                self.statuses[w] = "running"
            resp = {
                "ok": True,
                "job": self.job,
                "shard_id": self.shard_id,
                "n_shards": self.n_shards,
                **self._membership(),
            }
        return resp, b""

    def batch_key(self, step: int, worker: int) -> int:
        """Deterministic round-robin minibatch key for (step, worker)."""
        return ((step - 1) * self.P + worker) % self.n_batches

    def _op_batch(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        key = self.batch_key(step, worker)
        with self._lock:
            return {"ok": True, "key": key, **self._membership()}, b""

    def _op_publish(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        meta = h["meta"]
        digest = hashlib.sha1(
            json.dumps(meta, sort_keys=True).encode() + payload
        ).hexdigest()
        with self._cond:
            slot = self.updates.setdefault(step, {})
            dup = worker in slot
            if dup:
                # bit-identical dups (worker replay) are NOT re-logged:
                # the original record already persists, and re-appending
                # full payloads would bloat every future WAL replay
                if slot[worker][2] != digest:
                    self.dup_mismatches += 1
                    # the determinism tripwire must survive a shard
                    # respawn — persist a payload-free marker
                    self._log({"t": "dup_mismatch", "worker": worker,
                               "step": step, "kind": "publish"})
            else:
                # log while holding the lock, before the update becomes
                # pullable: no peer can apply an unlogged update
                self._log(h, payload)
                slot[worker] = (meta, payload, digest)
                self.max_published = max(self.max_published, step)
                self.clocks[worker] = max(self.clocks.get(worker, 0), step)
                self.update_bytes += protocol.wire_bytes(meta)
            if self.is_coordinator:
                # telemetry is a coordinator concern; the worker reports
                # its cross-shard wire_bytes total on this one publish
                self.telemetry.setdefault((step, worker), {}).update(
                    {
                        "loss": h.get("loss"),
                        "sent_fraction": h.get("sent_fraction"),
                        "inv_err": h.get("inv_err"),
                        "wire_bytes": (
                            h["wire_bytes"] if "wire_bytes" in h
                            else protocol.wire_bytes(meta)
                        ),
                    }
                )
            self._cond.notify_all()
            return {"ok": True, "dup": dup, **self._membership()}, b""

    def _op_flush(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        digest = hashlib.sha1(
            json.dumps(h["meta"], sort_keys=True).encode() + payload
        ).hexdigest()
        with self._cond:
            slot = self.flushes.setdefault(step, {})
            dup = worker in slot
            if dup:
                # a replayed flush must be bit-identical too — survivors may
                # already have applied the first copy
                if slot[worker][2] != digest:
                    self.dup_mismatches += 1
                    self._log({"t": "dup_mismatch", "worker": worker,
                               "step": step, "kind": "flush"})
            else:
                self._log(h, payload)  # as for publish: log-before-visible
                slot[worker] = (h["meta"], payload, digest)
            self._cond.notify_all()
        return {"ok": True, "dup": dup}, b""

    def _op_pull(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        timeout = float(h.get("timeout_s", 2.0))
        if self.consistency == "ssp":
            return self._pull_ssp(step, worker, timeout)
        with self._cond:
            ready = self._cond.wait_for(
                lambda: self._barrier_ready(step) or self.shutting_down,
                timeout=timeout,
            )
            if self.shutting_down:
                return {"ok": False, "abort": True}, b""
            if not ready or not self._barrier_ready(step):
                return {"ok": True, "ready": False, **self._membership()}, b""
            descs, payload = protocol.pack_parts(
                self._parts_at(step, worker)
            )
            resp = {
                "ok": True,
                "ready": True,
                "parts": descs,
                **self._membership(),
            }
            if self.is_coordinator:
                # coalesced pull: piggyback the NEXT step's minibatch key so
                # the steady-state worker loop is exactly 1 + n_shards round
                # trips per ISP barrier (one publish + one pull per shard)
                resp["key_next"] = self.batch_key(step + 1, worker)
        return resp, payload

    def _pull_ssp(self, step: int, worker: int,
                  timeout: float) -> tuple[dict, bytes]:
        """Bounded-staleness pull: a pull at step t is served exactly the
        updates of the frontier step d = t - slack - 1 (empty, and ready
        immediately, while d < 1), blocking only until every update from
        steps <= d is stored.  The delivery schedule is a pure function
        of t, so a respawned worker's replayed pulls return the identical
        retained parts — replay stays deterministic (DESIGN.md §13)."""
        d = step - self.slack - 1
        with self._cond:
            ready = self._cond.wait_for(
                lambda: self._ssp_ready(d) or self.shutting_down,
                timeout=timeout,
            )
            if self.shutting_down:
                return {"ok": False, "abort": True}, b""
            if not ready or not self._ssp_ready(d):
                return {"ok": True, "ready": False, **self._membership()}, b""
            parts = self._parts_at(d, worker) if d >= 1 else []
            descs, payload = protocol.pack_parts(parts)
            resp = {
                "ok": True,
                "ready": True,
                "parts": descs,
                "visible_step": d,
                **self._membership(),
            }
            if self.is_coordinator:
                resp["key_next"] = self.batch_key(step + 1, worker)
        return resp, payload

    def _op_report(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        step, worker = int(h["step"]), int(h["worker"])
        with self._lock:
            cell = self.telemetry.setdefault((step, worker), {})
            cell["dur_s"] = float(h["dur_s"])
            if "phase" in h:  # per-phase data-path breakdown (DESIGN.md §10)
                cell["phase"] = {
                    k: float(v) for k, v in h["phase"].items()
                }
        return {"ok": True}, b""

    def _op_bye(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            self.statuses[int(h["worker"])] = f"bye:{h.get('reason', '?')}"
        return {"ok": True}, b""

    def _op_evict(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        if not self.is_coordinator:
            # membership decisions are minted in exactly one place; other
            # shards receive the result via evict_apply
            return {"ok": False, "error": "evict: not the coordinator"}, b""
        worker = int(h["worker"])
        with self._cond:
            if worker in self.evictions:
                return {
                    "ok": True, "granted": True,
                    "evict_step": self.evictions[worker],
                }, b""
            # effective at a step no worker can have begun with the old
            # pool; distinct from every prior eviction's step — with ONE
            # leaver per step the survivors' sequential mean-preserving
            # pulls x += (flush - x)/P_old stay exact (two flushes at the
            # same step with the same divisor would drift the pool mean)
            step = max(
                self.max_published + 2,
                max(self.evictions.values(), default=0) + 1,
            )
            if step > self.total_steps:
                # the pool finishes before the eviction could take effect —
                # granting it would strand a flush no survivor ever pulls
                return {"ok": True, "granted": False,
                        "reason": "past-end"}, b""
            self.evictions[worker] = step
            # the WAL must replay the *result*, not re-derive it from a
            # different max_published — log the grant as an evict_apply
            self._log({"t": "evict_apply", "worker": worker, "step": step})
            self._cond.notify_all()
        return {"ok": True, "granted": True, "evict_step": step}, b""

    def _op_dup_mismatch(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """WAL-replay path only: restore a previously-detected replay
        divergence (the marker is logged at detection time; this op is
        not in _MUTATING so replay does not re-log it)."""
        with self._lock:
            self.dup_mismatches += 1
        return {"ok": True}, b""

    def _op_evict_apply(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Install a coordinator-granted eviction (worker, effective step)
        on this shard — the supervisor's cross-shard membership sync."""
        worker, step = int(h["worker"]), int(h["step"])
        with self._cond:
            prev = self.evictions.get(worker)
            if prev is not None and prev != step:
                return {
                    "ok": False,
                    "error": f"evict_apply conflict: worker {worker} already "
                    f"evicted at {prev}, got {step}",
                }, b""
            self.evictions[worker] = step
            self._cond.notify_all()
        return {"ok": True, "evict_step": step}, b""

    # -- live re-sharding (DESIGN.md §16) -------------------------------------

    @staticmethod
    def _entry_slices(meta: list, blob: bytes):
        """Yield ``(m, byte_segment)`` per leaf meta of one stored entry —
        the per-entry offset walk migrate read/in/drop all share."""
        off = 0
        for m in meta:
            nb = int(m["nbytes"])
            yield m, blob[off:off + nb]
            off += nb

    def _op_topo_begin(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Mint the epoch fence for a topology handover (coordinator only;
        idempotent).  The fence f satisfies: (a) no worker has published
        step >= f-1, so barrier(f-1) is incomplete at mint time and every
        pull response releasing a worker into step f carries the fence via
        _membership(); (b) f exceeds every granted eviction step, so an
        eviction flush always lands in a barrier <= f-1.  Logged as its
        RESULT (like evict): re-minting at replay could diverge."""
        with self._cond:
            if "fence" in h:  # WAL replay: install the minted fence
                self.topo_fence = int(h["fence"])
                self._cond.notify_all()
                return {"ok": True, "granted": True,
                        "fence": self.topo_fence}, b""
            if not self.is_coordinator:
                return {"ok": False,
                        "error": "topo_begin: not the coordinator"}, b""
            if self.topo_fence is not None:
                return {"ok": True, "granted": True,
                        "fence": self.topo_fence}, b""
            fence = max(
                self.max_published + 2,
                max(self.evictions.values(), default=0) + 1,
            )
            if fence > self.total_steps:
                # the job finishes before the fence could take effect —
                # same refusal as a past-end eviction
                return {"ok": True, "granted": False,
                        "reason": "past-end"}, b""
            self.topo_fence = fence
            self._log({"t": "topo_begin", "fence": fence})
            self._cond.notify_all()
        return {"ok": True, "granted": True, "fence": fence}, b""

    def _op_topo_commit(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Install the new topology after migration: update the job dict
        (respawned workers hello into the new assignment), bump the
        generation, clear the fence.  Parameter-complete header, so the
        generic WAL log-then-apply replays it exactly."""
        with self._cond:
            for k in ("n_brokers", "transport", "wire_scheme",
                      "shard_split_bytes", "partitioner"):
                if k in h:
                    self.job[k] = h[k]
            self.topo_gen = int(h["gen"])
            self.job["topo_gen"] = self.topo_gen
            self.n_shards = int(h["n_shards"])
            self.topo_fence = None
            self._cond.notify_all()
        return {"ok": True, "gen": self.topo_gen}, b""

    def _op_migrate_read(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Read every stored slice of the moved identities ``[(k, o), ...]``
        out of this shard (updates AND eviction flushes), packed as
        (kind, step, worker, meta) parts.  Read-only — not logged; the
        durable hand-off is the destination's migrate_in record."""
        moved = {(str(k), int(o)) for k, o in h["moved"]}
        with self._lock:
            parts = []
            for kind, store in (("update", self.updates),
                                ("flush", self.flushes)):
                for step in sorted(store):
                    for w in sorted(store[step]):
                        meta, blob, _ = store[step][w]
                        sel, segs = [], []
                        for m, seg in self._entry_slices(meta, blob):
                            if (m["k"], int(m.get("o", 0))) in moved:
                                sel.append(m)
                                segs.append(seg)
                        if sel:
                            parts.append((
                                {"kind": kind, "step": step, "worker": w,
                                 "meta": sel},
                                b"".join(segs),
                            ))
            descs, payload = protocol.pack_parts(parts)
            resp = {
                "ok": True,
                "parts": descs,
                "clocks": {str(k): v for k, v in self.clocks.items()},
                "max_published": self.max_published,
            }
        return resp, payload

    def _op_migrate_in(self, h: dict, payload: bytes) -> tuple[dict, bytes]:
        """Merge migrated slices into this shard's store.  Idempotent per
        (gen, src) — a supervisor retry after a SIGKILL mid-apply replays
        over the WAL-rebuilt ``migrations_applied`` marker.  Merged metas
        are kept sorted by (k, o); safe because migrated identities were
        owned by the source under the OLD assignment and are disjoint
        from anything this shard already stored, and post-fence pulls
        never read pre-fence steps (only dump reassembly does, and it is
        order-insensitive per (worker, step))."""
        from repro.wire.framing import unpack_parts

        key = (int(h["gen"]), int(h["src"]))
        with self._cond:
            if key in self.migrations_applied:
                return {"ok": True, "already": True}, b""
            for desc, part in unpack_parts(h["parts"], payload):
                kind = desc["kind"]
                store = self.updates if kind == "update" else self.flushes
                step, w = int(desc["step"]), int(desc["worker"])
                slot = store.setdefault(step, {})
                pairs = list(self._entry_slices(desc["meta"], bytes(part)))
                if w in slot:
                    old_meta, old_blob, _ = slot[w]
                    pairs.extend(self._entry_slices(old_meta, old_blob))
                pairs.sort(
                    key=lambda p: (p[0]["k"], int(p[0].get("o", 0)))
                )
                metas = [m for m, _ in pairs]
                blob = b"".join(seg for _, seg in pairs)
                digest = hashlib.sha1(
                    json.dumps(metas, sort_keys=True).encode() + blob
                ).hexdigest()
                slot[w] = (metas, blob, digest)
                if kind == "update":
                    self.max_published = max(self.max_published, step)
                    self.clocks[w] = max(self.clocks.get(w, 0), step)
                    self.update_bytes += protocol.wire_bytes(desc["meta"])
            self.migrations_applied.add(key)
            self._cond.notify_all()
        return {"ok": True, "already": False}, b""

    def _op_migrate_drop(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Drop the moved identities from this shard after every
        destination acked its migrate_in.  Naturally idempotent (dropping
        absent identities is a no-op); header-only, generically logged."""
        moved = {(str(k), int(o)) for k, o in h["moved"]}
        with self._cond:
            for kind, store in (("update", self.updates),
                                ("flush", self.flushes)):
                for step in list(store):
                    for w in list(store[step]):
                        meta, blob, _ = store[step][w]
                        keep, segs, dropped = [], [], []
                        for m, seg in self._entry_slices(meta, blob):
                            if (m["k"], int(m.get("o", 0))) in moved:
                                dropped.append(m)
                            else:
                                keep.append(m)
                                segs.append(seg)
                        if not dropped:
                            continue
                        if kind == "update":
                            self.update_bytes -= protocol.wire_bytes(dropped)
                        if keep:
                            kept_blob = b"".join(segs)
                            digest = hashlib.sha1(
                                json.dumps(keep, sort_keys=True).encode()
                                + kept_blob
                            ).hexdigest()
                            store[step][w] = (keep, kept_blob, digest)
                        else:
                            del store[step][w]
                            if not store[step]:
                                del store[step]
            self._cond.notify_all()
        return {"ok": True}, b""

    def _op_poll(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        # with a client-supplied cursor ('since') the poll is IDEMPOTENT —
        # a lost response replayed over a reconnecting wire.Connection
        # returns the same rows instead of dropping them; the server-side
        # cursor only backs cursor-less (legacy/debug) callers
        stateless = "since" in h
        with self._lock:
            rows = []
            step = int(h["since"]) if stateless else self._poll_cursor
            while step <= self.total_steps and self._telemetry_complete(step):
                active = self.active_at(step)
                cells = [self.telemetry[(step, w)] for w in active]
                row = {
                    "step": step,
                    "loss": _mean([c["loss"] for c in cells]),
                    "dur_s": _mean([c["dur_s"] for c in cells]),
                    "sent_fraction": _mean(
                        [c["sent_fraction"] for c in cells]
                    ),
                    "inv_err": max(
                        float(c["inv_err"] or 0.0) for c in cells
                    ),
                    "wire_bytes": float(
                        sum(c["wire_bytes"] for c in cells)
                    ),
                    "p_active": len(active),
                    # per-worker durations so a straggler's stalls are
                    # attributable (fig9 --live scores the NON-straggler
                    # p95 under each consistency model)
                    "dur_s_by_worker": {
                        str(w): float(self.telemetry[(step, w)]["dur_s"])
                        for w in active
                    },
                }
                phases = [c["phase"] for c in cells if "phase" in c]
                if phases:
                    row["phase"] = {
                        k: _mean([p.get(k) for p in phases])
                        for k in phases[0]
                    }
                rows.append(row)
                step += 1
            if not stateless:
                self._poll_cursor = step
            resp = {
                "ok": True,
                "rows": rows,
                "statuses": {str(k): v for k, v in self.statuses.items()},
                "max_published": self.max_published,
                "clocks": {str(k): v for k, v in self.clocks.items()},
                "dup_mismatches": self.dup_mismatches,
                **self._membership(),
            }
            if self.wal_quarantined_bytes:
                # key absent on the default path — response bytes stay
                # baseline-identical with no corruption ever seen
                resp["wal_quarantined"] = self.wal_quarantined_bytes
        return resp, b""

    def _op_dump(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        """Test/debug hook: every stored update slice as one multi-part
        payload (this shard's leaves only; the supervisor merges shards)."""
        with self._lock:
            parts = []
            for step in sorted(self.updates):
                for w in sorted(self.updates[step]):
                    meta, blob, _ = self.updates[step][w]
                    parts.append(
                        ({"worker": w, "step": step, "meta": meta}, blob)
                    )
            descs, payload = protocol.pack_parts(parts)
        return {"ok": True, "parts": descs}, payload

    def _op_stats(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._lock:
            resp = {
                "ok": True,
                "shard_id": self.shard_id,
                "stats": self.stats,
                "update_bytes": self.update_bytes,
                "dup_mismatches": self.dup_mismatches,
            }
            if self.wal_quarantined_bytes:
                resp["wal_quarantined"] = self.wal_quarantined_bytes
            return resp, b""

    def _op_shutdown(self, h: dict, _p: bytes) -> tuple[dict, bytes]:
        with self._cond:
            self.shutting_down = True
            self._cond.notify_all()
            resp = {
                "ok": True,
                "shard_id": self.shard_id,
                "stats": self.stats,
                "update_bytes": self.update_bytes,
                "dup_mismatches": self.dup_mismatches,
            }
        # shutdown_event is set by the HANDLER after this response is on
        # the wire — setting it here would let the standalone process exit
        # before the requester ever reads its stats
        return resp, b""

    # -- accounting -----------------------------------------------------------

    def account(self, kind: str, bytes_in: int, bytes_out: int) -> None:
        with self._lock:
            row = self.stats.setdefault(
                kind, {"count": 0, "bytes_in": 0, "bytes_out": 0}
            )
            row["count"] += 1
            row["bytes_in"] += bytes_in
            row["bytes_out"] += bytes_out


def _mean(xs) -> Optional[float]:
    vals = [float(x) for x in xs if x is not None]
    return sum(vals) / len(vals) if vals else None


# -- transport-generic serve loop ---------------------------------------------


def _account_request(core: BrokerCore, header: dict, payload: bytes,
                     bytes_out: int) -> None:
    """Identical byte accounting on every transport: the framed request
    size a TCP socket would have carried (8-byte length prefix + header
    JSON + payload) — transport-private overhead (shm rids/trailers, IP
    headers) is never counted."""
    hdr_len = len(json.dumps(header, separators=(",", ":")))
    core.account(header.get("t", "?"), 8 + hdr_len + len(payload), bytes_out)


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:  # one persistent connection, many requests
        broker: "Broker" = self.server.broker  # type: ignore[attr-defined]
        try:
            self.request.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while True:
                header, payload = protocol.recv_msg(self.request)
                if header.get("t") == "shm_serve":
                    # transport control plane, not shard state: the shell
                    # attaches a shared-memory segment and serves it from
                    # a dedicated thread (idempotent per segment)
                    resp = broker.shm_serve(header)
                    protocol.send_msg(self.request, resp)
                    continue
                core, resp, blob = broker.dispatch(header, payload)
                out = protocol.send_msg(self.request, resp, blob)
                _account_request(core, header, payload, out)
                if core.shutting_down and broker.all_shutting_down():
                    # signal process exit only AFTER the last job's
                    # (shutdown) response reached the wire — the
                    # requester must get its final stats back; with
                    # other jobs still live the connection stays up
                    for c in broker.cores.values():
                        c.shutdown_event.set()
                    break
        except (ConnectionError, ValueError, OSError):
            pass  # client vanished mid-stream; nothing to clean up


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class Broker:
    """Server shell around ``BrokerCore``; in-thread or standalone.

    Always binds a TCP port (the supervisor's control plane and the
    default worker data path); additionally serves any number of
    shared-memory segments handed to it via ``shm_serve`` requests —
    one daemon thread per segment running the same handler loop the TCP
    connections run (DESIGN.md §12.3).

    With ``wal_path`` the cores replay any existing log BEFORE the port is
    bound (a respawned shard never serves from partial state) and append
    every subsequent mutation to it.

    Multi-job (DESIGN.md §14): a config with a ``"jobs"`` key —
    ``{"jobs": {job_id: job_dict, ...}}`` — hosts one independent
    ``BrokerCore`` per job in this process, all sharing one TCP port,
    one WAL file, and the shm segments.  Requests route by their
    ``job`` header; a request without one goes to the sole core (so
    single-job traffic is byte-identical to the single-core build).
    ``self.core`` remains the sole/first core for solo-path callers.
    """

    def __init__(
        self,
        job: dict,
        host: str = "127.0.0.1",
        port: int = 0,
        shard_id: int = 0,
        n_shards: int = 1,
        wal_path: Optional[str] = None,
    ):
        jobs = job.get("jobs") if isinstance(job, dict) else None
        if jobs:
            self.cores: dict[Optional[str], BrokerCore] = {
                str(jid): BrokerCore(
                    jdict, shard_id=shard_id, n_shards=n_shards,
                    job_tag=str(jid),
                )
                for jid, jdict in jobs.items()
            }
        else:
            self.cores = {
                None: BrokerCore(job, shard_id=shard_id, n_shards=n_shards)
            }
        self.core = next(iter(self.cores.values()))
        self.replayed = 0
        if wal_path:
            self.replayed = self._attach_shared_wal(wal_path)
        self._server = _Server((host, port), _Handler)
        self._server.core = self.core  # type: ignore[attr-defined]
        self._server.broker = self  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None
        self._shm_threads: dict[str, threading.Thread] = {}
        self._shm_lock = threading.Lock()

    # -- multi-core routing ----------------------------------------------------

    def dispatch(
        self, header: dict, payload: bytes
    ) -> tuple[BrokerCore, dict, bytes]:
        """Route a request to its job's core by the ``job`` header and
        handle it there; returns the core too so the caller accounts the
        bytes on the right job's meter."""
        jid = header.get("job")
        core = self.cores.get(jid)
        if core is None and jid is None and len(self.cores) == 1:
            core = self.core
        if core is None:
            return self.core, {"ok": False, "error": f"unknown job {jid!r}"}, b""
        resp, blob = core.handle(header, payload)
        return core, resp, blob

    def all_shutting_down(self) -> bool:
        return all(c.shutting_down for c in self.cores.values())

    def _attach_shared_wal(self, path: str) -> int:
        """Replay one shared per-shard WAL into every core (records route
        by their ``job`` header), truncate any torn tail, then append all
        cores' subsequent mutations to the same (thread-safe) log.
        Identical to ``BrokerCore.attach_wal`` when there is one core."""
        replayed = 0
        if os.path.exists(path):
            for c in self.cores.values():
                c._replaying = True
            try:
                replayed, quarantined = replay_wal(
                    path, lambda h, p: self.dispatch(h, p))
            finally:
                for c in self.cores.values():
                    c._replaying = False
            for c in self.cores.values():
                c.wal_quarantined_bytes = quarantined
        wal = WriteAheadLog(path)
        for c in self.cores.values():
            c._wal = wal
        return replayed

    # -- shared-memory data path ----------------------------------------------

    def shm_serve(self, header: dict) -> dict:
        """Attach one ``wire.shm`` segment and serve it from a dedicated
        thread.  Idempotent: a retried request for a segment this process
        already serves is acked without a second (ring-resetting) attach —
        two servers on one ring would corrupt the stream."""
        name = str(header["seg"])
        with self._shm_lock:
            # dead threads (prior invocations' segments) would otherwise
            # accumulate one entry per invocation x shard for the job's
            # lifetime
            self._shm_threads = {
                n: th for n, th in self._shm_threads.items() if th.is_alive()
            }
            t = self._shm_threads.get(name)
            if t is not None:
                return {"ok": True, "seg": name, "already": True}
            t = threading.Thread(
                target=self._serve_shm_segment, args=(name,), daemon=True,
                name=f"shm-{name}",
            )
            self._shm_threads[name] = t
            t.start()
        return {"ok": True, "seg": name, "already": False}

    def _serve_shm_segment(self, name: str) -> None:
        from repro.wire import shm

        def stopping() -> bool:
            return self.all_shutting_down()

        while not self.all_shutting_down():
            try:
                chan = shm.ShmServerChannel(name, stop=stopping)
            except (ConnectionError, OSError, FileNotFoundError):
                return  # segment gone (worker slot torn down)
            try:
                while not self.all_shutting_down():
                    try:
                        rid, header, payload = chan.recv()
                    except shm.TornFrameError:
                        # desynced stream (e.g. a client abandoned a
                        # half-sent frame): heal by re-serving — the
                        # ring reset + generation bump make the client
                        # replay its request from a clean stream
                        break
                    core, resp, blob = self.dispatch(header, payload)
                    out = chan.send(rid, resp, blob)
                    _account_request(core, header, payload, out)
            except (ConnectionError, OSError, TimeoutError, ValueError):
                chan.close(mark_closed=self.all_shutting_down())
                return  # peer death or shutdown: this channel is done
            chan.close()  # torn-frame break: loop around and re-serve

    @property
    def addr(self) -> tuple[str, int]:
        return self._server.server_address[:2]

    def start(self) -> tuple[str, int]:
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05},
            daemon=True,
            name=f"broker-tcp-{self.core.shard_id}",
        )
        self._thread.start()
        return self.addr

    def stop(self, timeout: float = 5.0) -> list[str]:
        """Stop serving; returns the names of handler threads that failed
        to join within ``timeout`` (empty list = clean stop).  A wedged
        handler is also logged here — the one place the thread identity
        is still known."""
        for core in self.cores.values():
            with core._cond:
                core.shutting_down = True
                core._cond.notify_all()
            core.shutdown_event.set()
        self._server.shutdown()
        self._server.server_close()
        wedged: list[str] = []
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                wedged.append(self._thread.name)
        with self._shm_lock:
            shm_threads = list(self._shm_threads.values())
        for t in shm_threads:  # they exit within one wait slice (~50 ms)
            t.join(timeout=timeout)
            if t.is_alive():
                wedged.append(t.name)
        # cores share one WAL in fleet mode — close each distinct log once
        closed: set[int] = set()
        for core in self.cores.values():
            if core._wal is not None and id(core._wal) not in closed:
                closed.add(id(core._wal))
                core._wal.close()
        if wedged:
            print(
                f"broker shard {self.core.shard_id}: handler threads "
                f"failed to join within {timeout}s: {wedged}", flush=True,
            )
        return wedged


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", required=True, help="job config JSON file")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--shard-id", type=int, default=0)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--wal", default=None,
                    help="write-ahead log path (replayed on respawn)")
    ap.add_argument("--port-file", default=None,
                    help="write HOST:PORT here once listening (atomic) — "
                    "the supervisor's readiness signal")
    args = ap.parse_args()
    with open(args.config) as f:
        job = json.load(f)
    broker = Broker(
        job,
        port=args.port,
        shard_id=args.shard_id,
        n_shards=args.n_shards,
        wal_path=args.wal,
    )
    host, port = broker.start()
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{host}:{port}")
        os.replace(tmp, args.port_file)
    print(
        f"broker shard {args.shard_id}/{args.n_shards} listening on "
        f"{host}:{port} (replayed {broker.replayed} WAL records)",
        flush=True,
    )
    try:
        # fleet configs host several cores; the process exits only once
        # every job's core has been shut down
        for core in broker.cores.values():
            core.shutdown_event.wait()
    except KeyboardInterrupt:
        pass
    broker.stop()


if __name__ == "__main__":
    main()
