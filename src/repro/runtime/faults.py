"""Deterministic seeded fault-injection plane (DESIGN.md §17).

MLLess's cost argument rests on stateless functions recovering cheaply
from the failures serverless makes routine.  PRs 2/4/5/9 proved
bit-identical replay under *hand-placed* SIGKILLs; this module replaces
those one-off knobs (``kill_worker_at_step`` / ``kill_broker_at_step`` /
``straggler``) with one composable mechanism: a ``FaultPlan`` — a seeded
schedule of ``FaultEvent``s — threaded as injection hooks through every
runtime seam:

=================  ==========================================================
kind               seam
=================  ==========================================================
worker_kill        supervisor run loop → SIGKILL the worker process at step N
broker_kill        supervisor run loop → SIGKILL a broker shard at step N
supervisor_kill    supervisor run loop → SIGKILL *itself* (journal replays)
wal_corrupt        supervisor: SIGKILL the shard, flip one seeded byte in
                   its WAL tail, let CRC quarantine + rollback recover
transport_delay    wire client hook: sleep before a send (slow frame)
transport_stall    wire client hook: sleep before a recv (wedged peer)
transport_reset    wire client hook: raise ConnectionError once (the
                   transports' reconnect-and-replay path recovers)
ckpt_enospc        checkpoint store write hook: fail the npz write once
                   (simulated ENOSPC; atomic staging keeps it invisible)
compute_delay      worker step loop: sleep after compute (straggler)
=================  ==========================================================

Everything is deterministic at a fixed seed: ``FaultPlan.randomized``
expands a seed into explicit events once, supervisor-side, and ships
them to workers through ``job_dict`` — a respawned worker or a resumed
supervisor derives the identical plan.  With no plan installed every
hook is a single ``None`` check: the default path stays byte-identical
(``wire_guard``'s chaos-dormancy leg asserts this).

``RetryPolicy`` is the other half of the hardening: one jittered
exponential-backoff-plus-deadline policy replacing the scattered
``timeout=30.0`` / ``tries=8`` literals in the worker/supervisor RPC
paths, configurable via ``FaaSJobConfig.rpc``.
"""

from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import time
from dataclasses import dataclass, field, replace
from typing import Iterator, Optional

from repro.wire import framing

# fault kinds executed by the supervisor's run loop
SUPERVISOR_KINDS = ("worker_kill", "broker_kill", "supervisor_kill",
                    "wal_corrupt")
# fault kinds executed inside a worker process (wire / checkpoint / step
# hooks)
WORKER_KINDS = ("transport_delay", "transport_stall", "transport_reset",
                "ckpt_enospc", "compute_delay")
KINDS = SUPERVISOR_KINDS + WORKER_KINDS


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``step`` is the global training step the event arms at (supervisor
    kinds fire when the pool's max published step reaches it; worker
    kinds fire at the start of local step ``step``).  ``worker`` /
    ``shard`` select the victim where the kind needs one.  ``delay_s``
    parameterises the sleep kinds; ``every`` repeats a compute_delay
    every N steps from ``step`` on (1 = every step); ``op`` optionally
    restricts a transport fault to one RPC op name.
    """

    kind: str
    step: int
    worker: Optional[int] = None
    shard: Optional[int] = None
    delay_s: float = 0.0
    every: int = 0  # 0 = fire once; N>0 = repeat every N steps (compute_delay)
    op: Optional[str] = None

    def validate(self) -> "FaultEvent":
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0: {self}")
        if self.kind in ("worker_kill", "transport_delay",
                         "transport_stall", "transport_reset",
                         "ckpt_enospc", "compute_delay") \
                and self.worker is None:
            raise ValueError(f"{self.kind} needs worker=: {self}")
        if self.kind in ("broker_kill", "wal_corrupt") and self.shard is None:
            raise ValueError(f"{self.kind} needs shard=: {self}")
        if self.kind in ("transport_delay", "transport_stall",
                         "compute_delay") and self.delay_s <= 0:
            raise ValueError(f"{self.kind} needs delay_s > 0: {self}")
        return self

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "step": self.step}
        if self.worker is not None:
            d["worker"] = self.worker
        if self.shard is not None:
            d["shard"] = self.shard
        if self.delay_s:
            d["delay_s"] = self.delay_s
        if self.every:
            d["every"] = self.every
        if self.op is not None:
            d["op"] = self.op
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(
            kind=str(d["kind"]), step=int(d["step"]),
            worker=None if d.get("worker") is None else int(d["worker"]),
            shard=None if d.get("shard") is None else int(d["shard"]),
            delay_s=float(d.get("delay_s", 0.0)),
            every=int(d.get("every", 0)),
            op=d.get("op"),
        ).validate()


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, fully-explicit schedule of fault events.

    The plan that reaches workers and a resumed supervisor is always the
    *expanded* form — randomization happens exactly once, in
    ``randomized``, so every process derives identical behaviour.
    """

    seed: int = 0
    events: tuple = ()

    def validate(self) -> "FaultPlan":
        for e in self.events:
            e.validate()
        return self

    def to_spec(self) -> dict:
        return {"seed": self.seed,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_spec(cls, spec: Optional[dict]) -> Optional["FaultPlan"]:
        if spec is None:
            return None
        events = tuple(FaultEvent.from_dict(d)
                       for d in spec.get("events", ()))
        return cls(seed=int(spec.get("seed", 0)), events=events).validate()

    # -- selectors ------------------------------------------------------------

    def supervisor_events(self) -> list:
        return [e for e in self.events if e.kind in SUPERVISOR_KINDS]

    def worker_events(self, worker_id: int) -> list:
        return [e for e in self.events
                if e.kind in WORKER_KINDS and e.worker == worker_id]

    def counts(self) -> dict:
        out: dict = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    # -- seeded expansion -----------------------------------------------------

    @classmethod
    def randomized(
        cls,
        seed: int,
        n_workers: int,
        n_shards: int,
        total_steps: int,
        kinds: tuple = ("worker_kill", "broker_kill", "wal_corrupt",
                        "transport_stall", "supervisor_kill"),
    ) -> "FaultPlan":
        """Expand a seed into an explicit multi-fault schedule with at
        least one event of every requested kind.

        Event steps land in ``[3, total_steps - 6]`` so every fault has
        steps left in which to recover (a WAL corruption injected while
        a worker is already terminal could never be replayed), and the
        victims/steps/offsets all come from one ``random.Random(seed)``
        stream — the schedule is a pure function of its arguments.
        """
        if total_steps < 12:
            raise ValueError(
                f"randomized fault plans need total_steps >= 12 "
                f"(got {total_steps}) so every fault can recover")
        rng = random.Random(seed)
        lo, hi = 3, total_steps - 6
        events = []
        for kind in kinds:
            step = rng.randrange(lo, hi + 1)
            if kind in ("worker_kill", "ckpt_enospc"):
                events.append(FaultEvent(kind, step,
                                         worker=rng.randrange(n_workers)))
            elif kind in ("broker_kill", "wal_corrupt"):
                events.append(FaultEvent(kind, step,
                                         shard=rng.randrange(n_shards)))
            elif kind in ("transport_delay", "transport_stall"):
                events.append(FaultEvent(
                    kind, step, worker=rng.randrange(n_workers),
                    delay_s=round(0.2 + 0.8 * rng.random(), 3)))
            elif kind == "transport_reset":
                events.append(FaultEvent(kind, step,
                                         worker=rng.randrange(n_workers)))
            elif kind == "compute_delay":
                events.append(FaultEvent(
                    kind, step, worker=rng.randrange(n_workers),
                    delay_s=round(0.1 + 0.4 * rng.random(), 3), every=2))
            elif kind == "supervisor_kill":
                events.append(FaultEvent(kind, step))
            else:
                raise ValueError(f"unknown fault kind {kind!r}")
        events.sort(key=lambda e: (e.step, e.kind))
        return cls(seed=seed, events=tuple(events)).validate()


def parse_chaos_arg(arg: str, n_workers: int, n_shards: int,
                    total_steps: int) -> FaultPlan:
    """Parse the train driver's ``--chaos SEED:JSON`` flag.

    ``SEED:auto`` expands the seed into the default randomized multi-
    fault schedule; ``SEED:[{...}, ...]`` is an explicit event list.
    Malformed input raises SystemExit (mirrors ``--retune`` parsing).
    """
    try:
        seed_s, _, rest = arg.partition(":")
        seed = int(seed_s)
        if not rest:
            raise ValueError("missing event spec after ':'")
        if rest == "auto":
            return FaultPlan.randomized(seed, n_workers, n_shards,
                                        total_steps)
        events = tuple(FaultEvent.from_dict(d) for d in json.loads(rest))
        return FaultPlan(seed=seed, events=events).validate()
    except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
        raise SystemExit(
            f"--chaos: malformed spec {arg!r} "
            f"(want SEED:auto or SEED:[{{\"kind\":...,\"step\":...}}]): {e}")


# -- unified RPC retry policy -------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff + deadline for idempotent RPCs.

    Replaces the scattered ``timeout=30.0`` / ``tries=8`` /
    ``sleep(0.25 * 2**i)`` literals: ``timeout_s`` bounds one attempt,
    ``tries`` bounds the attempt count, ``deadline_s`` bounds the whole
    loop, and ``backoff(i)`` is deterministic at a fixed seed (full
    jitter in ``[0.5, 1.0] * min(cap, base * 2**i)``) so runs replay
    bit-identically while a thundering herd still decorrelates.
    """

    timeout_s: float = 30.0
    tries: int = 8
    backoff_s: float = 0.25
    backoff_cap_s: float = 2.0
    deadline_s: float = 120.0
    seed: int = 0

    def to_dict(self) -> dict:
        return {"timeout_s": self.timeout_s, "tries": self.tries,
                "backoff_s": self.backoff_s,
                "backoff_cap_s": self.backoff_cap_s,
                "deadline_s": self.deadline_s, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> "RetryPolicy":
        if not d:
            return cls()
        return cls(
            timeout_s=float(d.get("timeout_s", 30.0)),
            tries=int(d.get("tries", 8)),
            backoff_s=float(d.get("backoff_s", 0.25)),
            backoff_cap_s=float(d.get("backoff_cap_s", 2.0)),
            deadline_s=float(d.get("deadline_s", 120.0)),
            seed=int(d.get("seed", 0)),
        )

    def reseed(self, salt: int) -> "RetryPolicy":
        """Derive a policy with a per-caller jitter stream (worker id,
        shard id) so concurrent retry loops decorrelate."""
        return replace(self, seed=(self.seed * 1000003 + salt) & 0x7FFFFFFF)

    def backoff(self, attempt: int) -> float:
        base = min(self.backoff_cap_s, self.backoff_s * (2.0 ** attempt))
        u = random.Random((self.seed << 8) ^ attempt).random()
        return base * (0.5 + 0.5 * u)

    def attempts(self) -> Iterator[int]:
        """Yield attempt indices, sleeping the jittered backoff between
        them; stops after ``tries`` attempts or when the next attempt
        would start past ``deadline_s``.  The caller breaks out on
        success and re-raises its last error when the generator is
        exhausted."""
        start = time.monotonic()
        for i in range(self.tries):
            yield i
            if i + 1 >= self.tries:
                break
            pause = self.backoff(i)
            if time.monotonic() + pause - start > self.deadline_s:
                break
            time.sleep(pause)


# -- worker-side runtime ------------------------------------------------------


class WorkerFaults:
    """Executes a plan's worker-side events inside one worker process.

    Installs the wire-layer chaos hook, answers the step loop's
    straggler/compute-delay query, and arms the checkpoint-write fault.
    Each one-shot event fires at most once per invocation *generation*:
    events are keyed by identity, and the ``fired`` set survives only
    in-process — a respawned worker re-derives arming from its restored
    step, which is exactly the semantics a real transient fault has.
    """

    def __init__(self, plan: FaultPlan, worker_id: int):
        self.worker_id = worker_id
        self.events = plan.worker_events(worker_id)
        self._fired: set = set()
        self._step = -1
        self._installed = False

    def install(self) -> None:
        if self.events:
            framing.install_chaos_hook(self._on_wire)
            self._installed = True

    def uninstall(self) -> None:
        if self._installed:
            framing.clear_chaos_hook()
            self._installed = False

    def at_step(self, t: int) -> None:
        self._step = t

    # one-shot events fire when the worker has REACHED the event step —
    # ">=" not "==" — so a worker that restores past the step (crash
    # replay) still fires exactly once rather than never
    def _due(self, e: FaultEvent) -> bool:
        return id(e) not in self._fired and 0 <= e.step <= self._step

    def _on_wire(self, side: str, header: dict) -> None:
        op = header.get("op")
        for e in self.events:
            if not self._due(e):
                continue
            if e.op is not None and op is not None and e.op != op:
                continue
            if e.kind == "transport_delay" and side == "send":
                self._fired.add(id(e))
                time.sleep(e.delay_s)
            elif e.kind == "transport_stall" and side == "recv":
                self._fired.add(id(e))
                time.sleep(e.delay_s)
            elif e.kind == "transport_reset" and side == "send":
                self._fired.add(id(e))
                raise ConnectionError(
                    f"chaos: injected connection reset "
                    f"(worker {self.worker_id}, step {self._step})")

    def compute_delay_s(self, t: int) -> float:
        """Total injected straggler sleep for local step ``t``."""
        total = 0.0
        for e in self.events:
            if e.kind != "compute_delay" or t < e.step:
                continue
            if e.every > 0:
                if (t - e.step) % e.every == 0:
                    total += e.delay_s
            elif id(e) not in self._fired:
                self._fired.add(id(e))
                total += e.delay_s
        return total

    def ckpt_should_fail(self, step: int) -> bool:
        """True once when a ckpt_enospc event is armed at ``step``."""
        for e in self.events:
            if e.kind == "ckpt_enospc" and id(e) not in self._fired \
                    and step >= e.step:
                self._fired.add(id(e))
                return True
        return False


# -- resilient out-of-process job driver --------------------------------------


def run_job_resilient(cfg, max_restarts: int = 3,
                      verbose: bool = False) -> dict:
    """Run a job under a supervisor that may be killed by its own plan.

    The supervisor runs as a subprocess (``python -m
    repro.runtime.supervisor --config ... --allow-self-kill --resume``);
    when a ``supervisor_kill`` event takes it down mid-job, it is simply
    re-executed and re-adopts the live pool from its journal.  Returns
    the job result dict with ``supervisor_restarts`` added.
    """
    os.makedirs(cfg.run_dir, exist_ok=True)
    cfg_path = os.path.join(cfg.run_dir, "job_config.json")
    out_path = os.path.join(cfg.run_dir, "job_result.json")
    if os.path.exists(out_path):
        os.unlink(out_path)
    with open(cfg_path, "w") as f:
        json.dump(cfg.to_dict(), f)
    env = dict(os.environ)
    restarts = 0
    for attempt in range(max_restarts + 1):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.runtime.supervisor",
             "--config", cfg_path, "--out", out_path,
             "--allow-self-kill", "--resume"],
            env=env,
            stdout=None if verbose else subprocess.DEVNULL,
            stderr=None if verbose else subprocess.DEVNULL,
        )
        if os.path.exists(out_path):
            with open(out_path) as f:
                result = json.load(f)
            result["supervisor_restarts"] = restarts
            return result
        if proc.returncode == 0:
            raise RuntimeError(
                "supervisor exited 0 without writing a result")
        restarts += 1
    raise RuntimeError(
        f"supervisor did not complete within {max_restarts} restarts")
