"""Named, fully-deterministic workloads for the FaaS runtime.

A stateless worker cannot be handed Python objects — it gets a workload
*name* plus a JSON config dict from the broker's hello response and must
rebuild everything (data, initial parameters, grad function, minibatch
store) bit-identically to every peer and to the supervisor.  That's what
this registry guarantees: ``build(name, cfg)`` is a pure function of its
JSON-serializable arguments.

Workloads mirror the paper's two training jobs (§6.1):

* ``pmf`` — probabilistic matrix factorization on a MovieLens-like set
  (sparse updates; the headline ISP workload);
* ``lr``  — dense logistic regression on a Criteo-like set.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import numpy as np

from repro.data import synthetic
from repro.data.store import MinibatchStore

PyTree = Any


@dataclasses.dataclass
class Workload:
    """Everything a worker or supervisor needs about one training job."""

    name: str
    cfg: dict
    params0: PyTree
    grad_fn: Callable[[PyTree, Any], tuple[Any, PyTree]]
    store: MinibatchStore
    make_batch: Callable[[list[np.ndarray]], Any]
    eval_fn: Callable[[PyTree], float]

    @property
    def n_batches(self) -> int:
        return self.store.n_batches

    def batch(self, key: int):
        return self.make_batch(self.store.fetch(key))


def _pmf(cfg: dict) -> Workload:
    from repro.models import pmf
    import jax
    import jax.numpy as jnp

    c = {
        "n_users": 300,
        "n_movies": 500,
        "n_ratings": 24_000,
        "rank": 8,
        "batch_size": 256,
        "seed": 0,
        "eval_size": 2048,
        **cfg,
    }
    ml = synthetic.MovieLensLikeConfig(
        n_users=c["n_users"],
        n_movies=c["n_movies"],
        n_ratings=c["n_ratings"],
        rank=c["rank"],
        seed=c["seed"],
    )
    users, movies, ratings = synthetic.make_movielens(ml)
    mcfg = pmf.PMFConfig(
        n_users=ml.n_users, n_movies=ml.n_movies, rank=ml.rank
    )
    params0 = pmf.init(mcfg, jax.random.PRNGKey(c["seed"]))
    store = MinibatchStore([users, movies, ratings], c["batch_size"])
    rng = np.random.default_rng(c["seed"] + 17)
    eidx = rng.choice(
        len(ratings), min(c["eval_size"], len(ratings)), replace=False
    )
    eval_batch = pmf.RatingsBatch(
        user=jnp.asarray(users[eidx]),
        movie=jnp.asarray(movies[eidx]),
        rating=jnp.asarray(ratings[eidx]),
    )

    def make_batch(arrays: list[np.ndarray]):
        u, m, r = arrays
        return pmf.RatingsBatch(
            user=jnp.asarray(u), movie=jnp.asarray(m), rating=jnp.asarray(r)
        )

    return Workload(
        name="pmf",
        cfg=c,
        params0=params0,
        grad_fn=partial(pmf.grad_fn, mcfg),
        store=store,
        make_batch=make_batch,
        eval_fn=lambda p: float(pmf.rmse(p, eval_batch)),
    )


def _lr(cfg: dict) -> Workload:
    from repro.models import lr
    import jax
    import jax.numpy as jnp

    c = {
        "n_samples": 20_000,
        "batch_size": 256,
        "seed": 0,
        "eval_size": 2048,
        **cfg,
    }
    like = synthetic.CriteoLikeConfig(n_samples=c["n_samples"], seed=c["seed"])
    x, y = synthetic.make_criteo_dense(like)
    lcfg = lr.LRConfig(n_features=like.n_numerical, sparse=False)
    params0 = lr.init(lcfg, jax.random.PRNGKey(c["seed"]))
    store = MinibatchStore([x, y], c["batch_size"])
    rng = np.random.default_rng(c["seed"] + 17)
    eidx = rng.choice(len(y), min(c["eval_size"], len(y)), replace=False)
    eval_batch = lr.DenseBatch(x=jnp.asarray(x[eidx]), y=jnp.asarray(y[eidx]))

    def make_batch(arrays: list[np.ndarray]):
        xb, yb = arrays
        return lr.DenseBatch(x=jnp.asarray(xb), y=jnp.asarray(yb))

    return Workload(
        name="lr",
        cfg=c,
        params0=params0,
        grad_fn=partial(lr.grad_fn, lcfg),
        store=store,
        make_batch=make_batch,
        eval_fn=lambda p: float(lr.loss_fn(lcfg, p, eval_batch)),
    )


_REGISTRY: dict[str, Callable[[dict], Workload]] = {
    "pmf": _pmf,
    "lr": _lr,
}

WORKLOAD_NAMES = tuple(sorted(_REGISTRY))


def build(name: str, cfg: Optional[dict] = None) -> Workload:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown workload {name!r}; registered: {WORKLOAD_NAMES}"
        )
    return _REGISTRY[name](dict(cfg or {}))
