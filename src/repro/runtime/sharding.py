"""Leaf-key -> broker-shard partitioner + sharded tree encoding (DESIGN.md §11).

MLLess scales its external store by sharding keys across Redis instances
(paper §5; ``CommModel.n_redis`` already charges for it).  This module is
the live-runtime analogue: it owns the ONE deterministic assignment of
pytree leaf keys to broker shards that every party — each worker process,
the supervisor, and the tests — must compute identically from nothing but
the workload's parameter template and the shard count.

Properties the assignment guarantees (property-tested in
``tests/test_runtime_sharded.py``):

* **total**: every key is owned by exactly one shard in ``[0, n_shards)``;
* **deterministic / pool-independent**: a pure function of the
  (key, size) multiset and ``n_shards`` — independent of key order,
  worker-pool size, or process identity (no Python ``hash``, which is
  salted per process);
* **balanced**: greedy least-loaded placement over keys sorted by
  (size desc, key asc), so ``max_shard_bytes <= total/n + max_leaf_bytes``
  (the classic list-scheduling bound — tight enough that PMF's two
  embedding matrices land on different shards at ``n_shards == 2``).

``encode_tree_sharded`` is the worker-side producer: one codec pass per
leaf (``repro.wire``), grouped into per-shard (meta, buffer-views)
messages, with the optional fp32 quantization-error residual assembled
across all shards.  ``predict_shard_nbytes`` is the simulator/test-side
accountant: per-shard wire bytes through the same ``leaf_nbytes`` formula
the encoder asserts against, so broker-measured == simulator-accounted
bytes *per shard* by construction (§10's invariant, sharded).

**Oversized-leaf splitting** (``split_bytes > 0``): a model like PMF has
two embedding matrices and nothing else, so beyond two shards the greedy
partition degenerates — extra shards own zero update bytes.  With a split
threshold every leaf whose dense bytes exceed it is carved into
fixed-size flat chunks (element counts a multiple of 8, so bitmap masks
pack to identical totals) and the chunks are assigned independently.
The chunking is a pure function of the parameter template and the
threshold — NOT of the shard count — so wire bytes stay bit-identical
across topologies, and each *element* still lives on exactly one shard
with peers arriving in ascending worker order there: the per-element
float32 summation order, and therefore the final parameters, remain
bit-exact for any ``n_shards``.  ``tree_assignment`` warns when a shard
ends up owning zero bytes (raise the shard count past the chunk count
and the warning tells you the sweep is degenerate).
"""

from __future__ import annotations

import bisect
import hashlib
import warnings
from typing import Any, Callable, Iterator, Optional, Sequence

import numpy as np

from repro.wire import codec as wire_codec

PyTree = Any

#: Floor on the chunk byte size ``chunk_elems`` will honour.  Below this a
#: split threshold stops buying balance and starts exploding a large leaf
#: into thousands of subkeys, each paying per-chunk meta overhead — the
#: old floor was 8 *elements* (32 bytes of fp32), which silently turned a
#: 1 MB leaf into ~32k subkeys.
_MIN_CHUNK_BYTES = 1024

_warned_small_split = False


def assign_shards(
    keys: Sequence[str],
    sizes: Optional[Sequence[int]] = None,
    n_shards: int = 1,
) -> dict[str, int]:
    """Deterministic balanced assignment of leaf keys to shards.

    Greedy least-loaded over keys sorted by (size desc, key asc); ties on
    load go to the lowest shard id.  With ``sizes=None`` every key weighs
    1 (pure cardinality balance).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    keys = list(keys)
    if len(set(keys)) != len(keys):
        raise ValueError("leaf keys must be unique")
    weights = [1] * len(keys) if sizes is None else [int(s) for s in sizes]
    if len(weights) != len(keys):
        raise ValueError("sizes must align with keys")
    order = sorted(range(len(keys)), key=lambda i: (-weights[i], keys[i]))
    load = [0] * n_shards
    out: dict[str, int] = {}
    for i in order:
        s = min(range(n_shards), key=lambda j: (load[j], j))
        out[keys[i]] = s
        load[s] += weights[i]
    return out


def _ring_point(label: str) -> int:
    """Position of a label on the hash ring: 64-bit blake2b.  Never
    Python ``hash`` — that is salted per process, and every party (each
    worker, the supervisor, tests) must compute the identical ring."""
    return int.from_bytes(
        hashlib.blake2b(label.encode(), digest_size=8).digest(), "big"
    )


def ring_assign(
    keys: Sequence[str], n_shards: int, vnodes: int = 64
) -> dict[str, int]:
    """Consistent-hash assignment of keys to shards.

    Each shard owns ``vnodes`` points on a 64-bit ring, labelled
    ``"shard<s>:<v>"`` — labels depend only on the shard id, never on
    ``n_shards``, which is what buys the consistency property: going
    N→N+1 adds shard N's points and steals only the keys that now fall
    in its arcs (expected 1/(N+1) of them), moving them *to* the new
    shard; going N→N-1 removes shard N-1's points and releases only its
    keys *to* the survivors.  Every other key keeps its owner, so a live
    re-shard migrates a minimal, provable fraction of the store.  A pure
    function of (keys, n_shards) — key order, sizes, and process
    identity are irrelevant.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    keys = list(keys)
    if len(set(keys)) != len(keys):
        raise ValueError("leaf keys must be unique")
    points: list[tuple[int, int]] = sorted(
        (_ring_point(f"shard{s}:{v}"), s)
        for s in range(n_shards)
        for v in range(vnodes)
    )
    ring = [p for p, _ in points]
    out: dict[str, int] = {}
    for k in keys:
        i = bisect.bisect_right(ring, _ring_point(k)) % len(points)
        out[k] = points[i][1]
    return out


def chunk_elems(itemsize: int, split_bytes: int) -> int:
    """Elements per chunk for a split leaf: ``split_bytes`` worth, rounded
    down to a multiple of 8 so every chunk boundary falls on a bitmap-mask
    byte boundary — chunked bitmap bytes sum EXACTLY to the unsplit
    leaf's (``ceil(n/8)`` per chunk loses nothing when n % 8 == 0).
    A pure function of (itemsize, threshold): per-leaf or per-topology
    inputs here would break the cross-``n_shards`` byte invariance.

    ``split_bytes`` is clamped up to ``_MIN_CHUNK_BYTES`` (one-time
    warning): below that the chunk count grows without bound while each
    chunk's meta overhead stays fixed, so a tiny threshold silently
    explodes a large leaf into thousands of subkeys."""
    global _warned_small_split
    if 0 < split_bytes < _MIN_CHUNK_BYTES:
        if not _warned_small_split:
            _warned_small_split = True
            warnings.warn(
                f"shard_split_bytes={split_bytes} is below the "
                f"{_MIN_CHUNK_BYTES}-byte chunk floor; clamping — a "
                "smaller threshold only multiplies per-chunk meta "
                "overhead without improving balance",
                stacklevel=2,
            )
        split_bytes = _MIN_CHUNK_BYTES
    return max((split_bytes // max(itemsize, 1)) // 8 * 8, 8)


def iter_subleaves(
    key: str, leaf: Any, split_bytes: int
) -> Iterator[tuple[str, int, int]]:
    """Yield ``(subkey, offset_elems, n_elems)`` chunks of one leaf.

    A pure function of (leaf template, split_bytes) — never of the shard
    count — so the chunking, and with it every wire byte, is identical
    across topologies.  Unsplit leaves yield themselves with
    ``subkey == key``.
    """
    a = np.asarray(leaf)
    n = int(a.size)
    nbytes = n * a.dtype.itemsize
    if split_bytes <= 0 or nbytes <= split_bytes:
        yield key, 0, n
        return
    step = chunk_elems(a.dtype.itemsize, split_bytes)
    for i, off in enumerate(range(0, n, step)):
        yield f"{key}#{i:04d}", off, min(step, n - off)


def job_namespace(job_id: Optional[str]) -> str:
    """The per-job leaf-key prefix of the multi-job control plane
    (DESIGN.md §14): ``'j<id>/'`` for a fleet job, ``''`` for a solo job —
    the empty prefix keeps the single-job wire metadata byte-identical.
    ``'/'`` cannot occur in a job id (ids are validated by the scheduler)
    and terminates the prefix, so two distinct jobs can never collide on a
    key and a prefixed key can never equal an unprefixed one."""
    if job_id is None or job_id == "":
        return ""
    jid = str(job_id)
    if "/" in jid or "#" in jid:
        raise ValueError(f"job id must not contain '/' or '#': {jid!r}")
    return f"j{jid}/"


def tree_assignment(
    tree: PyTree,
    n_shards: int,
    split_bytes: int = 0,
    namespace: str = "",
    partitioner: str = "greedy",
) -> dict[str, int]:
    """The canonical assignment for a parameter template: keys are the
    checkpoint-store path keys (``wire.codec.tree_keys``) — or their
    ``key#chunk`` subkeys when ``split_bytes`` carves oversized leaves —
    weights the dense bytes, the quantity the balance bound is stated in.

    With a ``namespace`` (``job_namespace(job_id)``, multi-job control
    plane) every key is prefixed before placement.  Because the prefix is
    uniform across one job's keys, the (size desc, key asc) placement
    order — and therefore the partition itself — is IDENTICAL to the
    unprefixed one: a job sharded inside a fleet owns exactly the
    slices-per-shard it owns solo (property-tested in
    ``tests/test_runtime_multijob.py``).

    ``partitioner`` selects the placement policy: ``"greedy"`` (the
    default — least-loaded, best static balance, but a shard-count
    change can reshuffle everything) or ``"ring"`` (consistent hashing,
    minimal key movement across shard-count changes — the live-reshard
    partitioner).  Greedy stays the default so every existing run is
    bit-identical.

    Warns when any shard ends up owning ZERO bytes: every update round
    still pays that shard a round trip for nothing, and a sweep over
    shard counts silently stops measuring anything past that point.
    """
    import jax

    keys = wire_codec.tree_keys(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    subkeys: list[str] = []
    sizes: list[int] = []
    for key, leaf in zip(keys, leaves):
        itemsize = np.dtype(np.asarray(leaf).dtype).itemsize
        for subkey, _off, n in iter_subleaves(key, leaf, split_bytes):
            subkeys.append(namespace + subkey)
            sizes.append(n * itemsize)
    if partitioner == "greedy":
        assignment = assign_shards(subkeys, sizes, n_shards)
    elif partitioner == "ring":
        assignment = ring_assign(subkeys, n_shards)
    else:
        raise ValueError(
            f"unknown partitioner {partitioner!r} (greedy|ring)"
        )
    load = [0] * n_shards
    for subkey, size in zip(subkeys, sizes):
        load[assignment[subkey]] += size
    empty = [s for s, b in enumerate(load) if b == 0]
    if empty:
        warnings.warn(
            f"shard(s) {empty} own zero update bytes: the tree has only "
            f"{len(subkeys)} assignable leaves/chunks for {n_shards} "
            "shards — split oversized leaves (shard_split_bytes) or use "
            "a leafier workload",
            stacklevel=2,
        )
    return assignment


def tree_subleaves(
    tree: PyTree, split_bytes: int, namespace: str = ""
) -> list[tuple[str, str, int, int]]:
    """Flat list of ``(leaf_key, namespaced_subkey, offset_elems,
    n_elems)`` for every chunk of every leaf — the key universe a live
    handover enumerates when computing which stored identities move
    between shards (``leaf_key`` here is the namespaced key the metas
    carry in ``m['k']``)."""
    import jax

    keys = wire_codec.tree_keys(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    out: list[tuple[str, str, int, int]] = []
    for key, leaf in zip(keys, leaves):
        for subkey, off, n in iter_subleaves(key, leaf, split_bytes):
            out.append((namespace + key, namespace + subkey, off, n))
    return out


def offset_owner(
    tree: PyTree,
    split_bytes: int,
    assignment: dict[str, int],
    namespace: str = "",
) -> Callable[[str, int], int]:
    """Owner lookup ``(namespaced_leaf_key, offset_elems) -> shard`` under
    ``assignment`` (a ``tree_assignment`` for the SAME split_bytes).

    This is how a handover maps stored entries — chunked at the *old*
    ``split_bytes`` — onto the *new* topology when the thresholds differ:
    each old chunk goes to whichever new shard owns the new chunk that
    contains the old chunk's start offset.  Totality (each element
    stored exactly once across shards) is preserved, which is the only
    invariant pre-fence data needs — post-fence pulls never read
    pre-fence steps, and dump reassembly is order-insensitive per
    (worker, step)."""
    starts: dict[str, tuple[list[int], list[int]]] = {}
    for leaf_key, subkey, off, _n in tree_subleaves(
        tree, split_bytes, namespace
    ):
        offs, shards = starts.setdefault(leaf_key, ([], []))
        offs.append(off)
        shards.append(assignment[subkey])

    def owner(leaf_key: str, off: int) -> int:
        offs, shards = starts[leaf_key]
        return shards[bisect.bisect_right(offs, int(off)) - 1]

    return owner


def encode_tree_sharded(
    tree: PyTree,
    assignment: dict[str, int],
    n_shards: int,
    scheme: str = wire_codec.AUTO,
    quant: str = "none",
    with_residual: bool = False,
    split_bytes: int = 0,
    namespace: str = "",
    impl: str = "numpy",
) -> tuple[list[tuple[list[dict], list]], Optional[PyTree]]:
    """Encode a pytree into one (meta, buffer-views) message per shard.

    Leaves (and, under ``split_bytes``, their chunks in ascending offset
    order) keep the global ``tree_keys`` order *within* each shard, so a
    peer decoding shard by shard reassembles every element in a fixed
    order regardless of ``n_shards`` — the bit-exactness across shard
    counts rests on this.  Chunk metas carry the full leaf key in ``k``
    plus the flat element offset in ``o``; ``LeafBuffers`` is the decode
    twin.  Under a job ``namespace`` the meta keys and the assignment
    lookups are both prefixed — a fleet worker's ``LeafBuffers`` is keyed
    by the same prefixed keys, so one job can never decode into another
    job's accumulators.  Returns ``(per_shard, residual_tree)`` where
    ``per_shard[s]`` feeds ``publish``/``flush`` to shard ``s`` directly.
    ``impl`` selects the codec implementation per leaf (numpy reference
    or the fused Pallas wire-pack kernel, DESIGN.md §15) — wire bytes,
    metas and residuals are bit-identical either way.
    """
    import jax

    keys = wire_codec.tree_keys(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    per_shard: list[tuple[list[dict], list]] = [
        ([], []) for _ in range(n_shards)
    ]
    residuals: list = []
    for key, leaf in zip(keys, leaves):
        a = np.asarray(leaf)
        flat = np.ascontiguousarray(a).reshape(-1)
        res_flat: Optional[np.ndarray] = None
        for subkey, off, n in iter_subleaves(key, leaf, split_bytes):
            m, parts, r = wire_codec.encode_leaf(
                flat[off: off + n] if subkey != key else leaf,
                scheme=scheme, quant=quant, key=namespace + key,
                with_residual=with_residual, impl=impl,
            )
            if subkey != key:
                m["o"] = off
            meta_s, parts_s = per_shard[assignment[namespace + subkey]]
            meta_s.append(m)
            parts_s.extend(parts)
            if with_residual:
                if subkey == key:
                    res_flat = r.reshape(-1)
                else:
                    if res_flat is None:
                        res_flat = np.zeros(flat.size, np.float32)
                    res_flat[off: off + n] = r
        residuals.append(
            res_flat.reshape(a.shape) if res_flat is not None else None
        )
    res_tree = None
    if with_residual:
        treedef = jax.tree_util.tree_structure(tree)
        res_tree = jax.tree_util.tree_unflatten(treedef, residuals)
    return per_shard, res_tree


def predict_shard_nbytes(
    tree: PyTree,
    assignment: dict[str, int],
    n_shards: int,
    scheme: str = wire_codec.AUTO,
    quant: str = "none",
    split_bytes: int = 0,
    namespace: str = "",
) -> list[int]:
    """Simulator-side per-shard accounting: wire bytes each shard WOULD
    measure for this tree — the per-leaf accountant is the codec's own
    ``predict_leaf_nbytes`` (same ``leaf_nbytes`` formula + ``auto``
    resolution the encoder asserts against), chunked and bucketed by the
    same assignment the encoder uses, so ``== broker-measured`` per shard
    by construction."""
    import jax

    keys = wire_codec.tree_keys(tree)
    out = [0] * n_shards
    for key, leaf in zip(keys, jax.tree_util.tree_leaves(tree)):
        flat = np.ascontiguousarray(np.asarray(leaf)).reshape(-1)
        for subkey, off, n in iter_subleaves(key, leaf, split_bytes):
            out[assignment[namespace + subkey]] += wire_codec.predict_leaf_nbytes(
                flat[off: off + n] if subkey != key else leaf,
                scheme, quant,
            )
    return out


class LeafBuffers:
    """Per-leaf-key accumulation buffers — the ONE decode-side assembler
    for sharded (and possibly split) update payloads.

    ``add`` folds a decoded leaf or chunk into its buffer at the chunk's
    flat offset, in arrival order: within a shard that is ascending
    worker then ascending ``tree_keys``/offset order, and every element
    is owned by exactly one shard — the fixed per-element float32
    summation order the cross-topology bit-exactness claim rests on.
    Flush reassembly uses the same ``add`` (chunks of one worker's flush
    are disjoint, so summing into zeros reproduces the exact values).
    """

    def __init__(self, leaf_like: dict[str, tuple[Any, Any]]):
        self._bufs = {
            k: np.zeros(shape, dtype)
            for k, (shape, dtype) in leaf_like.items()
        }
        self._added = {k: 0 for k in self._bufs}

    def add(self, meta: dict, decoded: Any) -> None:
        buf = self._bufs[meta["k"]].reshape(-1)
        arr = np.asarray(decoded).reshape(-1)
        off = int(meta.get("o", 0))
        buf[off: off + arr.size] += arr
        self._added[meta["k"]] += arr.size

    def add_encoded(self, meta: dict, blob, impl: str = "numpy") -> None:
        """Fold one ENCODED leaf/chunk straight into its buffer slice —
        the fused decode/apply seam (DESIGN.md §15): under
        ``impl='pallas'`` a bitmap-encoded part is scattered into the
        accumulator by the unpack-apply kernel without materializing the
        dense intermediate; every other case is exactly
        ``add(meta, decode_leaf(meta, blob))``.  Bit-identical across
        impls (the kernel's off-support lanes add the same +0.0 numpy's
        ``+=`` does)."""
        if impl == "numpy":
            self.add(meta, wire_codec.decode_leaf(meta, blob))
            return
        buf = self._bufs[meta["k"]].reshape(-1)
        n = int(np.prod(meta["shape"])) if meta["shape"] else 1
        off = int(meta.get("o", 0))
        buf[off: off + n] = wire_codec.decode_add_leaf(
            buf[off: off + n], meta, blob, impl=impl
        )
        self._added[meta["k"]] += n

    def assert_complete(self, copies: int = 1, what: str = "tree") -> None:
        """Every element must have arrived exactly ``copies`` times —
        the all-or-nothing witness for flush/dump reassembly, which
        would otherwise silently read as zeros where a shard's slice
        went missing (the pre-LeafBuffers dict lookup was a loud
        KeyError; this keeps that property)."""
        bad = {
            k: (got, self._bufs[k].size * copies)
            for k, got in self._added.items()
            if got != self._bufs[k].size * copies
        }
        if bad:
            raise ValueError(
                f"incomplete {what} reassembly: got/expected elements "
                f"per leaf {bad}"
            )

    def __getitem__(self, key: str) -> np.ndarray:
        return self._bufs[key]

    def __contains__(self, key: str) -> bool:
        return key in self._bufs


def iter_part_views(descs: list[dict], payload):
    """Walk one shard's multi-part pull/dump payload: yields
    ``(desc, leaf_meta, byte_view)`` for every leaf of every part — the
    ONE place the per-part offset bookkeeping lives.  ``iter_part_leaves``
    decodes on top of this; the worker's fused decode/apply path hands
    the views to ``LeafBuffers.add_encoded`` instead."""
    from repro.wire.framing import unpack_parts

    for desc, part in unpack_parts(descs, payload):
        view = memoryview(part)
        off = 0
        for m in desc["meta"]:
            nb = int(m["nbytes"])
            yield desc, m, view[off:off + nb]
            off += nb
        if off != len(view):
            raise ValueError(
                f"part for worker {desc.get('worker')}: {len(view) - off} "
                "trailing bytes after its leaf metas"
            )


def iter_part_leaves(descs: list[dict], payload, impl: str = "numpy"):
    """Walk one shard's multi-part pull/dump payload: yields
    ``(desc, leaf_meta, decoded_leaf)`` for every leaf of every part.

    The ONE decode twin of ``encode_tree_sharded``'s slicing — the
    worker's peer-sum/flush reassembly and the supervisor's dump merge
    both consume this, so the offset bookkeeping and key-order
    assumptions the bit-exactness claim rests on live in one place.
    """
    for desc, m, view in iter_part_views(descs, payload):
        yield desc, m, wire_codec.decode_leaf(m, view, impl=impl)


def shard_bytes_bound(
    sizes: Sequence[int], n_shards: int
) -> float:
    """The list-scheduling balance bound the property tests assert:
    ``max shard load <= total/n + max item`` for least-loaded placement."""
    total = float(sum(sizes))
    biggest = float(max(sizes, default=0))
    return total / max(n_shards, 1) + biggest
