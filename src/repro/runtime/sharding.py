"""Leaf-key -> broker-shard partitioner + sharded tree encoding (DESIGN.md §11).

MLLess scales its external store by sharding keys across Redis instances
(paper §5; ``CommModel.n_redis`` already charges for it).  This module is
the live-runtime analogue: it owns the ONE deterministic assignment of
pytree leaf keys to broker shards that every party — each worker process,
the supervisor, and the tests — must compute identically from nothing but
the workload's parameter template and the shard count.

Properties the assignment guarantees (property-tested in
``tests/test_runtime_sharded.py``):

* **total**: every key is owned by exactly one shard in ``[0, n_shards)``;
* **deterministic / pool-independent**: a pure function of the
  (key, size) multiset and ``n_shards`` — independent of key order,
  worker-pool size, or process identity (no Python ``hash``, which is
  salted per process);
* **balanced**: greedy least-loaded placement over keys sorted by
  (size desc, key asc), so ``max_shard_bytes <= total/n + max_leaf_bytes``
  (the classic list-scheduling bound — tight enough that PMF's two
  embedding matrices land on different shards at ``n_shards == 2``).

``encode_tree_sharded`` is the worker-side producer: one codec pass per
leaf (``repro.wire``), grouped into per-shard (meta, buffer-views)
messages, with the optional fp32 quantization-error residual assembled
across all shards.  ``predict_shard_nbytes`` is the simulator/test-side
accountant: per-shard wire bytes through the same ``leaf_nbytes`` formula
the encoder asserts against, so broker-measured == simulator-accounted
bytes *per shard* by construction (§10's invariant, sharded).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import numpy as np

from repro.wire import codec as wire_codec

PyTree = Any


def assign_shards(
    keys: Sequence[str],
    sizes: Optional[Sequence[int]] = None,
    n_shards: int = 1,
) -> dict[str, int]:
    """Deterministic balanced assignment of leaf keys to shards.

    Greedy least-loaded over keys sorted by (size desc, key asc); ties on
    load go to the lowest shard id.  With ``sizes=None`` every key weighs
    1 (pure cardinality balance).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    keys = list(keys)
    if len(set(keys)) != len(keys):
        raise ValueError("leaf keys must be unique")
    weights = [1] * len(keys) if sizes is None else [int(s) for s in sizes]
    if len(weights) != len(keys):
        raise ValueError("sizes must align with keys")
    order = sorted(range(len(keys)), key=lambda i: (-weights[i], keys[i]))
    load = [0] * n_shards
    out: dict[str, int] = {}
    for i in order:
        s = min(range(n_shards), key=lambda j: (load[j], j))
        out[keys[i]] = s
        load[s] += weights[i]
    return out


def tree_assignment(tree: PyTree, n_shards: int) -> dict[str, int]:
    """The canonical assignment for a parameter template: keys are the
    checkpoint-store path keys (``wire.codec.tree_keys``), weights the
    dense leaf bytes — the quantity the balance bound is stated in."""
    import jax

    keys = wire_codec.tree_keys(tree)
    sizes = [
        int(np.asarray(leaf).size) * np.dtype(np.asarray(leaf).dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
    ]
    return assign_shards(keys, sizes, n_shards)


def encode_tree_sharded(
    tree: PyTree,
    assignment: dict[str, int],
    n_shards: int,
    scheme: str = wire_codec.AUTO,
    quant: str = "none",
    with_residual: bool = False,
) -> tuple[list[tuple[list[dict], list]], Optional[PyTree]]:
    """Encode a pytree into one (meta, buffer-views) message per shard.

    Leaves keep the global ``tree_keys`` order *within* each shard, so a
    peer decoding shard by shard reassembles every leaf in a fixed order
    regardless of ``n_shards`` — the bit-exactness across shard counts
    rests on this.  Returns ``(per_shard, residual_tree)`` where
    ``per_shard[s]`` feeds ``publish``/``flush`` to shard ``s`` directly.
    """
    import jax

    keys = wire_codec.tree_keys(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    per_shard: list[tuple[list[dict], list]] = [
        ([], []) for _ in range(n_shards)
    ]
    residuals: list = []
    for key, leaf in zip(keys, leaves):
        m, parts, r = wire_codec.encode_leaf(
            leaf, scheme=scheme, quant=quant, key=key,
            with_residual=with_residual,
        )
        meta_s, parts_s = per_shard[assignment[key]]
        meta_s.append(m)
        parts_s.extend(parts)
        residuals.append(r)
    res_tree = None
    if with_residual:
        treedef = jax.tree_util.tree_structure(tree)
        res_tree = jax.tree_util.tree_unflatten(treedef, residuals)
    return per_shard, res_tree


def predict_shard_nbytes(
    tree: PyTree,
    assignment: dict[str, int],
    n_shards: int,
    scheme: str = wire_codec.AUTO,
    quant: str = "none",
) -> list[int]:
    """Simulator-side per-shard accounting: wire bytes each shard WOULD
    measure for this tree — the per-leaf accountant is the codec's own
    ``predict_leaf_nbytes`` (same ``leaf_nbytes`` formula + ``auto``
    resolution the encoder asserts against), just bucketed by the
    assignment, so ``== broker-measured`` per shard by construction."""
    import jax

    keys = wire_codec.tree_keys(tree)
    out = [0] * n_shards
    for key, leaf in zip(keys, jax.tree_util.tree_leaves(tree)):
        out[assignment[key]] += wire_codec.predict_leaf_nbytes(
            leaf, scheme, quant
        )
    return out


def iter_part_leaves(descs: list[dict], payload):
    """Walk one shard's multi-part pull/dump payload: yields
    ``(desc, leaf_meta, decoded_leaf)`` for every leaf of every part.

    The ONE decode twin of ``encode_tree_sharded``'s slicing — the
    worker's peer-sum/flush reassembly and the supervisor's dump merge
    both consume this, so the offset bookkeeping and key-order
    assumptions the bit-exactness claim rests on live in one place.
    """
    from repro.wire.framing import unpack_parts

    for desc, part in unpack_parts(descs, payload):
        view = memoryview(part)
        off = 0
        for m in desc["meta"]:
            nb = int(m["nbytes"])
            yield desc, m, wire_codec.decode_leaf(m, view[off:off + nb])
            off += nb
        if off != len(view):
            raise ValueError(
                f"part for worker {desc.get('worker')}: {len(view) - off} "
                "trailing bytes after its leaf metas"
            )


def shard_bytes_bound(
    sizes: Sequence[int], n_shards: int
) -> float:
    """The list-scheduling balance bound the property tests assert:
    ``max shard load <= total/n + max item`` for least-loaded placement."""
    total = float(sum(sizes))
    biggest = float(max(sizes, default=0))
    return total / max(n_shards, 1) + biggest
