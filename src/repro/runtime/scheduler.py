"""Fleet scheduler — N concurrent training jobs on ONE serverless pool.

MLLess's thesis is cost efficiency from sub-second billing, but a single
job pays for every barrier stall: an ISP worker blocked on its slowest
peer is a live, billed function doing nothing.  The fleet scheduler
(DESIGN.md §14) admits N jobs onto one shared broker/worker pool so one
job's stall absorbs another job's compute inside the same 100 ms billing
quantum — the adaptive multi-job gap SMLT frames (PAPERS.md).

Architecture — every layer keeps its single-job semantics per job:

* **brokers**: each shard process hosts one independent ``BrokerCore``
  per job (``broker.Broker`` with a ``{"jobs": ...}`` config).  Requests
  route by their ``job`` header; all cores share one TCP port, one WAL
  (records are job-stamped and replay back into the right core) and the
  shm segments.  A shard SIGKILL replays every job's history at once.
* **keys**: every leaf key is prefixed ``j<id>/`` through
  ``sharding.job_namespace``.  The prefix is uniform within a job, so
  the (size desc, key asc) partition — and hence each job's per-shard
  slices, byte accounting and float summation order — is IDENTICAL to
  the same job run solo.  Concurrency is observationally invisible:
  final params are bit-identical to the solo run (the repo's standard
  gate, asserted across {tcp,shm} x {1,2} brokers x {isp,ssp}).
* **workers**: one invocation process per slot runs one training thread
  per admitted job (``worker.run_worker_fleet``) — bin-packing.  A
  process-wide compute lock models the 1-vCPU function: a job computes
  exactly while its siblings wait on barriers.  The first thread to hit
  its invocation budget declares a process-wide boundary; siblings wind
  down as ``bye:invocation-end`` within one 2 s barrier slice and the
  scheduler respawns ONE invocation for all of them.
* **scale-in**: one *independent, unmodified* ``ScaleInAutoTuner`` per
  job, fed that job's own telemetry — each job walks its own knee curve.
  The scheduler arbitrates a shared ``pool_budget``: when the fleet's
  active (worker, job) pairs exceed it, the job holding the most active
  workers gives one up (reason ``fair-share``).
* **billing**: the pool pays ONE bill (quantum-rounded invocation
  lifetimes + the shared VMs billed once on the fleet wall clock);
  ``core.billing.multi_job_rollup`` attributes it to jobs proportionally
  by measured busy seconds.  The headline claim — two bin-packed jobs
  cost less than the same two jobs solo — is measured live by
  ``benchmarks/fig11_multijob.py``.

``launch/train.py --jobs jobs.json`` is the CLI entry point.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from typing import Any, Optional

from repro.core.autotuner import (
    AutoTunerConfig,
    ScaleInAutoTuner,
    TopologyTuner,
    TopologyTunerConfig,
)
from repro.core.billing import CommModel, faas_cost, multi_job_rollup
from repro.runtime import protocol
from repro.runtime import workload as workload_lib
from repro.runtime.sharding import job_namespace
from repro.runtime.supervisor import FaaSJobConfig


@dataclasses.dataclass
class FleetConfig:
    """N admitted jobs sharing one broker/worker pool.

    Jobs must agree on the pool topology (``n_brokers``, ``transport``) —
    they share the processes.  Everything else (workload, wire scheme,
    consistency, slack, step budgets, tuners, fault hooks) is per job.
    Each job's ``run_dir`` is forced to ``<run_dir>/jobs/<job_id>`` so
    checkpoints and JIT caches never collide.
    """

    run_dir: str
    jobs: dict[str, FaaSJobConfig] = dataclasses.field(default_factory=dict)
    # fair-share arbitration: max concurrent active (worker, job) pairs
    # across the fleet; None = uncapped (each job keeps its own pool)
    pool_budget: Optional[int] = None
    poll_interval_s: float = 0.05
    deadline_s: float = 600.0


@dataclasses.dataclass
class _FleetSlot:
    """One invocation slot (one billable process hosting >= 1 job)."""

    worker: int
    proc: Optional[subprocess.Popen] = None
    spawned_at: float = 0.0
    invocations: int = 0
    # per-job shm segment names of the live invocation
    shm_segs: list = dataclasses.field(default_factory=list)

    @property
    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None


@dataclasses.dataclass
class _BrokerShard:
    shard: int
    proc: Optional[subprocess.Popen] = None
    addr: Optional[tuple[str, int]] = None
    spawns: int = 0

    @property
    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None


@dataclasses.dataclass
class _JobState:
    """Per-job control-plane state (the solo supervisor's fields, keyed)."""

    cfg: FaaSJobConfig
    wl: Any
    history: list = dataclasses.field(default_factory=list)
    poll_since: int = 1
    frontier: int = 0
    evictions: dict = dataclasses.field(default_factory=dict)
    statuses: dict = dataclasses.field(default_factory=dict)
    scale_events: list = dataclasses.field(default_factory=list)
    scripted_fired: int = 0
    killed_once: bool = False
    broker_killed_once: bool = False
    tuner: Optional[ScaleInAutoTuner] = None
    # observe-only topology tuner (cfg.topology_tune under a fleet): the
    # broker pool is SHARED, so no job may re-shard it live — the tuner
    # measures the running cell and the result carries a model-ranked
    # recommendation instead of a handover (DESIGN.md §16)
    topo_tuner: Optional[TopologyTuner] = None
    # (worker -> 'done' | 'evicted'): this job's terminal workers
    terminal: dict = dataclasses.field(default_factory=dict)

    def live_workers(self) -> list[int]:
        return [
            w for w in range(self.cfg.n_workers) if w not in self.terminal
        ]

    def active_workers(self) -> list[int]:
        """Live and not yet scheduled to leave."""
        return [w for w in self.live_workers() if w not in self.evictions]

    @property
    def complete(self) -> bool:
        return not self.live_workers()


class FleetScheduler:
    """Admission + packing + fair-share control plane over one pool."""

    def __init__(self, fleet: FleetConfig):
        if not fleet.jobs:
            raise ValueError("fleet needs at least one job")
        self.fleet = fleet
        self.job_ids = sorted(fleet.jobs)
        for jid in self.job_ids:
            job_namespace(jid)  # validates the id charset
        cfgs = [fleet.jobs[j] for j in self.job_ids]
        if len({c.n_brokers for c in cfgs}) != 1:
            raise ValueError("fleet jobs must agree on n_brokers")
        if len({c.transport for c in cfgs}) != 1:
            raise ValueError("fleet jobs must agree on transport")
        for jid, c in zip(self.job_ids, cfgs):
            if c.transport not in ("tcp", "shm"):
                raise ValueError(f"job {jid}: bad transport {c.transport!r}")
            if c.consistency not in ("isp", "ssp"):
                raise ValueError(
                    f"job {jid}: bad consistency {c.consistency!r}"
                )
            if c.consistency == "ssp" and c.slack < 0:
                raise ValueError(f"job {jid}: slack must be >= 0")
            if c.prewarm:
                # pre-warmed respawn is a solo-supervisor feature; a fleet
                # slot already overlaps init across jobs by construction
                raise ValueError(
                    f"job {jid}: prewarm is not supported under the fleet "
                    "scheduler (use the solo supervisor)"
                )
            if c.scripted_retunes:
                # the broker pool is shared across jobs: one job forcing a
                # re-shard would fence every other job's workers mid-step
                raise ValueError(
                    f"job {jid}: scripted_retunes is not supported under "
                    "the fleet scheduler (use the solo supervisor)"
                )
            if c.chaos is not None:
                # a chaos plan SIGKILLs shared slots/brokers — the blast
                # radius crosses tenant boundaries; the legacy per-job
                # kill_*_at_step knobs above remain the fleet's fault hooks
                raise ValueError(
                    f"job {jid}: chaos plans are not supported under the "
                    "fleet scheduler (use the solo supervisor)"
                )
        self.n_brokers = cfgs[0].n_brokers
        self.transport = cfgs[0].transport
        # admission: pin each job's run_dir inside the fleet's
        self.jobs: dict[str, _JobState] = {}
        for jid in self.job_ids:
            cfg = dataclasses.replace(
                fleet.jobs[jid],
                run_dir=os.path.join(fleet.run_dir, "jobs", jid),
            )
            st = _JobState(cfg=cfg, wl=workload_lib.build(
                cfg.workload, cfg.workload_cfg
            ))
            if cfg.autotune:
                st.tuner = ScaleInAutoTuner(
                    cfg.tuner or AutoTunerConfig(), cfg.n_workers
                )
            if cfg.topology_tune:
                # observe-only: single cell = the fleet's shared topology
                st.topo_tuner = TopologyTuner(
                    [{
                        "n_brokers": self.n_brokers,
                        "transport": self.transport,
                        "wire_scheme": cfg.wire_scheme,
                        "shard_split_bytes": cfg.shard_split_bytes,
                    }],
                    TopologyTunerConfig(),
                    comm=CommModel(),
                    n_workers=cfg.n_workers,
                )
            self.jobs[jid] = st
        n_slots = max(c.n_workers for c in cfgs)
        self.slots = [_FleetSlot(worker=w) for w in range(n_slots)]
        self.shards = [_BrokerShard(shard=s) for s in range(self.n_brokers)]
        self._conns: list[Optional[protocol.Connection]] = (
            [None] * self.n_brokers
        )
        self.lifetimes: list[float] = []
        self.respawns: list[dict] = []
        self.broker_respawns: list[dict] = []
        self._stopping = False
        import secrets

        self._shm_token = f"fl{os.getpid():x}{secrets.token_hex(2)}"
        self._shm_segments: dict[str, Any] = {}

    # -- job placement ---------------------------------------------------------

    def _hosted_jobs(self, slot: _FleetSlot) -> list[str]:
        """Jobs this slot still runs: admitted there and not terminal."""
        return [
            jid for jid in self.job_ids
            if slot.worker < self.jobs[jid].cfg.n_workers
            and slot.worker not in self.jobs[jid].terminal
        ]

    # -- env / broker lifecycle (the solo supervisor's recipe, fleet dirs) -----

    def _base_env(self) -> dict:
        import repro

        pkg_dir = (
            os.path.dirname(repro.__file__)
            if getattr(repro, "__file__", None)
            else next(iter(repro.__path__))
        )
        src = os.path.dirname(os.path.abspath(pkg_dir))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        return env

    def _worker_env(self) -> dict:
        env = self._base_env()
        if all(self.jobs[j].cfg.force_cpu for j in self.job_ids):
            env["JAX_PLATFORMS"] = "cpu"
        env.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false "
                       "intra_op_parallelism_threads=1")
        env.setdefault("OMP_NUM_THREADS", "1")
        env.setdefault("OPENBLAS_NUM_THREADS", "1")
        return env

    def _broker_dir(self) -> str:
        return os.path.join(self.fleet.run_dir, "broker")

    def _spawn_broker(self, bs: _BrokerShard) -> None:
        bdir = self._broker_dir()
        os.makedirs(bdir, exist_ok=True)
        logdir = os.path.join(self.fleet.run_dir, "logs")
        os.makedirs(logdir, exist_ok=True)
        port_file = os.path.join(bdir, f"shard{bs.shard:02d}.port")
        if os.path.exists(port_file):
            os.unlink(port_file)
        wal_path = os.path.join(bdir, f"shard{bs.shard:02d}.wal")
        if bs.spawns == 0 and os.path.exists(wal_path):
            os.unlink(wal_path)  # fresh fleet: never replay a previous one
        log = open(
            os.path.join(
                logdir, f"broker{bs.shard:02d}.spawn{bs.spawns:02d}.log"
            ),
            "wb",
        )
        bs.proc = subprocess.Popen(
            [
                sys.executable, "-m", "repro.runtime.broker",
                "--config", os.path.join(bdir, "fleet.json"),
                "--shard-id", str(bs.shard),
                "--n-shards", str(self.n_brokers),
                "--port", str(bs.addr[1] if bs.addr else 0),
                "--wal", wal_path,
                "--port-file", port_file,
            ],
            stdout=log,
            stderr=subprocess.STDOUT,
            env=self._base_env(),
        )
        log.close()
        bs.spawns += 1
        deadline = time.monotonic() + max(
            self.jobs[j].cfg.broker_spawn_timeout_s for j in self.job_ids
        )
        while not os.path.exists(port_file):
            if bs.proc.poll() is not None:
                raise RuntimeError(
                    f"fleet broker shard {bs.shard} exited during spawn "
                    f"(code {bs.proc.returncode}); logs in {logdir}"
                )
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"fleet broker shard {bs.shard} did not listen in time"
                )
            time.sleep(0.01)
        with open(port_file) as f:
            host, port = f.read().strip().rsplit(":", 1)
        bs.addr = (host, int(port))

    def _start_brokers(self) -> None:
        bdir = self._broker_dir()
        os.makedirs(bdir, exist_ok=True)
        cfg_doc = {
            "jobs": {
                jid: self.jobs[jid].cfg.job_dict(self.jobs[jid].wl.n_batches)
                for jid in self.job_ids
            }
        }
        with open(os.path.join(bdir, "fleet.json"), "w") as f:
            json.dump(cfg_doc, f, indent=1)
        for bs in self.shards:
            self._spawn_broker(bs)

    def _reap_brokers(self) -> None:
        if self._stopping:
            return
        for bs in self.shards:
            if bs.proc is not None and bs.proc.poll() is not None:
                self.broker_respawns.append(
                    {
                        "shard": bs.shard,
                        "exit_code": bs.proc.returncode,
                        "at_frontier": {
                            j: self.jobs[j].frontier for j in self.job_ids
                        },
                    }
                )
                if self._conns[bs.shard] is not None:
                    self._conns[bs.shard].close()
                    self._conns[bs.shard] = None
                self._spawn_broker(bs)
                if self.transport == "shm":
                    self._reserve_shard_shm(bs)

    # -- shm lifecycle (per (slot, job, shard) segment families) ---------------

    def _teardown_slot_shm(self, slot: _FleetSlot) -> None:
        from repro.wire import shm

        for name in slot.shm_segs:
            seg = self._shm_segments.pop(name, None)
            if seg is not None:
                seg.unlink()
            else:  # pragma: no cover - belt and braces
                shm.Segment.unlink_by_name(name)
        slot.shm_segs = []

    def _setup_slot_shm(self, slot: _FleetSlot, jids: list[str]) -> str:
        """Fresh per-job segment families for this slot's next invocation;
        the worker's job thread for ``jid`` attaches
        ``<base>g<jid>s<shard>`` (worker.run_worker_fleet)."""
        from repro.wire import shm

        self._teardown_slot_shm(slot)
        base = f"{self._shm_token}w{slot.worker}i{slot.invocations}"
        ring = max(self.jobs[j].cfg.shm_ring_bytes for j in jids)
        names = [
            f"{base}g{jid}s{s}"
            for jid in jids for s in range(self.n_brokers)
        ]
        for name in names:
            self._shm_segments[name] = shm.Segment.create(
                name, ring_bytes=ring
            )
        for jid in jids:
            for s in range(self.n_brokers):
                resp, _ = self._rpc(
                    {"t": "shm_serve", "seg": f"{base}g{jid}s{s}"}, shard=s
                )
                if not resp.get("ok"):  # pragma: no cover - defensive
                    raise RuntimeError(f"shard {s} refused shm_serve: {resp}")
        slot.shm_segs = names
        return base

    def _reserve_shard_shm(self, bs: _BrokerShard) -> None:
        for slot in self.slots:
            if not slot.shm_segs:
                continue
            for name in slot.shm_segs:
                if not name.endswith(f"s{bs.shard}"):
                    continue
                for attempt in range(3):
                    try:
                        protocol.request(
                            bs.addr, {"t": "shm_serve", "seg": name},
                            timeout=10.0,
                        )
                        break
                    except (ConnectionError, OSError, TimeoutError):
                        if attempt == 2:
                            break
                        time.sleep(0.2)

    # -- worker lifecycle ------------------------------------------------------

    def _spawn(self, slot: _FleetSlot) -> None:
        jids = self._hosted_jobs(slot)
        assert jids, "spawning a slot with no live jobs"
        logdir = os.path.join(self.fleet.run_dir, "logs")
        os.makedirs(logdir, exist_ok=True)
        log = open(
            os.path.join(
                logdir, f"w{slot.worker:03d}.inv{slot.invocations:03d}.log"
            ),
            "wb",
        )
        brokers = ",".join(f"{h}:{p}" for h, p in
                           (bs.addr for bs in self.shards))
        cmd = [
            sys.executable, "-m", "repro.runtime.worker",
            "--brokers", brokers,
            "--worker-id", str(slot.worker),
            "--jobs", ",".join(jids),
        ]
        if self.transport == "shm":
            cmd += ["--transport", "shm",
                    "--shm-seg", self._setup_slot_shm(slot, jids)]
        slot.proc = subprocess.Popen(
            cmd,
            stdout=log,
            stderr=subprocess.STDOUT,
            env=self._worker_env(),
        )
        log.close()
        slot.spawned_at = time.monotonic()
        slot.invocations += 1

    def _reap(self, slot: _FleetSlot) -> None:
        """Classify an exited invocation per hosted job and respawn while
        any of them lives on.  Terminal statuses (done/evicted) were
        already folded in from live polls; what's left per job is either
        a clean invocation boundary or a crash (replay)."""
        assert slot.proc is not None
        code = slot.proc.returncode
        self.lifetimes.append(time.monotonic() - slot.spawned_at)
        slot.proc = None
        live = []
        for jid in self._hosted_jobs(slot):
            st = self.jobs[jid]
            status = st.statuses.get(str(slot.worker), "")
            if status == "bye:invocation-end":
                live.append(jid)
            else:
                # no goodbye for this job: crash — replay from its newest
                # checkpoint (per-job ckpt dirs, per-job WAL'd history)
                from repro.checkpoint import store as ckpt

                restored = ckpt.latest_step(
                    os.path.join(
                        st.cfg.run_dir, "ckpt", f"w{slot.worker:03d}"
                    )
                )
                self.respawns.append(
                    {
                        "worker": slot.worker,
                        "job": jid,
                        "exit_code": code,
                        "restored_step": restored or 0,
                        "at_frontier": st.frontier,
                    }
                )
                live.append(jid)
        if live:
            self._spawn(slot)
        else:
            self._teardown_slot_shm(slot)

    def _fold_statuses(self) -> None:
        """Terminal per-(worker, job) transitions arrive through live
        polls — a thread saying ``bye:done``/``bye:evicted`` ends that
        job on that slot while the PROCESS may keep running siblings."""
        for jid in self.job_ids:
            st = self.jobs[jid]
            for w_str, status in st.statuses.items():
                w = int(w_str)
                if w in st.terminal:
                    continue
                if status == "bye:done":
                    st.terminal[w] = "done"
                elif status == "bye:evicted":
                    st.terminal[w] = "evicted"

    # -- control-plane RPC -----------------------------------------------------

    def _rpc(
        self, header: dict, payload: bytes = b"", shard: int = 0,
        tries: int = 8,
    ) -> tuple[dict, bytes]:
        last: Optional[Exception] = None
        for i in range(tries):
            if self._conns[shard] is None:
                self._conns[shard] = protocol.Connection(
                    self.shards[shard].addr, timeout=30.0
                )
            try:
                return self._conns[shard].request(header, payload)
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
                self._conns[shard].close()
                self._conns[shard] = None
                self._reap_brokers()
                time.sleep(0.1 * (i + 1))
        assert last is not None
        raise last

    def _poll_job(self, jid: str) -> None:
        st = self.jobs[jid]
        resp, _ = self._rpc(
            {"t": "poll", "since": st.poll_since, "job": jid}
        )
        for row in resp["rows"]:
            st.history.append(row)
            st.poll_since = row["step"] + 1
            st.frontier = max(st.frontier, row["step"])
            if st.tuner is not None:
                st.tuner.observe(row["step"], row["loss"], row["dur_s"])
            if st.topo_tuner is not None:
                st.topo_tuner.observe(row["dur_s"], row.get("phase"))
        st.evictions = {int(k): v for k, v in resp["evictions"].items()}
        st.statuses = resp["statuses"]

    def _evict_victim(self, jid: str, reason: str, s_delta=None) -> bool:
        """One worker leaves job ``jid`` (its thread flushes and exits;
        the slot keeps running its other jobs)."""
        st = self.jobs[jid]
        victims = st.active_workers()
        if len(victims) <= 1:
            return False
        victim = max(victims)
        resp, _ = self._rpc({"t": "evict", "worker": victim, "job": jid})
        if not resp.get("granted"):
            return False
        for s in range(1, self.n_brokers):
            self._rpc(
                {"t": "evict_apply", "worker": victim,
                 "step": resp["evict_step"], "job": jid},
                shard=s,
            )
        st.evictions[victim] = resp["evict_step"]
        st.scale_events.append(
            {
                "worker": victim,
                "evict_step": resp["evict_step"],
                "at_frontier": st.frontier,
                "s_delta": s_delta,
                "reason": reason,
            }
        )
        return True

    def _fair_share(self) -> None:
        """Arbitrate the shared pool: while the fleet holds more active
        (worker, job) pairs than the budget, the job with the most active
        workers gives one up — each job still walks its own knee curve,
        the budget only caps the sum."""
        budget = self.fleet.pool_budget
        if budget is None:
            return
        for _ in range(len(self.job_ids) * max(len(self.slots), 1)):
            counts = {
                jid: len(self.jobs[jid].active_workers())
                for jid in self.job_ids
                if not self.jobs[jid].complete
            }
            if sum(counts.values()) <= budget:
                return
            for jid in sorted(counts, key=lambda j: (-counts[j], j)):
                if self._evict_victim(jid, "fair-share"):
                    break
            else:
                return  # nobody can shrink further

    # -- main loop -------------------------------------------------------------

    def run(self) -> dict:
        fleet = self.fleet
        os.makedirs(fleet.run_dir, exist_ok=True)
        for jid in self.job_ids:
            os.makedirs(self.jobs[jid].cfg.run_dir, exist_ok=True)
        t0 = time.monotonic()
        shard_stats: dict[str, list] = {jid: [] for jid in self.job_ids}
        try:
            self._start_brokers()
            for slot in self.slots:
                self._spawn(slot)
            deadline = t0 + fleet.deadline_s
            while True:
                time.sleep(fleet.poll_interval_s)
                self._reap_brokers()
                for jid in self.job_ids:
                    self._poll_job(jid)
                self._fold_statuses()

                # per-job fault hooks: a worker SIGKILL hits the PROCESS
                # (all jobs on that slot replay — the honest fleet fault)
                for jid in self.job_ids:
                    st = self.jobs[jid]
                    if (
                        st.cfg.kill_worker_at_step is not None
                        and not st.killed_once
                    ):
                        w, at = st.cfg.kill_worker_at_step
                        slot = self.slots[w]
                        if st.frontier >= at and slot.alive:
                            slot.proc.send_signal(signal.SIGKILL)
                            st.killed_once = True
                    if (
                        st.cfg.kill_broker_at_step is not None
                        and not st.broker_killed_once
                    ):
                        s, at = st.cfg.kill_broker_at_step
                        bs = self.shards[s]
                        if st.frontier >= at and bs.alive:
                            bs.proc.send_signal(signal.SIGKILL)
                            st.broker_killed_once = True

                for slot in self.slots:
                    if slot.proc is not None and slot.proc.poll() is not None:
                        # refresh per-job statuses so just-sent byes are
                        # not misread as crashes
                        for jid in self._hosted_jobs(slot):
                            self._poll_job(jid)
                        self._fold_statuses()
                        self._reap(slot)

                all_alive = all(
                    slot.alive
                    for slot in self.slots if self._hosted_jobs(slot)
                )
                if all_alive:
                    for jid in self.job_ids:
                        st = self.jobs[jid]
                        if st.scripted_fired < len(
                            st.cfg.scripted_evict_steps
                        ):
                            nxt = st.cfg.scripted_evict_steps[
                                st.scripted_fired
                            ]
                            if st.frontier >= nxt:
                                if self._evict_victim(jid, "scripted"):
                                    st.scripted_fired += 1
                        if st.tuner is not None and st.history:
                            decision = st.tuner.decide()
                            if decision.remove_worker:
                                self._evict_victim(
                                    jid, decision.reason, decision.s_delta
                                )
                    self._fair_share()

                if all(self.jobs[j].complete for j in self.job_ids):
                    for jid in self.job_ids:
                        self._poll_job(jid)
                    break
                if time.monotonic() > deadline:
                    status_dump = {
                        j: self.jobs[j].statuses for j in self.job_ids
                    }
                    raise RuntimeError(
                        f"fleet deadline ({fleet.deadline_s}s) exceeded; "
                        f"frontiers="
                        f"{ {j: self.jobs[j].frontier for j in self.job_ids} }"
                        f"; statuses={status_dump}; logs in "
                        f"{os.path.join(fleet.run_dir, 'logs')}"
                    )

            # drain: every job is complete, so each slot's process is
            # exiting on its own — wait for it and bill its real lifetime
            # (terminal transitions fold in from live polls, so the loop
            # breaks BEFORE the procs finish exiting)
            for slot in self.slots:
                if slot.proc is not None:
                    try:
                        slot.proc.wait(timeout=30.0)
                    except subprocess.TimeoutExpired:
                        slot.proc.kill()
                        slot.proc.wait()
                    self.lifetimes.append(
                        time.monotonic() - slot.spawned_at
                    )
                    slot.proc = None
                    self._teardown_slot_shm(slot)

            self._stopping = True
            # one shutdown per (job core, shard); the shard process exits
            # after its LAST core is down, so order jobs inner
            for s in range(self.n_brokers):
                for jid in self.job_ids:
                    resp, _ = self._rpc({"t": "shutdown", "job": jid},
                                        shard=s)
                    shard_stats[jid].append(resp)
        finally:
            for slot in self.slots:
                if slot.alive:
                    slot.proc.kill()
            for conn in self._conns:
                if conn is not None:
                    conn.close()
            self._conns = [None] * self.n_brokers
            for bs in self.shards:
                if bs.proc is not None:
                    bs.proc.terminate()
                    try:
                        bs.proc.wait(timeout=5.0)
                    except subprocess.TimeoutExpired:
                        bs.proc.kill()
            for seg in self._shm_segments.values():
                seg.unlink()
            self._shm_segments.clear()

        wall = time.monotonic() - t0
        return self._result(wall, shard_stats)

    # -- results ---------------------------------------------------------------

    def _job_result(self, jid: str, stats_rows: list) -> dict:
        st = self.jobs[jid]
        hist = st.history
        durs = [r["dur_s"] for r in hist if r.get("dur_s")]
        stats: dict[str, dict[str, int]] = {}
        for resp in stats_rows:
            for kind, row in (resp.get("stats") or {}).items():
                agg = stats.setdefault(
                    kind, {"count": 0, "bytes_in": 0, "bytes_out": 0}
                )
                for k in agg:
                    agg[k] += row.get(k, 0)
        busy_s = sum(
            float(r["dur_s"]) * int(r.get("p_active", 1)) for r in hist
            if r.get("dur_s")
        )
        return {
            "job_id": jid,
            "workload": st.wl.name,
            "run_dir": st.cfg.run_dir,
            "n_workers": st.cfg.n_workers,
            "steps": st.frontier,
            "final_loss": hist[-1]["loss"] if hist else None,
            "final_pool": sum(
                1 for v in st.terminal.values() if v == "done"
            ),
            "history": hist,
            "measured_step_s": (sum(durs) / len(durs)) if durs else None,
            "busy_s": busy_s,
            "wire_bytes_total": sum(r["wire_bytes"] for r in hist),
            "invariant_max_err": max(
                (r["inv_err"] for r in hist), default=0.0
            ),
            "scale_events": st.scale_events,
            "evictions": dict(st.evictions),
            "dup_mismatches": sum(
                int(r.get("dup_mismatches", 0)) for r in stats_rows
            ),
            "broker_stats": stats,
            "broker_stats_per_shard": [
                r.get("stats") or {} for r in stats_rows
            ],
            "broker_update_bytes_per_shard": [
                int(r.get("update_bytes", 0)) for r in stats_rows
            ],
            "topology_recommendation": self._topo_recommendation(jid),
        }

    def _topo_recommendation(self, jid: str) -> Optional[dict]:
        """Observe-only topology advice for one fleet job: the shared pool
        is never re-sharded live, so we measure the running cell and rank
        the neighbouring cells with the cost model instead."""
        st = self.jobs[jid]
        if st.topo_tuner is None:
            return None
        hist = st.history
        steps = max(len(hist), 1)
        bytes_per_step = (
            sum(float(r.get("wire_bytes") or 0.0) for r in hist) / steps
        )
        p = st.cfg.n_workers
        current = dict(st.topo_tuner.cells[0])
        candidates = [current]
        flip_b = dict(current)
        flip_b["n_brokers"] = 2 if int(current["n_brokers"]) == 1 else 1
        candidates.append(flip_b)
        flip_t = dict(current)
        flip_t["transport"] = (
            "shm" if current["transport"] == "tcp" else "tcp"
        )
        candidates.append(flip_t)
        comm = CommModel()
        ranked = sorted(
            (
                {
                    "cell": c,
                    "model_exchange_s": comm.indirect_exchange_time(
                        bytes_per_step, p, n_redis=int(c["n_brokers"])
                    ),
                }
                for c in candidates
            ),
            key=lambda r: r["model_exchange_s"],
        )
        return {
            "mode": "observe-only",
            "note": "fleet pool is shared; no live re-shard per job",
            "measured": st.topo_tuner.cell_stats(0),
            "model_ranked_cells": ranked,
        }

    def _result(self, wall: float, shard_stats: dict[str, list]) -> dict:
        per_job = {
            jid: self._job_result(jid, shard_stats[jid])
            for jid in self.job_ids
        }
        bill = faas_cost(self.lifetimes, wall, n_redis=self.n_brokers)
        rollup = multi_job_rollup(
            self.lifetimes, wall, self.n_brokers,
            {jid: per_job[jid]["busy_s"] for jid in self.job_ids},
        )
        return {
            "jobs": per_job,
            "job_ids": list(self.job_ids),
            "n_brokers": self.n_brokers,
            "transport": self.transport,
            "pool_budget": self.fleet.pool_budget,
            "wall_s": wall,
            "n_invocations": len(self.lifetimes),
            "lifetimes_s": list(self.lifetimes),
            "respawns": self.respawns,
            "n_respawns": len(self.respawns),
            "broker_respawns": self.broker_respawns,
            "dup_mismatches": sum(
                per_job[j]["dup_mismatches"] for j in self.job_ids
            ),
            "bill": {
                "worker_seconds": bill.worker_seconds,
                "wall_seconds": bill.wall_seconds,
                "worker_cost": bill.worker_cost,
                "infra_cost": bill.infra_cost,
                "n_redis": bill.n_redis,
                "total": bill.total,
            },
            "rollup": {
                "per_job": rollup["per_job"],
                "total": rollup["bill"].total,
            },
        }


def run_fleet(fleet: FleetConfig) -> dict:
    """Run N admitted jobs to completion on one pool; returns the fleet
    result dict (``jobs[<id>]`` mirrors the solo supervisor's results)."""
    return FleetScheduler(fleet).run()
