"""repro.runtime — multi-process FaaS-style training substrate (DESIGN.md §9).

The executable form of the MLLess system: stateless invocation-bounded
worker processes exchanging significance-filtered updates *indirectly*
through an in-memory broker over local sockets, supervised by a host-side
controller that drives the scale-in auto-tuner from live telemetry and
meters real per-worker lifetimes at the FaaS billing quantum.

    broker      — update-store shard: pub/sub + WAL + byte accounting
                  (shard 0 = coordinator: minibatch keys, membership,
                  telemetry)
    sharding    — leaf-key -> shard partitioner + sharded tree encoding
    worker      — stateless ISP worker entrypoint (subprocess)
    supervisor  — spawn/evict/respawn controller (workers AND broker
                  shards), billing with n_redis == n_brokers, results
    scheduler   — fleet control plane: N concurrent jobs bin-packed on
                  ONE shared broker/worker pool (§14), merged billing
    protocol    — thin veneer over repro.wire (codec + framing, §10)
    workload    — named deterministic workloads (pmf, lr)
"""

from repro.runtime.scheduler import (  # noqa: F401
    FleetConfig,
    FleetScheduler,
    run_fleet,
)
from repro.runtime.supervisor import (  # noqa: F401
    FaaSJobConfig,
    PMF_QUICKSTART_CFG,
    Supervisor,
    final_params_digest,
    pmf_quickstart_config,
    run_job,
)
from repro.runtime.workload import WORKLOAD_NAMES, build as build_workload  # noqa: F401
