"""Wire protocol of the FaaS runtime — framing, sparse pytree encoding, RPC.

The broker (``runtime.broker``) plays the RabbitMQ/Redis role of MLLess:
workers exchange significance-filtered updates *indirectly* through it, one
short-lived TCP request per message (the stateless-client access pattern of
the paper's workers).  Every message is::

    uint32 header_len | uint32 payload_len | header JSON (utf-8) | payload

The header is a small JSON dict (message type, worker id, step, telemetry);
the payload carries tensors.  The broker never decodes payloads — it is a
dumb byte store with per-message byte accounting, exactly like the KV store
in the paper — only workers encode/decode.

Tensor encoding (``encode_tree`` / ``decode_tree``): per leaf, whichever of

* ``dense``  — raw array bytes, ``size * itemsize``;
* ``sparse`` — int32 flat indices + values, ``nnz * (4 + itemsize)``

is smaller.  Significance-filtered updates are mostly zeros, so the sparse
form realizes the paper's "sparse serialization" wire saving; dense flush
payloads (full replicas on eviction) fall back to the dense form.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any, Optional

import numpy as np

PyTree = Any

_HDR = struct.Struct("<II")
MAX_MSG_BYTES = 1 << 31  # sanity bound on a single message


# -- framing ------------------------------------------------------------------


def send_msg(sock: socket.socket, header: dict, payload: bytes = b"") -> int:
    """Write one framed message; returns total bytes on the wire."""
    raw = json.dumps(header, separators=(",", ":")).encode("utf-8")
    sock.sendall(_HDR.pack(len(raw), len(payload)))
    sock.sendall(raw)
    if payload:
        sock.sendall(payload)
    return _HDR.size + len(raw) + len(payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-message")
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[dict, bytes]:
    """Read one framed message → (header, payload)."""
    hlen, plen = _HDR.unpack(_recv_exact(sock, _HDR.size))
    if hlen > MAX_MSG_BYTES or plen > MAX_MSG_BYTES:
        raise ValueError(f"oversized message header ({hlen}, {plen})")
    header = json.loads(_recv_exact(sock, hlen).decode("utf-8"))
    payload = _recv_exact(sock, plen) if plen else b""
    return header, payload


def request(
    addr: tuple[str, int],
    header: dict,
    payload: bytes = b"",
    timeout: float = 30.0,
) -> tuple[dict, bytes]:
    """One RPC round trip: connect, send, receive, close."""
    with socket.create_connection(addr, timeout=timeout) as sock:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_msg(sock, header, payload)
        return recv_msg(sock)


# -- pytree <-> bytes ---------------------------------------------------------


def tree_keys(tree: PyTree) -> list[str]:
    """Stable '/'-joined path keys — ``checkpoint.store.path_key``'s scheme
    (imported, not copied, so wire metadata and checkpoint manifests can
    never drift apart)."""
    import jax

    from repro.checkpoint.store import path_key

    return [
        path_key(path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def encode_tree(tree: PyTree, sparse: bool = True) -> tuple[list[dict], bytes]:
    """Encode a pytree of arrays → (per-leaf meta list, payload bytes).

    Leaf order is the pytree flatten order, so the decoder only needs a
    structurally-identical template.  ``meta`` per leaf: key, shape, dtype,
    enc ('dense'|'sparse'), nnz, nbytes.
    """
    keys = tree_keys(tree)
    import jax

    leaves = jax.tree_util.tree_leaves(tree)
    meta: list[dict] = []
    parts: list[bytes] = []
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(leaf)
        flat = arr.reshape(-1)
        nz = np.flatnonzero(flat)
        nnz = int(nz.size)
        dense_b = flat.size * arr.itemsize
        sparse_b = nnz * (4 + arr.itemsize)
        if sparse and sparse_b < dense_b:
            idx = nz.astype(np.int32)
            vals = flat[nz]
            blob = idx.tobytes() + np.ascontiguousarray(vals).tobytes()
            enc = "sparse"
        else:
            blob = np.ascontiguousarray(arr).tobytes()
            enc = "dense"
        meta.append(
            {
                "k": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "enc": enc,
                "nnz": nnz,
                "nbytes": len(blob),
            }
        )
        parts.append(blob)
    return meta, b"".join(parts)


def decode_tree(meta: list[dict], payload: bytes, like: PyTree) -> PyTree:
    """Decode bytes back into numpy leaves shaped like ``like``."""
    import jax

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(like_leaves) != len(meta):
        raise ValueError(
            f"template has {len(like_leaves)} leaves, message {len(meta)}"
        )
    out = []
    off = 0
    for m in meta:
        shape = tuple(m["shape"])
        dtype = np.dtype(m["dtype"])
        blob = payload[off : off + m["nbytes"]]
        off += m["nbytes"]
        if m["enc"] == "sparse":
            nnz = m["nnz"]
            idx = np.frombuffer(blob, dtype=np.int32, count=nnz)
            vals = np.frombuffer(blob, dtype=dtype, offset=nnz * 4, count=nnz)
            arr = np.zeros(int(np.prod(shape)) if shape else 1, dtype=dtype)
            arr[idx] = vals
            arr = arr.reshape(shape)
        else:
            arr = np.frombuffer(blob, dtype=dtype).reshape(shape)
        out.append(arr)
    if off != len(payload):
        raise ValueError(f"trailing bytes in payload: {len(payload) - off}")
    return jax.tree_util.tree_unflatten(treedef, out)


def wire_bytes(meta: list[dict]) -> int:
    """Payload bytes a meta list accounts for (the broker's unit of record)."""
    return int(sum(m["nbytes"] for m in meta))


# -- multi-part payloads (pull responses) -------------------------------------


def pack_parts(parts: list[tuple[dict, bytes]]) -> tuple[list[dict], bytes]:
    """Concatenate several (meta-dict, payload) pairs into one message.

    Each part's descriptor gains an ``nbytes`` so the peer can slice the
    concatenated payload back apart.
    """
    descs = []
    blobs = []
    for desc, blob in parts:
        d = dict(desc)
        d["nbytes"] = len(blob)
        descs.append(d)
        blobs.append(blob)
    return descs, b"".join(blobs)


def unpack_parts(descs: list[dict], payload: bytes) -> list[tuple[dict, bytes]]:
    out = []
    off = 0
    for d in descs:
        n = d["nbytes"]
        out.append((d, payload[off : off + n]))
        off += n
    if off != len(payload):
        raise ValueError(f"trailing bytes in multi-part payload: {len(payload) - off}")
    return out
