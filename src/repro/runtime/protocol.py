"""Wire protocol of the FaaS runtime — a thin veneer over ``repro.wire``.

Everything that used to be hand-rolled here (framing, sparse pytree
encoding, byte accounting) now lives in the shared codec layer
(DESIGN.md §10): ``dist.compression``, the simulator's cost model and
this runtime all encode and account through the SAME functions, so
simulated bytes == measured bytes by construction.

What remains runtime-specific is only vocabulary: the broker
(``runtime.broker``) plays the RabbitMQ/Redis role of MLLess; workers
exchange significance-filtered updates *indirectly* through it over
persistent per-worker connections (``repro.wire.framing.Connection``),
one request/response round trip per message.  The broker never decodes
payloads — it is a dumb byte store with per-message byte accounting,
exactly like the KV store in the paper — only workers encode/decode.

Tensor payloads per leaf use the codec registry: ``dense`` raw bytes,
``sparse`` flat-index+value pairs (int64 indices above 2**31 elements),
``bitmap`` packed mask + values, ``auto`` picking the smallest; values
optionally quantized to fp16/bf16 with an fp32 error-feedback residual.
"""

from __future__ import annotations

from repro.wire.codec import (  # noqa: F401
    decode_leaf,
    decode_tree,
    encode_leaf,
    encode_tree,
    encode_tree_parts,
    tree_keys,
    tree_nbytes,
)
from repro.wire.framing import (  # noqa: F401
    MAX_MSG_BYTES,
    TRANSPORTS,
    Connection,
    Transport,
    make_transport,
    pack_parts,
    pipelined,
    recv_msg,
    request,
    send_msg,
    unpack_parts,
)

# the broker's unit of record: payload bytes a meta list accounts for
wire_bytes = tree_nbytes
