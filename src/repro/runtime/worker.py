"""Stateless FaaS worker — one invocation of the MLLess training function.

Spawned as ``python -m repro.runtime.worker --brokers HOST:PORT[,HOST:PORT...]
--worker-id K`` with *no other job state on the command line*: everything
(workload name + config, ISP threshold, step budget, checkpoint root) comes
from the coordinator shard's hello response, and model/optimizer/residual
state is restored from ``checkpoint.store`` — the invocation-bounded,
externally-checkpointed worker model of the paper (§5).

The update store is sharded by leaf key over the N broker shards
(``runtime.sharding``, DESIGN.md §11): the worker holds ONE persistent
``wire.Transport`` channel per shard, publishes each shard its slice of
every update, and pulls each shard's coalesced slice of the peers'
updates — shard 0 (the coordinator) additionally serves minibatch keys,
membership, and telemetry.  The channel is pluggable (DESIGN.md §12):
``--transport tcp`` (default) is the persistent loopback socket;
``--transport shm`` rides the supervisor-allocated shared-memory ring
segments (``--shm-seg`` base name, one segment per shard) — same
framing, same codec, same accounted bytes, no kernel socket copy.

Per step t the worker runs the *paper-faithful replica semantics* of
``core.isp`` (the same math ``core.simulator`` vmaps, here on a real
process):

1. fetch its minibatch key (piggybacked on the previous coordinator pull;
   a ``batch`` round trip only on the first step of an invocation) and
   load the batch locally;
2. ``u_t = optimizer(grads) / P_active(t)`` (averaged-gradient scaling);
3. ``sig, residual' = filter_update(residual + u_t)`` — the ISP
   significance split of ``core.isp``, bit-identical semantics;
4. publish ``sig`` sliced per shard through the shared wire codec
   (``repro.wire``; scheme and optional fp16/bf16 value quantization from
   the job config, any quantization error fed back into the residual);
5. pull the peers' significant updates for t (ISP barrier per shard, ONE
   coalesced round trip per shard on its persistent connection) and apply
   ``x += u_t + sum_peers sig`` — own update in full, peers filtered.
   Each leaf is owned by exactly one shard and peers arrive in ascending
   worker order within a shard, so the per-leaf float32 summation order
   is fixed regardless of the shard count — final params are bit-exact
   across ``n_brokers`` (asserted by ``tests/test_runtime_sharded.py``);
6. on an eviction notice effective at t: publish ``x + residual`` as the
   flush payload (the leaving worker's model-averaging hand-off, sliced
   per shard) and exit; on a flush from a leaving peer: mean-preserving
   reintegration via ``dist.elastic.reintegrate_into``.

Every step reports a per-phase wall-clock breakdown (fetch / compute /
encode / wire / decode) so data-path regressions are attributable
(surfaced in ``BENCH_runtime.json``).

Crash recovery is replay: a respawned worker restores the newest checkpoint
and re-executes forward — every input (minibatch key, peer updates, pool
membership) is served deterministically by the brokers, so replayed
publishes are bit-identical (each shard counts any mismatch) and the pool
never observes a diverging history.  A *broker shard* crash is equally
survivable: the RPC layer retries through the supervisor's respawn window,
and the respawned shard replays its write-ahead log, so any acked publish
is still there and any retried one dup-checks bit-identical.

Exit codes: 0 clean (done / evicted / invocation boundary), 3 broker
abort, 4 broker unreachable, 5 barrier deadline exceeded.
"""

from __future__ import annotations

import argparse
import contextlib
import errno
import os
import threading
import time
from typing import Any, Optional

from repro.runtime.faults import FaultPlan, RetryPolicy, WorkerFaults

PyTree = Any


def _make_rpc(conn, policy_fn):
    """Retrying RPC over one persistent broker-shard connection.

    The retry window (``RetryPolicy``, DESIGN.md §17.4) must comfortably
    cover a supervisor shard respawn (detect + python start + WAL replay
    + bind), which a worker rides out instead of dying into a full
    checkpoint-replay cold start.  ``policy_fn`` is late-bound: the
    job-configured policy only arrives with the hello response.
    """

    def _rpc(header, payload=b"", timeout=None):
        policy: RetryPolicy = policy_fn()
        last: Optional[Exception] = None
        for _ in policy.attempts():
            try:
                return conn.request(
                    header, payload,
                    timeout=timeout if timeout is not None
                    else policy.timeout_s,
                )
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
        raise SystemExit(4) from last

    return _rpc


class _Membership:
    """Worker-side view of the eviction table (worker -> effective step).

    Updated from every shard response; entries are only ever added (the
    coordinator is the single minting authority), so merging views from
    shards with differently-stale tables is safe.
    """

    def __init__(self, n_workers: int):
        self.P = n_workers
        self.evictions: dict[int, int] = {}
        # topology epoch fence (DESIGN.md §16): once the coordinator mints
        # it, every worker exits at loop-top t >= fence so the supervisor
        # can re-shard the store at an invocation boundary
        self.topo_fence: Optional[int] = None

    def update(self, resp: dict) -> None:
        for k, v in (resp.get("evictions") or {}).items():
            self.evictions[int(k)] = int(v)
        if resp.get("topo_fence") is not None:
            self.topo_fence = int(resp["topo_fence"])

    def p_active(self, step: int) -> int:
        return self.P - sum(1 for e in self.evictions.values() if e <= step)

    def my_evict_step(self, worker: int) -> Optional[int]:
        return self.evictions.get(worker)


def run_worker(
    addrs: list[tuple[str, int]],
    worker_id: int,
    transport: str = "tcp",
    shm_seg: Optional[str] = None,
    job_id: Optional[str] = None,
    stop_event: Optional["threading.Event"] = None,
    compute_lock: Optional["threading.Lock"] = None,
    prewarm_gate: Optional[str] = None,
    _ready_cb=None,
) -> int:
    """One worker's life for one job.

    Solo (``job_id is None``) this is the single-job path, byte-identical
    to the pre-fleet build: no ``job`` header on any RPC, no key prefix.
    Under the multi-job control plane (DESIGN.md §14) one *process* runs
    one ``run_worker`` thread per admitted job: ``job_id`` tags every RPC
    (the broker routes it to that job's core) and prefixes every leaf key
    (``sharding.job_namespace``); ``compute_lock`` serializes the compute
    phases so one job's barrier stall is absorbed by another job's
    compute inside the same invocation (the bin-packing claim);
    ``stop_event`` is the shared invocation boundary — the first thread
    to hit its step budget sets it and every sibling winds down at its
    next barrier slice, so the process exits as one billable unit.

    ``prewarm_gate`` is the pre-warmed respawn path: connect, fetch the
    job config with a status-neutral warm hello, build + JIT-warm the
    step functions, signal readiness (``<gate>.ready``), then block until
    the supervisor opens the gate file — only THEN restore the newest
    checkpoint and run, so runtime/XLA init overlaps the tail of the
    previous invocation without ever racing its checkpoints.
    """
    # jax and friends are imported lazily so ``--help`` stays instant — the
    # import cost is the measured FaaS cold-start of each invocation.
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import optim
    from repro.checkpoint import store as ckpt
    from repro.core import isp as isp_lib
    from repro.dist.elastic import reintegrate_into
    from repro.runtime import protocol, sharding
    from repro.runtime import workload as workload_lib

    # ONE persistent channel per broker shard for the whole invocation —
    # the coalesced data path (DESIGN.md §10.3) instead of a TCP connect
    # per message.  conns[0] is the coordinator.  The transport factory
    # (wire.framing.make_transport) is the ONLY transport-aware line.
    # the bootstrap policy covers the hello round trip; the job-configured
    # one (FaaSJobConfig.rpc) replaces it as soon as the hello response
    # carries the job dict — per-worker reseed decorrelates the jitter
    # streams of concurrent retry loops without losing determinism
    rpc_policy = RetryPolicy().reseed(worker_id)

    def _policy() -> RetryPolicy:
        return rpc_policy

    n_shards = len(addrs)
    conns = [
        protocol.make_transport(
            transport,
            addr=a,
            shm_name=f"{shm_seg}s{s}" if shm_seg else None,
            timeout=rpc_policy.timeout_s,
        )
        for s, a in enumerate(addrs)
    ]
    # single-shard round trips (hello/batch/report/bye) go to the
    # coordinator; everything per-shard goes through the pipelined fanout
    rpc0 = _make_rpc(conns[0], _policy)

    def fanout(shard_ids, headers, payloads=None, timeout=None):
        """Pipelined RPC to several shards (send all, then collect all) —
        per-shard latencies overlap instead of summing, which is what
        makes the sharded store cheaper, not dearer, per barrier.  Retries
        whole rounds through a broker-shard respawn window; every op is
        idempotent so a replayed round is safe."""
        policy = _policy()
        payloads = payloads or [b""] * len(shard_ids)
        last: Optional[Exception] = None
        for _ in policy.attempts():
            try:
                return protocol.pipelined(
                    [conns[s] for s in shard_ids],
                    list(zip(headers, payloads)),
                    timeout=timeout if timeout is not None
                    else policy.timeout_s,
                )
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
        raise SystemExit(4) from last

    # fleet mode: tag every RPC with the job id (broker-side core routing)
    # and prefix every leaf key (store/WAL namespace).  Solo mode adds
    # NOTHING — headers and keys stay byte-identical to the pre-fleet
    # build, which is what the wire-guard byte gate pins.
    jtag = {} if job_id is None else {"job": str(job_id)}
    ns = sharding.job_namespace(job_id)
    # a warm hello (prewarm path) fetches the job config without touching
    # the worker's status — the previous invocation still owns it
    hello, _ = rpc0(
        {"t": "hello", "worker": worker_id, **jtag,
         **({"warm": True} if prewarm_gate is not None else {})}
    )
    job = hello["job"]
    members = _Membership(int(job["n_workers"]))
    members.update(hello)
    if job.get("rpc"):
        rpc_policy = RetryPolicy.from_dict(job["rpc"]).reseed(worker_id)
    # chaos plane (runtime/faults.py, DESIGN.md §17): this worker's slice
    # of the job's seeded fault plan — wire delays / stalls / resets,
    # checkpoint write failures, straggler compute delays.  With no plan
    # (the default) nothing installs and every hook stays dormant.
    _plan = FaultPlan.from_spec(job.get("chaos"))
    wfaults = WorkerFaults(_plan, worker_id) if _plan is not None else None
    if wfaults is not None:
        wfaults.install()

    # persistent jit cache under the run dir: later invocations (respawns,
    # invocation boundaries, every worker after the first) load compiled
    # step functions instead of re-paying the ~1 s XLA cold start — the
    # standard warm-container trick for FaaS runtimes (cuts the measured
    # cold-start share of BENCH_runtime.json's step-time mean)
    try:
        jax.config.update(
            "jax_compilation_cache_dir",
            os.path.join(job["run_dir"], "jit_cache"),
        )
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:
        pass  # older jax: cold starts stay, correctness unaffected

    wl = workload_lib.build(job["workload"], job["workload_cfg"])
    optimizer = optim.make(job["optimizer"], job["lr"])
    isp = isp_lib.ISPConfig(
        v=float(job["isp_v"]), decay=bool(job.get("isp_decay", True))
    )
    total_steps = int(job["total_steps"])
    invocation_steps = int(job.get("invocation_steps", 1_000_000))
    checkpoint_every = int(job.get("checkpoint_every", 10))
    pull_deadline_s = float(job.get("pull_deadline_s", 120.0))
    wire_scheme = str(job.get("wire_scheme", "auto"))
    wire_quant = str(job.get("wire_quant", "none"))
    wire_impl = str(job.get("wire_impl", "numpy"))
    # bounded-staleness mode (DESIGN.md §13): under 'ssp' a pull at step t
    # is served exactly the peers' updates of step t - slack - 1, so the
    # worker runs up to slack + 1 steps ahead of the slowest peer instead
    # of barriering every step; 'isp' (default) is unchanged
    consistency = str(job.get("consistency", "isp"))
    slack = int(job.get("slack", 3))
    ckpt_dir = os.path.join(job["run_dir"], "ckpt", f"w{worker_id:03d}")

    params = wl.params0
    opt_state = optimizer.init(params)
    residual = jax.tree.map(jnp.zeros_like, params)

    # the leaf-key -> shard partition: a pure function of the parameter
    # template, the shard count and the (topology-independent) leaf-split
    # threshold, so every worker, the supervisor, and the tests compute
    # the identical assignment (runtime.sharding)
    split_bytes = int(job.get("shard_split_bytes", 0))
    partitioner = str(job.get("partitioner", "greedy"))
    leaf_keys = protocol.tree_keys(params)
    assignment = sharding.tree_assignment(
        params, n_shards, split_bytes=split_bytes, namespace=ns,
        partitioner=partitioner,
    )
    leaves0 = [np.asarray(x) for x in jax.tree_util.tree_leaves(params)]
    treedef0 = jax.tree_util.tree_structure(params)
    # decode accumulators are keyed by the (namespaced) wire keys the
    # shard metas carry — one job can never decode into another's buffers
    leaf_like = {
        ns + k: (leaf.shape, leaf.dtype) for k, leaf in zip(leaf_keys, leaves0)
    }

    start_step = 1
    last_saved = 0

    def restore_latest() -> None:
        """Resume from the newest checkpoint whose content digest
        verifies, falling back generation by generation past corrupt
        ones (DESIGN.md §17.3) — deferred past the prewarm gate: a
        pre-warmed process must not read checkpoints the previous
        invocation is still writing."""
        nonlocal params, opt_state, residual, start_step, last_saved
        latest, tree = ckpt.restore_latest_valid(
            ckpt_dir,
            {"params": params, "opt": opt_state, "residual": residual},
        )
        if latest is not None:
            params, opt_state, residual = (
                tree["params"], tree["opt"], tree["residual"],
            )
            start_step = latest + 1
            last_saved = latest

    def compute(params, opt_state, residual, batch, inv_p, t):
        loss, grads = wl.grad_fn(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        u = jax.tree.map(lambda a: (a * inv_p).astype(a.dtype), updates)
        sig, new_state, masks = isp_lib.filter_update(
            isp, isp_lib.ISPState(residual=residual, step=t), u, params
        )
        res = new_state.residual
        sent = isp_lib.communicated_fraction(masks)
        # conservation witness: sent + residual' - (residual + update), the
        # pool-wide ISP invariant the fault-injection test asserts on
        errs = jax.tree.map(
            lambda s, r2, r0, uu: jnp.max(jnp.abs((s + r2) - (r0 + uu))),
            sig, res, residual, u,
        )
        inv_err = jax.tree.reduce(jnp.maximum, errs)
        return u, sig, res, opt_state, loss, sent, inv_err

    compute = jax.jit(compute)
    apply_visible = jax.jit(
        lambda p, u, peers: jax.tree.map(
            lambda a, b, c: a + b + c.astype(a.dtype), p, u, peers
        )
    )
    reintegrate = jax.jit(reintegrate_into)
    # catch-up merge: peers-only apply (no own update) for the SSP drain
    apply_peers = jax.jit(
        lambda p, peers: jax.tree.map(
            lambda a, c: a + c.astype(a.dtype), p, peers
        )
    )

    def save_ckpt(step_done: int) -> None:
        nonlocal last_saved
        if step_done <= 0 or step_done == last_saved:
            return
        if wfaults is not None and wfaults.ckpt_should_fail(step_done):
            # simulated ENOSPC at the worst moment: after the staged npz
            # is written, before the atomic install — the store's staging
            # contract keeps the partial snapshot invisible
            def _enospc(tmp: str) -> None:
                raise OSError(errno.ENOSPC, "chaos: injected ENOSPC", tmp)

            ckpt.install_write_fault_hook(_enospc)
        try:
            ckpt.save(
                ckpt_dir,
                step_done,
                {"params": params, "opt": opt_state, "residual": residual},
                extra={"worker": worker_id, "next_step": step_done + 1},
            )
        except OSError as e:
            # a failed checkpoint write is survivable: the previous
            # generation stays restorable and replay covers the gap —
            # warn and train on rather than crash the invocation
            print(f"worker {worker_id}: checkpoint save at step "
                  f"{step_done} failed ({e}); continuing on the previous "
                  f"generation", flush=True)
            return
        finally:
            ckpt.clear_write_fault_hook()
        last_saved = step_done

    def bye(reason: str) -> None:
        if wfaults is not None:
            wfaults.uninstall()  # the farewell RPCs run fault-free
        rpc0({"t": "bye", "worker": worker_id, "reason": reason, **jtag})
        for c in conns:
            c.close()

    def pull_all(step: int):
        """One barrier's worth of pipelined coalesced pulls (all shards'
        long polls run server-side concurrently).  Returns (exit_code,
        shard_parts): code is None on success, 3 on broker abort, 5 on
        deadline, 7 when a sibling job thread declared the invocation
        boundary mid-barrier (fleet mode; checked between 2 s poll
        slices, never mid-RPC)."""
        nonlocal key_next
        deadline = time.monotonic() + pull_deadline_s
        shard_parts: list[Optional[tuple[list, bytes]]] = [None] * n_shards
        pending = list(range(n_shards))
        while pending:
            if stop_event is not None and stop_event.is_set():
                return 7, None
            # the 2 s timeout_s is protocol, not retry policy: the server
            # parks the long poll for one slice and answers not-ready, so
            # the client-side attempt bound is the policy's timeout_s
            resps = fanout(
                pending,
                [{"t": "pull", "worker": worker_id, "step": step,
                  "timeout_s": 2.0, **jtag} for _ in pending],
            )
            nxt = []
            for s, (resp, blob) in zip(pending, resps):
                if resp.get("abort"):
                    return 3, None
                members.update(resp)
                if resp.get("ready"):
                    if s == 0:
                        key_next = resp.get("key_next")
                    shard_parts[s] = (resp["parts"], blob)
                else:
                    nxt.append(s)
            pending = nxt
            if pending and time.monotonic() > deadline:
                return 5, None
        return None, shard_parts

    def decode_parts(shard_parts):
        """Peers' update slices + eviction-flush slices back into per-leaf
        accumulators (sharding.LeafBuffers handles split leaves).  Every
        element lives on exactly one shard and peers arrive in ascending
        worker order there, so the per-element float32 summation order is
        fixed for ANY shard count — the replay path and every peer stay
        bit-identical."""
        sums = sharding.LeafBuffers(leaf_like)
        flush_acc: dict[int, sharding.LeafBuffers] = {}
        for descs, blob in shard_parts:
            for desc, m, view in sharding.iter_part_views(descs, blob):
                if desc.get("flush"):
                    q = int(desc["worker"])
                    if q not in flush_acc:  # setdefault would zero-fill
                        flush_acc[q] = sharding.LeafBuffers(leaf_like)
                    flush_acc[q].add_encoded(m, view, impl=wire_impl)
                else:
                    sums.add_encoded(m, view, impl=wire_impl)
        peers_sum = jax.tree_util.tree_unflatten(
            treedef0, [sums[ns + k] for k in leaf_keys]
        )
        flushes = []
        for q, acc in flush_acc.items():
            # a flush is a full replica: reintegrating one with a missing
            # shard slice would silently fold zeros into every survivor
            acc.assert_complete(what=f"flush from worker {q}")
            flushes.append(
                (q, jax.tree_util.tree_unflatten(
                    treedef0, [acc[ns + k] for k in leaf_keys]
                ))
            )
        return peers_sum, flushes

    def apply_flushes(params, flushes, deliver_step: int):
        """Mean-preserving reintegration of leaving peers' replicas, in
        ascending worker order, divided by the pool size just before the
        step the flush is effective at (= delivered at, on both models)."""
        pool_before = members.p_active(deliver_step - 1)
        for _q, flushed in sorted(flushes, key=lambda kv: kv[0]):
            params = reintegrate(
                params, flushed, jnp.asarray(pool_before, jnp.float32)
            )
        return params

    def ssp_drain(params):
        """Catch-up merge: the last regular pull (step T) delivered the
        frontier T - slack - 1, so the retained steps T - slack .. T are
        still undelivered.  Pull them via the same schedule (a pull at td
        delivers td - slack - 1) and apply peers-only, step-ascending —
        the same per-leaf order a peer that saw them live used.  Returns
        (exit_code, params); the caller checkpoints at the sentinel step
        total_steps + 1 afterwards so a respawn never drains twice."""
        for td in range(total_steps + 1, total_steps + slack + 2):
            code, shard_parts = pull_all(td)
            if code is not None:
                return code, params
            peers_sum, flushes = decode_parts(shard_parts)
            params = apply_peers(params, peers_sum)
            if flushes:
                params = apply_flushes(params, flushes, td - slack - 1)
        return None, jax.block_until_ready(params)

    if prewarm_gate is not None:
        # pre-warmed respawn (DESIGN.md §14.5): pay the jax import, XLA
        # backend init and step-function compile NOW, overlapping the
        # tail of the previous invocation, then hold at the gate.  The
        # warm-up runs on the initial template (identical shapes/dtypes
        # to the live state) and discards its outputs — no job state is
        # touched before the gate opens.
        warm_batch = wl.batch(0)
        jax.block_until_ready(
            compute(
                params, opt_state, residual, warm_batch,
                jnp.asarray(1.0, jnp.float32), jnp.asarray(1, jnp.int32),
            )
        )
        zeros_p = jax.tree.map(jnp.zeros_like, params)
        zeros_f = jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), params
        )
        jax.block_until_ready(apply_visible(params, zeros_p, zeros_f))
        if _ready_cb is not None:
            _ready_cb()  # fleet: last job thread signals for the process
        else:
            with open(prewarm_gate + ".ready", "w"):
                pass
        while not os.path.exists(prewarm_gate):
            if stop_event is not None and stop_event.is_set():
                for c in conns:
                    c.close()
                return 0
            time.sleep(0.02)
        # NOW this invocation owns the worker slot: announce for real
        hello2, _ = rpc0({"t": "hello", "worker": worker_id, **jtag})
        members.update(hello2)
    restore_latest()

    t = start_step
    steps_this_invocation = 0
    key_next: Optional[int] = None  # piggybacked by the previous pull
    while True:
        if wfaults is not None:
            wfaults.at_step(t)  # arm this step's wire/checkpoint events
        ev = members.my_evict_step(worker_id)
        # an eviction effective past the job's end is a no-op (the broker
        # refuses to grant those, but guard anyway): finish as 'done'
        if ev is not None and ev <= total_steps and t >= ev:
            # eviction effective at step ev: publish replica + residual (the
            # paper's leaving-worker hand-off, error-feedback form: no
            # accumulated update mass is lost) and end this worker's life.
            # Flushes are full replicas, so the scheme stays 'auto' (dense
            # wins); under --wire-quant the VALUES ride the job's fp16/bf16
            # quantizer — an explicit opt-in to a lossy hand-off that
            # halves the largest single messages in the system (the
            # survivors' mean-preserving pull folds the quantized replica
            # exactly as published, so replay stays bit-identical).  The
            # default 'none' ships the exact dense bytes of the pre-fleet
            # build.
            flushed = jax.tree.map(lambda x, r: x + r, params, residual)
            per_shard, _ = sharding.encode_tree_sharded(
                flushed, assignment, n_shards,
                quant=wire_quant, impl=wire_impl,
                split_bytes=split_bytes, namespace=ns,
            )
            fanout(
                list(range(n_shards)),
                [{"t": "flush", "worker": worker_id, "step": ev,
                  "meta": meta, **jtag} for meta, _ in per_shard],
                [parts for _, parts in per_shard],
            )
            bye("evicted")
            return 0
        # topology epoch fence (DESIGN.md §16): exit cleanly BEFORE
        # starting step fence so the supervisor can migrate the store.
        # After an eviction check on purpose — a granted eviction step is
        # always < fence (the mint guarantees it), so a leaver's flush
        # still lands in a barrier the survivors complete pre-fence.  The
        # checkpoint at fence-1 is durable before the handover starts, so
        # the respawned invocation resumes AT the fence and never replays
        # a pre-fence step against the re-sharded store.
        fence = members.topo_fence
        if fence is not None and t >= fence:
            save_ckpt(t - 1)
            bye("topo-fence")
            return 0
        if t > total_steps:
            if consistency == "ssp" and t == total_steps + 1:
                # drain exactly once: the sentinel checkpoint below makes
                # a post-drain respawn resume at t = total_steps + 2 and
                # skip straight to bye; a mid-drain SIGKILL restores a
                # step <= total_steps, replays (publishes dup-check
                # bit-identical), and drains again from scratch
                code, params = ssp_drain(params)
                if code == 7:
                    # invocation boundary mid-drain: do NOT checkpoint the
                    # partially drained params — the respawn restores a
                    # pre-drain step and re-drains from scratch (pulls are
                    # read-only, so the replay is exact)
                    bye("invocation-end")
                    return 0
                if code is not None:
                    return code
                save_ckpt(total_steps + 1)
            else:
                save_ckpt(t - 1)
            bye("done")
            return 0
        if steps_this_invocation >= invocation_steps or (
            stop_event is not None and stop_event.is_set()
        ):
            if stop_event is not None:
                # first thread to hit its budget declares the boundary for
                # the whole process — sibling jobs wind down at their next
                # barrier slice, and the supervisor respawns ONE invocation
                stop_event.set()
            save_ckpt(t - 1)
            bye("invocation-end")
            return 0

        tp = time.perf_counter
        t0 = tp()
        # -- fetch: minibatch key (piggybacked except on the first step of
        #    an invocation) + local batch materialization
        if key_next is None:
            resp, _ = rpc0(
                {"t": "batch", "worker": worker_id, "step": t, **jtag}
            )
            members.update(resp)
            key = int(resp["key"])
        else:
            key = key_next
        batch = wl.batch(key)
        t_fetch = tp()
        # -- compute: grads -> optimizer -> ISP split (block for honest
        #    phase attribution; jax dispatch is asynchronous).  In fleet
        #    mode the process-wide lock serializes sibling jobs' compute
        #    phases — the invocation models one billable vCPU, and a job
        #    only computes while its siblings are stalled on barriers
        #    (the bin-packing the cost rollup prices)
        p_act = members.p_active(t)
        with compute_lock if compute_lock is not None else (
            contextlib.nullcontext()
        ):
            u, sig, res, opt_state, loss, sent, inv_err = (
                jax.block_until_ready(
                    compute(
                        params,
                        opt_state,
                        residual,
                        batch,
                        jnp.asarray(1.0 / p_act, jnp.float32),
                        jnp.asarray(t, jnp.int32),
                    )
                )
            )
        if wfaults is not None:
            # injected straggler stall (compute_delay events — what the
            # old ad-hoc ``straggler`` knob compiled into), counted into
            # this worker's measured compute phase: the peers' barrier
            # exposure to it is what the consistency models price
            # differently
            delay = wfaults.compute_delay_s(t)
            if delay > 0.0:
                time.sleep(delay)
        t_compute = tp()
        # -- encode: shared wire codec, sliced per shard; quantization
        #    error (if any) is error-feedback — it joins the residual,
        #    conserving update mass
        per_shard, qerr = sharding.encode_tree_sharded(
            sig, assignment, n_shards,
            scheme=wire_scheme, quant=wire_quant,
            with_residual=(wire_quant != "none"),
            split_bytes=split_bytes, namespace=ns, impl=wire_impl,
        )
        if qerr is not None:
            # fence the async residual fold: without it the tree.map's
            # device work smears into whatever phase blocks next, and
            # t_encode under-reports the encode phase it belongs to
            res = jax.block_until_ready(jax.tree.map(
                lambda r, e: r + e.astype(r.dtype), res, qerr
            ))
        total_bytes = sum(
            protocol.wire_bytes(meta) for meta, _ in per_shard
        )
        t_encode = tp()
        # -- wire: one pipelined publish round (every shard gets its slice;
        #    the coordinator's carries the telemetry header), then
        #    pipelined coalesced pulls — all shards' ISP-barrier long
        #    polls run server-side concurrently
        pub_hdrs = []
        for s, (meta, _parts) in enumerate(per_shard):
            hdr = {"t": "publish", "worker": worker_id, "step": t,
                   "meta": meta, **jtag}
            if s == 0:
                hdr.update(
                    loss=float(loss),
                    sent_fraction=float(sent),
                    inv_err=float(inv_err),
                    wire_bytes=total_bytes,
                )
            pub_hdrs.append(hdr)
        for ack, _ in fanout(
            list(range(n_shards)), pub_hdrs,
            [parts for _, parts in per_shard],
        ):
            members.update(ack)

        code, shard_parts = pull_all(t)
        if code == 7:
            # sibling-declared invocation boundary mid-barrier: step t's
            # publish is durable but its pull never completed, and
            # opt_state is already advanced locally — exit WITHOUT a
            # checkpoint, so the respawn restores the last consistent
            # step and replays forward (publishes dup-check bit-identical)
            bye("invocation-end")
            return 0
        if code is not None:
            return code
        t_wire = tp()
        # -- decode: under 'isp' the parts are the peers' step-t slices;
        #    under 'ssp' the frontier step t - slack - 1's (empty while
        #    that is < 1) — same codec, same fixed per-leaf order
        peers_sum, flushes = decode_parts(shard_parts)
        t_decode = tp()
        # -- apply (counted as compute): own update + the delivered peers
        #    + reintegration of any flush effective at the delivered step
        params = apply_visible(params, u, peers_sum)
        if flushes:
            deliver_step = t - slack - 1 if consistency == "ssp" else t
            params = apply_flushes(params, flushes, deliver_step)
        params = jax.block_until_ready(params)
        residual = res
        t_apply = tp()
        rpc0(
            {
                "t": "report", "worker": worker_id, "step": t, **jtag,
                "dur_s": float(t_apply - t0),
                "phase": {
                    "fetch": t_fetch - t0,
                    "compute": (t_compute - t_fetch) + (t_apply - t_decode),
                    "encode": t_encode - t_compute,
                    "wire": t_wire - t_encode,
                    "decode": t_decode - t_wire,
                },
            },
        )
        steps_this_invocation += 1
        if t % checkpoint_every == 0:
            save_ckpt(t)
        t += 1


def run_worker_fleet(
    addrs: list[tuple[str, int]],
    worker_id: int,
    job_ids: list[str],
    transport: str = "tcp",
    shm_seg: Optional[str] = None,
    prewarm_gate: Optional[str] = None,
) -> int:
    """One invocation hosting several jobs: one ``run_worker`` thread per
    job, bin-packed onto one billable process (DESIGN.md §14.3).

    Each thread owns its own per-shard connections (the framed transports
    are not thread-safe) — under shm each job rides its own segment family
    ``<base>g<job>s<shard>``.  A shared stop event makes the invocation
    boundary process-wide, and a shared compute lock serializes the
    compute phases so one job computes exactly while its siblings stall
    on barriers.  A thread that crashes (nonzero code) also declares the
    boundary: sibling jobs wind down cleanly as ``bye:invocation-end``
    while the crashed job's status stays ``running``, which is precisely
    the signal the scheduler's reaper classifies per job.  Exit code is
    the max across threads (0 when every job ended cleanly).
    """
    stop_event = threading.Event()
    compute_lock = threading.Lock()
    codes: dict[str, int] = {}
    ready_lock = threading.Lock()
    ready_n = [0]

    def _ready() -> None:
        # the process is warm only once EVERY job's step functions are:
        # the last thread through signals the supervisor
        with ready_lock:
            ready_n[0] += 1
            if ready_n[0] == len(job_ids) and prewarm_gate is not None:
                with open(prewarm_gate + ".ready", "w"):
                    pass

    def _one(jid: str) -> None:
        seg = f"{shm_seg}g{jid}" if shm_seg else None
        try:
            code = run_worker(
                addrs, worker_id, transport=transport, shm_seg=seg,
                job_id=jid, stop_event=stop_event,
                compute_lock=compute_lock, prewarm_gate=prewarm_gate,
                _ready_cb=_ready if prewarm_gate is not None else None,
            )
        except SystemExit as e:
            code = int(e.code or 0)
        except BaseException:
            code = 1
        codes[jid] = code
        if code != 0:
            stop_event.set()

    threads = [
        threading.Thread(target=_one, args=(jid,), name=f"job-{jid}")
        for jid in job_ids
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return max(codes.values()) if codes else 1


def _parse_addrs(spec: str) -> list[tuple[str, int]]:
    out = []
    for item in spec.split(","):
        host, port = item.strip().rsplit(":", 1)
        out.append((host, int(port)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--brokers", default=None,
                    help="comma-separated HOST:PORT per shard "
                    "(shard 0 = coordinator)")
    ap.add_argument("--broker", default=None,
                    help="single-shard HOST:PORT (legacy alias)")
    ap.add_argument("--worker-id", type=int, required=True)
    ap.add_argument("--transport", default="tcp", choices=("tcp", "shm"),
                    help="update-path channel per shard (wire.framing."
                    "make_transport); shm needs --shm-seg")
    ap.add_argument("--shm-seg", default=None,
                    help="shared-memory segment base name (supervisor-"
                    "allocated); shard s attaches '<base>s<s>' (fleet "
                    "mode: '<base>g<job>s<s>')")
    ap.add_argument("--jobs", default=None,
                    help="comma-separated job ids — run one training "
                    "thread per job, bin-packed onto this one invocation "
                    "(fleet mode; every RPC is job-tagged)")
    ap.add_argument("--prewarm-gate", default=None,
                    help="pre-warmed respawn: JIT-warm, touch "
                    "'<gate>.ready', then hold until the gate file "
                    "appears before restoring state and training")
    args = ap.parse_args()
    spec = args.brokers or args.broker
    if not spec:
        ap.error("--brokers (or --broker) is required")
    if args.transport == "shm" and not args.shm_seg:
        ap.error("--transport shm requires --shm-seg")
    addrs = _parse_addrs(spec)
    if args.jobs:
        raise SystemExit(
            run_worker_fleet(
                addrs,
                args.worker_id,
                [j.strip() for j in args.jobs.split(",") if j.strip()],
                transport=args.transport,
                shm_seg=args.shm_seg,
                prewarm_gate=args.prewarm_gate,
            )
        )
    raise SystemExit(
        run_worker(
            addrs,
            args.worker_id,
            transport=args.transport,
            shm_seg=args.shm_seg,
            prewarm_gate=args.prewarm_gate,
        )
    )


if __name__ == "__main__":
    main()
