r"""Host-side supervisor of the FaaS runtime — the MLLess scheduler (§4.2, §5).

Owns one training job end to end:

* spawns ``n_brokers`` update-broker shard processes (``runtime.broker``;
  the sharded Redis role, shard 0 doubling as the coordinator/messaging
  VM) and ``n_workers`` real OS worker processes (``runtime.worker``),
  each invocation-bounded;
* polls live (loss, step-duration) telemetry off the coordinator and feeds
  the *unmodified* ``core.autotuner.ScaleInAutoTuner`` — scale-in decisions
  are made from measured wall-clock, not modelled time;
* on a decision, evicts the highest-id worker: the coordinator picks the
  effective step (then the supervisor installs it on the other shards via
  ``evict_apply``), the worker flushes its replica through the
  mean-preserving reintegration path (``dist.elastic.reintegrate_into``)
  and exits, and the process's real lifetime stops being billed;
* respawns workers at invocation boundaries and after crashes — a crashed
  worker restores the newest ``checkpoint.store`` snapshot and replays
  forward deterministically (the brokers' update log serves the history);
* respawns a crashed *broker shard* on its original port — the shard
  replays its write-ahead log before binding, so workers' idempotent RPC
  retries land on bit-identical state (``dup_mismatches`` stays 0);
* meters every invocation's measured lifetime through
  ``core.billing.faas_cost`` at the 100 ms quantum with
  ``n_redis == n_brokers``, so a live run emits a real ``FaaSBill`` whose
  infra cost matches the topology it actually ran.

State machine per worker slot::

    spawned -> running -> { done | evicted }          (terminal)
                      \-> invocation-end -> respawn -> running
                      \-> crashed        -> respawn -> running (replay)

The job completes when every slot is terminal; the supervisor then restores
the final checkpoint for a held-out eval and returns history + bill.
"""

from __future__ import annotations

import dataclasses
import json
import os
import random
import shutil
import signal
import subprocess
import sys
import time
from typing import Any, Optional

import warnings

from repro.core.autotuner import (
    AutoTunerConfig,
    ScaleInAutoTuner,
    TopologyTuner,
    TopologyTunerConfig,
)
from repro.core.billing import CommModel, FaaSBill, faas_cost
from repro.runtime import protocol
from repro.runtime import workload as workload_lib
from repro.runtime.faults import (
    SUPERVISOR_KINDS,
    FaultEvent,
    FaultPlan,
    RetryPolicy,
)
from repro.wire import codec as wire_codec

PyTree = Any


@dataclasses.dataclass
class FaaSJobConfig:
    """One serverless training job (all fields JSON-serializable)."""

    run_dir: str
    workload: str = "pmf"
    workload_cfg: dict = dataclasses.field(default_factory=dict)
    n_workers: int = 4
    total_steps: int = 60
    invocation_steps: int = 1_000_000  # steps per function invocation
    checkpoint_every: int = 10
    optimizer: str = "nesterov"
    lr: float = 0.08
    isp_v: float = 0.7
    isp_decay: bool = True
    # pull-barrier consistency (DESIGN.md §13): 'isp' is the full per-step
    # barrier (default, bit-identical to pre-SSP builds); 'ssp' is bounded
    # staleness — a pull at step t blocks only until every update from
    # steps <= t - slack - 1 is stored, and is served exactly that step
    consistency: str = "isp"
    slack: int = 3
    # test/benchmark hook: {"worker": k, "delay_s": d, "every": n} makes
    # worker k sleep d seconds inside every n-th step's compute phase
    straggler: Optional[dict] = None
    # update wire encoding (repro.wire): 'auto'|'dense'|'sparse'|'bitmap',
    # optional 'fp16'|'bf16' value quantization with error-feedback residual
    wire_scheme: str = "auto"
    wire_quant: str = "none"
    # codec backend (repro.wire.codec.IMPLS): 'numpy' is the reference
    # path, 'pallas' the fused encode/decode kernels (bit-identical bytes,
    # kernels/wire_pack.py), 'auto' picks per leaf by size
    wire_impl: str = "numpy"
    # tuned worker launch env (launch/hostperf.py): tcmalloc LD_PRELOAD
    # when present, pinned XLA host flags, thread caps; the applied env is
    # recorded verbatim in the result under 'hostperf'
    hostperf: bool = False
    # update-store shards (paper: Redis instances) — the leaf-key partition
    # of runtime.sharding; bills as n_redis == n_brokers
    n_brokers: int = 1
    # worker<->shard data-path transport (DESIGN.md §12): 'tcp' is the
    # persistent loopback socket, 'shm' the supervisor-allocated
    # shared-memory ring segments (same framing/codec/accounted bytes);
    # the supervisor's own control plane always rides TCP
    transport: str = "tcp"
    shm_ring_bytes: int = 4 << 20  # per-direction ring capacity
    # split leaves denser than this many bytes into flat chunks before
    # shard assignment (0 = off) — topology-independent, so wire bytes
    # stay bit-identical across n_brokers; fixes the degenerate partition
    # of few-leaf models (PMF) at high shard counts
    shard_split_bytes: int = 0
    # pre-warmed invocation respawn (DESIGN.md §14.5): as a slot nears its
    # invocation boundary the supervisor pre-spawns the NEXT invocation
    # with --prewarm-gate — it connects, JIT-warms, and holds before
    # touching any state; at the boundary the supervisor opens the gate
    # instead of paying a cold start inside the barrier stall.  The
    # pre-spawned process's full lifetime is billed (it is a live
    # function), and the measured init overlap lands in the result.
    prewarm: bool = False
    autotune: bool = False
    tuner: Optional[AutoTunerConfig] = None
    # live topology autotuning (DESIGN.md §16): explore-then-commit over
    # {n_brokers, transport, wire_scheme, shard_split_bytes} cells with a
    # WAL-coordinated re-shard between cells.  Requires consistency='isp'
    # (SSP pulls read pre-fence steps) and no prewarm (a gated successor
    # spans the fence).  'partitioner' picks the leaf-key placement:
    # 'greedy' (default, bit-identical to every existing run) or 'ring'
    # (consistent hashing — minimal key movement across re-shards)
    topology_tune: bool = False
    partitioner: str = "greedy"
    topo_explore_steps: int = 6
    # deterministic test hooks
    scripted_evict_steps: tuple[int, ...] = ()
    # scripted topology changes: ((step, {knob: value, ...}), ...) — at
    # frontier >= step, re-shard to the given (partial) topology; the
    # deterministic twin of topology_tune the tests/CI drive
    scripted_retunes: tuple = ()
    kill_worker_at_step: Optional[tuple[int, int]] = None  # (worker, step)
    kill_broker_at_step: Optional[tuple[int, int]] = None  # (shard, step)
    # SIGKILL shard k right after its first migrate_read/migrate_in of a
    # handover — the replay-safety cell of the §16 failure matrix
    kill_broker_during_handover: Optional[int] = None
    # deterministic chaos plane (runtime/faults.py, DESIGN.md §17): an
    # expanded FaultPlan spec ({"seed": ..., "events": [...]}).  The
    # legacy kill_*_at_step / straggler knobs above compile into the same
    # plan, so every fault rides one mechanism
    chaos: Optional[dict] = None
    # unified RPC retry policy (faults.RetryPolicy.to_dict()) applied to
    # the supervisor's and the workers' broker RPCs; None = defaults
    rpc: Optional[dict] = None
    retain_updates: bool = False
    # housekeeping
    poll_interval_s: float = 0.05
    deadline_s: float = 600.0
    pull_deadline_s: float = 120.0
    broker_spawn_timeout_s: float = 30.0
    force_cpu: bool = True
    seed: int = 0

    def compiled_chaos_plan(self) -> Optional[FaultPlan]:
        """The job's effective fault plan: the explicit ``chaos`` spec
        merged with the legacy one-off knobs — ``kill_worker_at_step`` /
        ``kill_broker_at_step`` become supervisor kill events and
        ``straggler`` a repeating ``compute_delay``, so every fault rides
        the one seeded mechanism.  None when the job injects nothing."""
        plan = FaultPlan.from_spec(self.chaos)
        events = list(plan.events) if plan is not None else []
        seed = plan.seed if plan is not None else 0
        if self.kill_worker_at_step is not None:
            w, at = self.kill_worker_at_step
            events.append(FaultEvent("worker_kill", int(at), worker=int(w)))
        if self.kill_broker_at_step is not None:
            s, at = self.kill_broker_at_step
            events.append(FaultEvent("broker_kill", int(at), shard=int(s)))
        if self.straggler is not None:
            st = self.straggler
            events.append(FaultEvent(
                "compute_delay", 0, worker=int(st["worker"]),
                delay_s=float(st["delay_s"]), every=int(st.get("every", 1)),
            ))
        if not events:
            return None
        return FaultPlan(seed=seed, events=tuple(events)).validate()

    def to_dict(self) -> dict:
        """JSON round-trip for the out-of-process supervisor driver
        (``faults.run_job_resilient``); inverse of ``from_dict``."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaaSJobConfig":
        d = dict(d)
        if d.get("tuner"):
            d["tuner"] = AutoTunerConfig(**d["tuner"])
        d["scripted_evict_steps"] = tuple(
            d.get("scripted_evict_steps") or ())
        d["scripted_retunes"] = tuple(
            (int(s), dict(c)) for s, c in (d.get("scripted_retunes") or ()))
        for k in ("kill_worker_at_step", "kill_broker_at_step"):
            if d.get(k) is not None:
                d[k] = tuple(d[k])
        return cls(**d)

    def job_dict(self, n_batches: int) -> dict:
        d = {
            "workload": self.workload,
            "workload_cfg": dict(self.workload_cfg),
            "n_workers": self.n_workers,
            "total_steps": self.total_steps,
            "invocation_steps": self.invocation_steps,
            "checkpoint_every": self.checkpoint_every,
            "optimizer": self.optimizer,
            "lr": self.lr,
            "isp_v": self.isp_v,
            "isp_decay": self.isp_decay,
            "consistency": self.consistency,
            "slack": self.slack,
            "straggler": self.straggler,
            "wire_scheme": self.wire_scheme,
            "wire_quant": self.wire_quant,
            "wire_impl": self.wire_impl,
            "n_brokers": self.n_brokers,
            "transport": self.transport,
            "shard_split_bytes": self.shard_split_bytes,
            "partitioner": self.partitioner,
            "topo_gen": 0,
            "n_batches": n_batches,
            "run_dir": self.run_dir,
            "pull_deadline_s": self.pull_deadline_s,
            "seed": self.seed,
        }
        # keys absent on the default path: a chaos-free job's hello
        # response stays byte-identical to the wire baseline (the
        # 'straggler' key above is retained for the same reason — workers
        # now read its semantics from the compiled plan)
        plan = self.compiled_chaos_plan()
        if plan is not None:
            d["chaos"] = plan.to_spec()
        if self.rpc is not None:
            d["rpc"] = dict(self.rpc)
        return d


def _pid_alive(pid: Optional[int]) -> bool:
    """Liveness via /proc — works for ADOPTED processes (not our children,
    so waitpid is unavailable).  A zombie counts as dead: its exit status
    belongs to init, and it will never publish again."""
    if not pid:
        return False
    try:
        with open(f"/proc/{pid}/stat") as f:
            return f.read().rsplit(")", 1)[-1].split()[0] != "Z"
    except OSError:
        return False


def _terminate_pid(pid: int, grace_s: float = 5.0) -> None:
    """SIGTERM an adopted (non-child) process, escalating to SIGKILL."""
    try:
        os.kill(pid, signal.SIGTERM)
    except OSError:
        return
    deadline = time.monotonic() + grace_s
    while _pid_alive(pid) and time.monotonic() < deadline:
        time.sleep(0.05)
    if _pid_alive(pid):
        try:
            os.kill(pid, signal.SIGKILL)
        except OSError:
            pass


@dataclasses.dataclass
class _Slot:
    """One logical worker (survives respawns; one proc per invocation)."""

    worker: int
    proc: Optional[subprocess.Popen] = None
    # pid re-adopted from a previous supervisor's journal (not our child:
    # liveness comes from /proc, never waitpid)
    adopted_pid: Optional[int] = None
    spawned_at: float = 0.0
    invocations: int = 0
    terminal: Optional[str] = None  # 'done' | 'evicted'
    # first training step of the current invocation (restored + 1) — the
    # prewarm trigger predicts the boundary from it
    inv_start: int = 1
    # shm transport: current per-shard segment names (fresh per
    # invocation — the shm analogue of 'a new connection per invocation')
    shm_segs: list = dataclasses.field(default_factory=list)
    # pre-warmed next invocation (cfg.prewarm): a live process holding at
    # its gate, plus its own segment family and spawn timestamp.  All
    # prewarm timing is MONOTONIC — pre_ready_mono is the supervisor's
    # first sighting of the '.ready' marker (0.0 until seen), so the
    # overlap computation never mixes clock domains (a wall-clock step
    # used to be able to report negative or inflated overlaps)
    pre_proc: Optional[subprocess.Popen] = None
    pre_gate: Optional[str] = None
    pre_spawned_mono: float = 0.0
    pre_ready_mono: float = 0.0
    pre_shm_segs: list = dataclasses.field(default_factory=list)
    # parked at a topology fence: exited cleanly, respawns after handover
    held: bool = False

    @property
    def alive(self) -> bool:
        if self.proc is not None:
            return self.proc.poll() is None
        return _pid_alive(self.adopted_pid)

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else self.adopted_pid


@dataclasses.dataclass
class _BrokerShard:
    """One update-store shard (survives respawns at a pinned port)."""

    shard: int
    proc: Optional[subprocess.Popen] = None
    adopted_pid: Optional[int] = None  # re-adopted from a journal
    addr: Optional[tuple[str, int]] = None
    spawns: int = 0

    @property
    def alive(self) -> bool:
        if self.proc is not None:
            return self.proc.poll() is None
        return _pid_alive(self.adopted_pid)

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else self.adopted_pid


def _sigkill(obj) -> None:
    """SIGKILL a slot's or shard's process, spawned or adopted."""
    if obj.proc is not None:
        if obj.proc.poll() is None:
            obj.proc.send_signal(signal.SIGKILL)
    elif obj.adopted_pid is not None:
        try:
            os.kill(obj.adopted_pid, signal.SIGKILL)
        except OSError:
            pass


class Supervisor:
    def __init__(self, cfg: FaaSJobConfig, *, allow_self_kill: bool = False,
                 resume: bool = False):
        if cfg.transport not in ("tcp", "shm"):
            raise ValueError(
                f"transport must be 'tcp' or 'shm', got {cfg.transport!r}"
            )
        if cfg.consistency not in ("isp", "ssp"):
            raise ValueError(
                f"consistency must be 'isp' or 'ssp', got "
                f"{cfg.consistency!r}"
            )
        if cfg.consistency == "ssp" and cfg.slack < 0:
            raise ValueError(f"slack must be >= 0, got {cfg.slack}")
        if cfg.wire_impl not in wire_codec.IMPLS:
            raise ValueError(
                f"wire_impl must be one of {wire_codec.IMPLS}, got "
                f"{cfg.wire_impl!r}"
            )
        if cfg.partitioner not in ("greedy", "ring"):
            raise ValueError(
                f"partitioner must be 'greedy' or 'ring', got "
                f"{cfg.partitioner!r}"
            )
        retunes = []
        for step, changes in cfg.scripted_retunes or ():
            allowed = {"n_brokers", "transport", "wire_scheme",
                       "shard_split_bytes", "partitioner"}
            bad = set(changes) - allowed
            if bad:
                raise ValueError(f"scripted_retunes: unknown knobs {bad}")
            retunes.append((int(step), dict(changes)))
        if cfg.topology_tune or retunes:
            if cfg.consistency != "isp":
                # an SSP pull at step t is served step t - slack - 1 —
                # post-fence pulls would read pre-fence steps against a
                # re-sharded store; the fence argument is ISP-only
                raise ValueError(
                    "live re-sharding requires consistency='isp'"
                )
            if cfg.prewarm:
                raise ValueError(
                    "topology tuning is incompatible with prewarm: a "
                    "gated successor would span the epoch fence"
                )
        self.plan = cfg.compiled_chaos_plan()
        if self.plan is not None:
            for e in self.plan.events:
                if e.worker is not None and not 0 <= e.worker < cfg.n_workers:
                    raise ValueError(f"fault event targets worker "
                                     f"{e.worker} of {cfg.n_workers}: {e}")
                if e.shard is not None and not 0 <= e.shard < cfg.n_brokers:
                    raise ValueError(f"fault event targets shard "
                                     f"{e.shard} of {cfg.n_brokers}: {e}")
            if any(e.kind == "supervisor_kill" for e in self.plan.events):
                if not allow_self_kill:
                    raise ValueError(
                        "a supervisor_kill fault needs the out-of-process "
                        "driver (faults.run_job_resilient) — an in-process "
                        "supervisor cannot survive killing itself")
                if cfg.topology_tune or cfg.scripted_retunes:
                    raise ValueError(
                        "supervisor_kill is incompatible with live "
                        "re-sharding: handover state is not journaled")
        self._allow_self_kill = allow_self_kill
        self._resume = resume
        # the journal only pays for itself when a successor could read it
        self._journal_enabled = allow_self_kill or resume
        self._resumed = 0
        self._chaos_fired: set[int] = set()
        self._chaos_pending: list[dict] = []
        self.chaos_events: list[dict] = []
        self.rpc_policy = RetryPolicy.from_dict(cfg.rpc)
        self._t_job0 = time.monotonic()
        self.cfg = cfg
        self.wl = workload_lib.build(cfg.workload, cfg.workload_cfg)
        self.shards = [_BrokerShard(shard=s) for s in range(cfg.n_brokers)]
        self._conns: list[Optional[protocol.Connection]] = (
            [None] * cfg.n_brokers
        )
        self.slots = [_Slot(worker=w) for w in range(cfg.n_workers)]
        self.lifetimes: list[float] = []  # one entry per finished invocation
        self.history: list[dict] = []
        self.scale_events: list[dict] = []
        self.respawns: list[dict] = []
        self.broker_respawns: list[dict] = []
        self.cold_start_overlaps: list[dict] = []
        self.evictions: dict[int, int] = {}
        self._frontier = 0
        self._poll_since = 1  # next telemetry step this supervisor hasn't seen
        self._scripted_fired = 0
        self._stopping = False  # end-of-job: shard exits are intentional
        # shm transport: job-unique segment namespace + live segments
        # (the supervisor is the single owner of create/unlink)
        import secrets

        self._shm_token = f"ml{os.getpid():x}{secrets.token_hex(2)}"
        self._shm_segments: dict[str, Any] = {}  # name -> wire.shm.Segment
        self.hostperf_applied: Optional[dict] = None
        self.tuner: Optional[ScaleInAutoTuner] = None
        if cfg.autotune:
            self.tuner = ScaleInAutoTuner(
                cfg.tuner or AutoTunerConfig(), cfg.n_workers
            )
        # live topology state (DESIGN.md §16): the CURRENT knob values —
        # cfg keeps the job's starting point, self.topology what is
        # actually running now
        self.topology = {
            "n_brokers": cfg.n_brokers,
            "transport": cfg.transport,
            "wire_scheme": cfg.wire_scheme,
            "shard_split_bytes": cfg.shard_split_bytes,
            "partitioner": cfg.partitioner,
        }
        self.topo_gen = 0
        self._max_brokers = cfg.n_brokers  # peak shard count → n_redis bill
        self._handover: Optional[dict] = None  # {"fence", "changes"}
        self._retunes_pending = retunes
        self._topo_kill_armed = cfg.kill_broker_during_handover is not None
        self.retired_shard_stats: list[dict] = []
        self.topology_events: list[dict] = []
        self._topo_cell_start = 1  # first step measured for the active cell
        self.topo_tuner: Optional[TopologyTuner] = None
        if cfg.topology_tune and not retunes:
            cur = dict(self.topology)
            flip_brokers = dict(cur,
                                n_brokers=2 if cur["n_brokers"] == 1 else 1)
            flip_transport = dict(
                cur, transport="shm" if cur["transport"] == "tcp" else "tcp"
            )
            self.topo_tuner = TopologyTuner(
                [cur, flip_brokers, flip_transport],
                TopologyTunerConfig(explore_steps=cfg.topo_explore_steps),
                comm=CommModel(),
                n_workers=cfg.n_workers,
            )

    # -- process management ---------------------------------------------------

    def _base_env(self) -> dict:
        import repro

        # repro may be a namespace package (no __init__.py): use __path__
        pkg_dir = (
            os.path.dirname(repro.__file__)
            if getattr(repro, "__file__", None)
            else next(iter(repro.__path__))
        )
        src = os.path.dirname(os.path.abspath(pkg_dir))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        return env

    def _worker_env(self) -> dict:
        env = self._base_env()
        if self.cfg.force_cpu:
            env["JAX_PLATFORMS"] = "cpu"
        if self.cfg.hostperf:
            # tuned launch env (launch/hostperf.py): tcmalloc preload when
            # available, pinned XLA host flags, full thread-cap family; what
            # was actually applied is recorded in self.hostperf_applied
            from repro.launch import hostperf

            env = hostperf.build_env(env, threads=1)
            self.hostperf_applied = hostperf.describe(env)
            return env
        # each worker is the paper's 1 vCPU function: cap per-process math
        # threads so N workers on an M-core host don't thrash each other
        # (oversubscribed intra-op parallelism was the dominant measured
        # compute overhead on small hosts — see BENCH_runtime.json phases)
        env.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false "
                       "intra_op_parallelism_threads=1")
        env.setdefault("OMP_NUM_THREADS", "1")
        env.setdefault("OPENBLAS_NUM_THREADS", "1")
        return env

    # -- broker shard lifecycle -----------------------------------------------

    def _broker_dir(self) -> str:
        return os.path.join(self.cfg.run_dir, "broker")

    def _spawn_broker(self, bs: _BrokerShard) -> None:
        """Spawn (or respawn) one shard process and wait until it listens.

        First spawn binds an ephemeral port; respawns pin the original port
        so the workers' persistent connections reconnect unchanged.  The
        port file doubles as the readiness signal — the shard writes it
        only after any WAL replay completed and the socket is bound.
        """
        bdir = self._broker_dir()
        os.makedirs(bdir, exist_ok=True)
        logdir = os.path.join(self.cfg.run_dir, "logs")
        os.makedirs(logdir, exist_ok=True)
        port_file = os.path.join(bdir, f"shard{bs.shard:02d}.port")
        if os.path.exists(port_file):
            os.unlink(port_file)
        wal_path = os.path.join(bdir, f"shard{bs.shard:02d}.wal")
        if bs.spawns == 0 and os.path.exists(wal_path):
            # a reused run_dir must not replay the PREVIOUS job's log into
            # a fresh one; only respawns within this job replay the WAL
            os.unlink(wal_path)
        log = open(
            os.path.join(
                logdir, f"broker{bs.shard:02d}.spawn{bs.spawns:02d}.log"
            ),
            "wb",
        )
        bs.adopted_pid = None
        bs.proc = subprocess.Popen(
            [
                sys.executable,
                "-m",
                "repro.runtime.broker",
                "--config", os.path.join(bdir, "job.json"),
                "--shard-id", str(bs.shard),
                "--n-shards", str(len(self.shards)),
                "--port", str(bs.addr[1] if bs.addr else 0),
                "--wal", wal_path,
                "--port-file", port_file,
            ],
            stdout=log,
            stderr=subprocess.STDOUT,
            env=self._base_env(),
        )
        log.close()
        bs.spawns += 1
        deadline = time.monotonic() + self.cfg.broker_spawn_timeout_s
        while not os.path.exists(port_file):
            if bs.proc.poll() is not None:
                raise RuntimeError(
                    f"broker shard {bs.shard} exited during spawn "
                    f"(code {bs.proc.returncode}); logs in {logdir}"
                )
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"broker shard {bs.shard} did not listen within "
                    f"{self.cfg.broker_spawn_timeout_s}s"
                )
            time.sleep(0.01)
        with open(port_file) as f:
            host, port = f.read().strip().rsplit(":", 1)
        bs.addr = (host, int(port))

    def _start_brokers(self) -> None:
        bdir = self._broker_dir()
        os.makedirs(bdir, exist_ok=True)
        with open(os.path.join(bdir, "job.json"), "w") as f:
            json.dump(self.cfg.job_dict(self.wl.n_batches), f, indent=1)
        for bs in self.shards:
            self._spawn_broker(bs)

    def _reap_brokers(self) -> None:
        """Respawn any shard that died without being asked to — the WAL
        replay restores its store; workers ride the gap on RPC retries."""
        if self._stopping:
            # shutdown phase: shards exit on purpose after acking their
            # shutdown RPC — respawning one here (e.g. from a _rpc retry
            # whose response was lost) would hand back a fresh process
            # with empty socket stats and a phantom respawn entry
            return
        for bs in self.shards:
            exited = (
                bs.proc.poll() is not None if bs.proc is not None
                else bs.adopted_pid is not None
                and not _pid_alive(bs.adopted_pid)
            )
            if exited:
                self.broker_respawns.append(
                    {
                        "shard": bs.shard,
                        "exit_code": (
                            bs.proc.returncode if bs.proc is not None
                            else None  # adopted: init reaped the status
                        ),
                        "at_frontier": self._frontier,
                    }
                )
                # drop the stale client connection before the port rebinds
                if self._conns[bs.shard] is not None:
                    self._conns[bs.shard].close()
                    self._conns[bs.shard] = None
                self._spawn_broker(bs)
                if self.topology["transport"] == "shm":
                    # the shard's shm serving threads died with it: hand
                    # it every live worker's segment again (each re-serve
                    # resets that ring pair and bumps its generation, so
                    # in-flight workers replay through the same retry
                    # window TCP reconnects use)
                    self._reserve_shard_shm(bs)

    # -- shared-memory segment lifecycle --------------------------------------
    #
    # The supervisor is the single owner of segment create/unlink (workers
    # and brokers only ever attach): one segment per (worker, shard),
    # recreated FRESH for every worker invocation — the shm analogue of
    # 'a new connection per invocation', which is what makes respawn after
    # a SIGKILL race-free (a dying invocation's half-written rings are
    # never reused; its broker-side threads exit on client-death
    # detection and the supervisor unlinks the memory).

    def _teardown_worker_shm(self, slot: _Slot) -> None:
        from repro.wire import shm

        for name in slot.shm_segs:
            seg = self._shm_segments.pop(name, None)
            if seg is not None:
                seg.unlink()
            else:  # pragma: no cover - belt and braces
                shm.Segment.unlink_by_name(name)
        slot.shm_segs = []

    def _setup_worker_shm(self, slot: _Slot) -> str:
        """(Re)allocate fresh segments for this slot's next invocation and
        hand them to every shard to serve; returns the worker's segment
        base name (shard s attaches '<base>s<s>')."""
        from repro.wire import shm

        self._teardown_worker_shm(slot)
        base = f"{self._shm_token}w{slot.worker}i{slot.invocations}"
        names = [f"{base}s{s}" for s in range(len(self.shards))]
        for name in names:
            self._shm_segments[name] = shm.Segment.create(
                name, ring_bytes=self.cfg.shm_ring_bytes
            )
        for s, name in enumerate(names):
            resp, _ = self._rpc({"t": "shm_serve", "seg": name}, shard=s)
            if not resp.get("ok"):  # pragma: no cover - defensive
                raise RuntimeError(f"shard {s} refused shm_serve: {resp}")
        slot.shm_segs = names
        return base

    def _reserve_shard_shm(self, bs: "_BrokerShard") -> None:
        """After a broker-shard respawn: hand the fresh process every live
        worker's segment for this shard again (its serving threads died
        with it).  Direct one-shot RPCs to the just-bound port — this is
        called from inside ``_rpc``'s retry path, so it must not recurse
        into ``_rpc`` itself."""
        for slot in self.slots:
            if slot.terminal is not None or not slot.shm_segs:
                continue
            name = slot.shm_segs[bs.shard]
            for attempt in range(3):
                try:
                    protocol.request(
                        bs.addr, {"t": "shm_serve", "seg": name},
                        timeout=10.0,
                    )
                    break
                except (ConnectionError, OSError, TimeoutError):
                    if attempt == 2:
                        # workers ride it out: their shm connect wait +
                        # RPC retries outlast the next reap cycle
                        break
                    time.sleep(0.2)

    # -- worker lifecycle -----------------------------------------------------

    def _spawn(self, slot: _Slot) -> None:
        logdir = os.path.join(self.cfg.run_dir, "logs")
        os.makedirs(logdir, exist_ok=True)
        log = open(
            os.path.join(
                logdir, f"w{slot.worker:03d}.inv{slot.invocations:03d}.log"
            ),
            "wb",
        )
        brokers = ",".join(f"{h}:{p}" for h, p in
                           (bs.addr for bs in self.shards))
        cmd = [
            sys.executable,
            "-m",
            "repro.runtime.worker",
            "--brokers",
            brokers,
            "--worker-id",
            str(slot.worker),
        ]
        if self.topology["transport"] == "shm":
            cmd += [
                "--transport", "shm",
                "--shm-seg", self._setup_worker_shm(slot),
            ]
        slot.adopted_pid = None
        slot.proc = subprocess.Popen(
            cmd,
            stdout=log,
            stderr=subprocess.STDOUT,
            env=self._worker_env(),
        )
        log.close()
        slot.spawned_at = time.monotonic()
        slot.invocations += 1
        slot.inv_start = self._restored_step(slot) + 1

    def _restored_step(self, slot: _Slot) -> int:
        from repro.checkpoint import store as ckpt

        return ckpt.latest_step(
            os.path.join(self.cfg.run_dir, "ckpt", f"w{slot.worker:03d}")
        ) or 0

    # -- pre-warmed respawn (DESIGN.md §14.5) ----------------------------------

    def _setup_prewarm_shm(self, slot: _Slot) -> str:
        """Fresh segments for the NEXT invocation, created alongside the
        current invocation's live ones (never torn down here)."""
        from repro.wire import shm

        base = f"{self._shm_token}w{slot.worker}i{slot.invocations}"
        names = [f"{base}s{s}" for s in range(len(self.shards))]
        for name in names:
            self._shm_segments[name] = shm.Segment.create(
                name, ring_bytes=self.cfg.shm_ring_bytes
            )
        for s, name in enumerate(names):
            resp, _ = self._rpc({"t": "shm_serve", "seg": name}, shard=s)
            if not resp.get("ok"):  # pragma: no cover - defensive
                raise RuntimeError(f"shard {s} refused shm_serve: {resp}")
        slot.pre_shm_segs = names
        return base

    def _prespawn(self, slot: _Slot) -> None:
        """Spawn the slot's next invocation gated: it imports, connects,
        JIT-warms and then holds at ``pre_gate`` — runtime init runs
        under the tail of the current invocation instead of inside the
        respawn stall."""
        logdir = os.path.join(self.cfg.run_dir, "logs")
        gatedir = os.path.join(self.cfg.run_dir, "gate")
        os.makedirs(logdir, exist_ok=True)
        os.makedirs(gatedir, exist_ok=True)
        gate = os.path.join(
            gatedir, f"w{slot.worker:03d}.inv{slot.invocations:03d}.gate"
        )
        for p in (gate, gate + ".ready"):
            if os.path.exists(p):  # pragma: no cover - stale reuse
                os.unlink(p)
        log = open(
            os.path.join(
                logdir,
                f"w{slot.worker:03d}.inv{slot.invocations:03d}.pre.log",
            ),
            "wb",
        )
        brokers = ",".join(f"{h}:{p}" for h, p in
                           (bs.addr for bs in self.shards))
        cmd = [
            sys.executable,
            "-m",
            "repro.runtime.worker",
            "--brokers", brokers,
            "--worker-id", str(slot.worker),
            "--prewarm-gate", gate,
        ]
        if self.topology["transport"] == "shm":
            cmd += ["--transport", "shm",
                    "--shm-seg", self._setup_prewarm_shm(slot)]
        slot.pre_proc = subprocess.Popen(
            cmd,
            stdout=log,
            stderr=subprocess.STDOUT,
            env=self._worker_env(),
        )
        log.close()
        slot.pre_gate = gate
        slot.pre_spawned_mono = time.monotonic()
        slot.pre_ready_mono = 0.0

    def _scan_prewarm_ready(self) -> None:
        """Stamp the first MONOTONIC sighting of each pre-warming slot's
        '.ready' marker — the supervisor's own clock, so the overlap
        computation never reads a file mtime from the wall-clock domain
        (which can step and report negative/inflated overlaps)."""
        for slot in self.slots:
            if (
                slot.pre_proc is not None
                and slot.pre_gate is not None
                and slot.pre_ready_mono == 0.0
                and os.path.exists(slot.pre_gate + ".ready")
            ):
                slot.pre_ready_mono = time.monotonic()

    def _promote_prewarmed(self, slot: _Slot) -> None:
        """The current invocation ended and a pre-warmed successor is
        holding at its gate: open the gate and make it THE invocation.
        Records the measured init overlap — the cold-start seconds the
        barrier never saw."""
        self._scan_prewarm_ready()
        now_mono = time.monotonic()
        warm = slot.pre_ready_mono > 0.0
        # overlapped cold-start seconds: init time the successor spent
        # under the previous invocation — up to the ready sighting when it
        # finished warming in time, else everything it got so far (it is
        # still warming, but those seconds were still hidden).  Pure
        # monotonic delta; a negative value can only mean a bookkeeping
        # bug, so clamp loudly rather than record garbage.
        end = slot.pre_ready_mono if warm else now_mono
        overlap = end - slot.pre_spawned_mono
        if overlap < 0.0:  # pragma: no cover - defensive
            warnings.warn(
                f"negative prewarm overlap ({overlap:.3f}s) for worker "
                f"{slot.worker}; clamping to 0",
                stacklevel=2,
            )
            overlap = 0.0
        self.cold_start_overlaps.append(
            {
                "worker": slot.worker,
                "invocation": slot.invocations,
                "overlap_s": overlap,
                "ready_at_promotion": warm,
            }
        )
        # open the gate (atomic create): the held process restores the
        # newest checkpoint — written by the invocation that just exited —
        # and starts training
        tmp = slot.pre_gate + ".tmp"
        with open(tmp, "w"):
            pass
        os.replace(tmp, slot.pre_gate)
        # the old invocation's segments die with it; the promoted one
        # already owns a served family
        self._teardown_worker_shm(slot)
        slot.shm_segs, slot.pre_shm_segs = slot.pre_shm_segs, []
        slot.proc = slot.pre_proc
        slot.spawned_at = slot.pre_spawned_mono
        slot.pre_proc = None
        slot.pre_gate = None
        slot.invocations += 1
        slot.inv_start = self._restored_step(slot) + 1

    def _abort_prewarmed(self, slot: _Slot) -> None:
        """The slot went terminal with a successor still holding at its
        gate: kill it and bill its (real, live-function) lifetime."""
        if slot.pre_proc is None:
            return
        if slot.pre_proc.poll() is None:
            slot.pre_proc.kill()
            slot.pre_proc.wait()
        self.lifetimes.append(time.monotonic() - slot.pre_spawned_mono)
        slot.pre_proc = None
        slot.pre_gate = None
        for name in slot.pre_shm_segs:
            seg = self._shm_segments.pop(name, None)
            if seg is not None:
                seg.unlink()
        slot.pre_shm_segs = []

    def _maybe_prespawn(self) -> None:
        """Fire a gated successor for every slot within one step of its
        invocation boundary (predicted from the invocation's start step
        and budget) that doesn't have one yet."""
        if not self.cfg.prewarm or self._handover is not None:
            return
        if self.cfg.invocation_steps > self.cfg.total_steps:
            return  # single-invocation job: no boundary to warm for
        for slot in self.slots:
            if (
                slot.terminal is not None
                or not slot.alive
                or slot.pre_proc is not None
                or slot.worker in self.evictions
            ):
                continue
            boundary = slot.inv_start + self.cfg.invocation_steps - 1
            if boundary > self.cfg.total_steps:
                continue  # final invocation: nothing follows it
            if self._frontier >= boundary - 1:
                self._prespawn(slot)

    def _reap(self, slot: _Slot, statuses: dict) -> None:
        """Classify an exited process and respawn when the slot lives on."""
        assert slot.proc is not None or slot.adopted_pid is not None
        # an adopted process was reaped by init: no exit code to read
        code = slot.proc.returncode if slot.proc is not None else None
        self.lifetimes.append(time.monotonic() - slot.spawned_at)
        status = statuses.get(str(slot.worker), "")
        slot.proc = None
        slot.adopted_pid = None
        if status == "bye:done":
            slot.terminal = "done"
            self._teardown_worker_shm(slot)
            self._abort_prewarmed(slot)
        elif status == "bye:evicted":
            slot.terminal = "evicted"
            self._teardown_worker_shm(slot)
            self._abort_prewarmed(slot)
        elif status == "bye:topo-fence":
            # parked at the topology epoch fence (DESIGN.md §16): its
            # fence-1 checkpoint is durable; the slot respawns only after
            # the handover migrated the store (its segments die now — a
            # transport switch may mean the next invocation isn't shm)
            self._teardown_worker_shm(slot)
            self._abort_prewarmed(slot)
            slot.held = True
        elif status == "bye:invocation-end":
            # next invocation of the same function — pre-warmed and held
            # at its gate when cfg.prewarm got it ready in time
            if slot.pre_proc is not None and slot.pre_proc.poll() is None:
                self._promote_prewarmed(slot)
            else:
                self._abort_prewarmed(slot)
                self._spawn(slot)
        else:
            # no goodbye: the process died (e.g. SIGKILL) — respawn; the
            # worker restores its newest checkpoint and replays forward.
            # A held pre-warmed successor is an equally valid respawn: it
            # restores the newest checkpoint only after its gate opens.
            restored = self._restored_step(slot)
            self.respawns.append(
                {
                    "worker": slot.worker,
                    "exit_code": code,
                    "restored_step": restored,
                    "at_frontier": self._frontier,
                }
            )
            if slot.pre_proc is not None and slot.pre_proc.poll() is None:
                self._promote_prewarmed(slot)
            else:
                self._abort_prewarmed(slot)
                self._spawn(slot)

    # -- broker RPC -----------------------------------------------------------

    def _rpc(
        self, header: dict, payload: bytes = b"", shard: int = 0,
        tries: Optional[int] = None,
    ) -> tuple[dict, bytes]:
        """Retrying RPC to one shard — must survive a shard respawn window
        (the connection reconnects to the pinned port once it rebinds).
        Attempt timeout, count, backoff and deadline all come from the
        job's ``RetryPolicy`` (``cfg.rpc``; ``tries`` overrides the count
        for callers with their own bound)."""
        policy = (
            self.rpc_policy if tries is None
            else dataclasses.replace(self.rpc_policy, tries=tries)
        )
        last: Optional[Exception] = None
        for _ in policy.attempts():
            if self._conns[shard] is None:
                self._conns[shard] = protocol.Connection(
                    self.shards[shard].addr, timeout=policy.timeout_s
                )
            try:
                return self._conns[shard].request(header, payload)
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
                self._conns[shard].close()
                self._conns[shard] = None
                self._reap_brokers()  # a dead shard blocks every retry
        assert last is not None
        raise last

    def _poll(self) -> dict:
        # supervisor-owned cursor keeps the poll idempotent: if the
        # connection retries a poll whose response was lost, the broker
        # re-serves the same rows instead of dropping them
        resp, _ = self._rpc({"t": "poll", "since": self._poll_since})
        for row in resp["rows"]:
            self.history.append(row)
            self._poll_since = row["step"] + 1
            self._frontier = max(self._frontier, row["step"])
            if self.tuner is not None:
                self.tuner.observe(row["step"], row["loss"], row["dur_s"])
            if (
                self.topo_tuner is not None
                and row["step"] >= self._topo_cell_start
            ):
                # steps before the cell boundary belong to the previous
                # topology — feeding them would pollute the new cell's p50
                self.topo_tuner.observe(row["dur_s"], row.get("phase"))
        self.evictions = {int(k): v for k, v in resp["evictions"].items()}
        return resp

    def _evict_victim(self, reason: str, s_delta=None) -> bool:
        """Highest-id live, non-terminal, non-evicted worker leaves."""
        victims = [
            s.worker
            for s in self.slots
            if s.terminal is None and s.worker not in self.evictions
        ]
        if len(victims) <= 1:
            return False
        victim = max(victims)
        resp, _ = self._rpc({"t": "evict", "worker": victim})
        if not resp.get("granted"):
            return False  # e.g. past-end: the job ends before it could land
        # install the coordinator-granted (worker, step) on every other
        # shard: until the sync lands a stale shard only *blocks* its
        # step-e barrier (it still expects the leaver's publish), so the
        # window is safe — see DESIGN.md §11 failure matrix
        for s in range(1, len(self.shards)):
            self._rpc(
                {"t": "evict_apply", "worker": victim,
                 "step": resp["evict_step"]},
                shard=s,
            )
        # record immediately — a second decision in this same poll iteration
        # must not re-target the worker we just evicted
        self.evictions[victim] = resp["evict_step"]
        self.scale_events.append(
            {
                "worker": victim,
                "evict_step": resp["evict_step"],
                "at_frontier": self._frontier,
                "s_delta": s_delta,
                "reason": reason,
            }
        )
        return True

    # -- live topology handover (DESIGN.md §16) --------------------------------

    def _initiate_retune(self, changes: dict) -> bool:
        """Ask the coordinator for an epoch fence toward ``changes``.
        Returns True when the request is settled (handover pending, or a
        no-op because nothing actually changes), False when the
        coordinator refused (past-end) — a permanent refusal."""
        diff = {
            k: v for k, v in changes.items() if self.topology.get(k) != v
        }
        if not diff:
            self.topology_events.append(
                {"gen": self.topo_gen, "fence": None, "changes": {},
                 "noop": True, "at_frontier": self._frontier}
            )
            return True
        resp, _ = self._rpc({"t": "topo_begin"})
        if not resp.get("granted"):
            self.topology_events.append(
                {"gen": self.topo_gen, "fence": None, "changes": diff,
                 "refused": resp.get("reason", "?"),
                 "at_frontier": self._frontier}
            )
            return False
        self._handover = {"fence": int(resp["fence"]), "changes": diff}
        return True

    def _complete_handover(self) -> None:
        """Every live worker is parked at the fence with a durable
        fence-1 checkpoint: migrate the moved identities, commit the new
        topology, respawn.  Every mutation rides the shards' WALs and the
        idempotent migrate ops, so a SIGKILL on either side of any
        migration replays to bit-identical state."""
        from repro.runtime import sharding

        hand = self._handover
        assert hand is not None
        fence = hand["fence"]
        # drain the final pre-fence telemetry so the tuner's cell
        # accounting closes at the boundary
        self._poll()

        old = dict(self.topology)
        new = dict(old, **hand["changes"])
        old_n, new_n = len(self.shards), int(new["n_brokers"])
        params0 = self.wl.params0
        a_old = sharding.tree_assignment(
            params0, old_n, split_bytes=int(old["shard_split_bytes"]),
            partitioner=old["partitioner"],
        )
        a_new = sharding.tree_assignment(
            params0, new_n, split_bytes=int(new["shard_split_bytes"]),
            partitioner=new["partitioner"],
        )
        owner_new = sharding.offset_owner(
            params0, int(new["shard_split_bytes"]), a_new
        )
        # stored pre-fence entries are chunked at the OLD threshold: each
        # old chunk moves to the new owner of the new chunk containing its
        # start offset — totality is preserved, which is all pre-fence
        # data needs (post-fence pulls never read pre-fence steps)
        subleaves = sharding.tree_subleaves(
            params0, int(old["shard_split_bytes"])
        )
        moves: dict[tuple[int, int], list] = {}
        for leaf_key, subkey, off, _n in subleaves:
            src = a_old[subkey]
            dest = owner_new(leaf_key, off)
            if src != dest:
                moves.setdefault((src, dest), []).append([leaf_key, off])
        gen = self.topo_gen + 1

        # rewrite job.json FIRST: every shard (re)spawned from here reads
        # the new topology.  Old shards re-reading it mid-migration is
        # harmless — their store rebuilds from the WAL and the migrate ops
        # never consult the config
        job = self.cfg.job_dict(self.wl.n_batches)
        job.update(
            n_brokers=new_n,
            transport=new["transport"],
            wire_scheme=new["wire_scheme"],
            shard_split_bytes=new["shard_split_bytes"],
            partitioner=new["partitioner"],
            topo_gen=gen,
        )
        with open(os.path.join(self._broker_dir(), "job.json"), "w") as f:
            json.dump(job, f, indent=1)

        if new_n > old_n:
            # grow: append ALL new shard slots first (len(self.shards) is
            # the --n-shards every spawn reads), then spawn + install the
            # eviction table so the new barriers agree on membership
            for s in range(old_n, new_n):
                self.shards.append(_BrokerShard(shard=s))
                self._conns.append(None)
            for s in range(old_n, new_n):
                self._spawn_broker(self.shards[s])
                for w, estep in self.evictions.items():
                    self._rpc({"t": "evict_apply", "worker": w,
                               "step": estep}, shard=s)

        kill_shard = self.cfg.kill_broker_during_handover
        moved_subkeys = 0
        for (src, dest) in sorted(moves):
            moved = moves[(src, dest)]
            moved_subkeys += len(moved)
            resp, blob = self._rpc(
                {"t": "migrate_read", "moved": moved}, shard=src
            )
            if kill_shard is not None and self._topo_kill_armed:
                # §16 failure-matrix cell: SIGKILL a shard mid-handover;
                # _rpc retries ride the respawn+WAL-replay and the
                # idempotent migrate ops land bit-identical state
                self._topo_kill_armed = False
                bs = self.shards[kill_shard]
                if bs.alive:
                    bs.proc.send_signal(signal.SIGKILL)
            self._rpc(
                {"t": "migrate_in", "gen": gen, "src": src,
                 "parts": resp["parts"]},
                payload=blob, shard=dest,
            )
        # drop only after EVERY destination acked its migrate_in: a source
        # with several destinations must not lose unread slices
        for src in sorted({s for s, _ in moves}):
            moved = [
                m for (s, _d), ms in moves.items() if s == src for m in ms
            ]
            self._rpc({"t": "migrate_drop", "moved": moved}, shard=src)

        # commit on every shard of the NEW topology (clears the fence on
        # the coordinator; updates the job dict respawned workers hello
        # into); retired shards get a shutdown instead
        for s in range(new_n):
            self._rpc(
                {"t": "topo_commit", "gen": gen, "n_shards": new_n,
                 "n_brokers": new_n, "transport": new["transport"],
                 "wire_scheme": new["wire_scheme"],
                 "shard_split_bytes": new["shard_split_bytes"],
                 "partitioner": new["partitioner"]},
                shard=s,
            )
        if new_n < old_n:
            # shrink: the move map emptied shards >= new_n; retire them
            # synchronously (no _rpc between terminate and truncation, or
            # a retry's _reap_brokers would respawn a retired shard)
            for s in range(new_n, old_n):
                bs = self.shards[s]
                try:
                    r, _ = self._rpc({"t": "shutdown"}, shard=s)
                    self.retired_shard_stats.append(r)
                except Exception:  # pragma: no cover - defensive
                    self.retired_shard_stats.append({"shard_id": s})
                if self._conns[s] is not None:
                    self._conns[s].close()
                if bs.proc is not None:
                    bs.proc.terminate()
                    try:
                        bs.proc.wait(timeout=5.0)
                    except subprocess.TimeoutExpired:
                        bs.proc.kill()
            del self.shards[new_n:]
            del self._conns[new_n:]

        self.topology = new
        self.topo_gen = gen
        self._max_brokers = max(self._max_brokers, new_n)
        self._topo_cell_start = fence
        self.topology_events.append(
            {
                "gen": gen,
                "fence": fence,
                "changes": hand["changes"],
                "moved_subkeys": moved_subkeys,
                "total_subkeys": len(subleaves),
                "at_frontier": self._frontier,
            }
        )
        self._handover = None
        if self.topo_tuner is not None:
            # observations from here on belong to the next cell (the
            # entry _poll above closed the old cell's rows)
            self.topo_tuner.cell_started()
        for slot in self.slots:
            if slot.held:
                slot.held = False
                self._spawn(slot)

    # -- chaos plane (runtime/faults.py, DESIGN.md §17) ------------------------

    def _chaos_step(self) -> None:
        """Fire due supervisor-side fault events, then settle in-flight
        recoveries (a fault's ``recovery_s`` closes when the supervisor
        observes the victim back: worker respawned, shard rebound)."""
        if self.plan is not None:
            for idx, e in enumerate(self.plan.events):
                if (
                    e.kind not in SUPERVISOR_KINDS
                    or idx in self._chaos_fired
                    or self._frontier < e.step
                ):
                    continue
                self._chaos_fired.add(idx)
                self._inject(idx, e)
        self._settle_chaos()

    def _inject(self, idx: int, e: FaultEvent) -> None:
        rec = {"index": idx, "kind": e.kind, "step": e.step,
               "at_frontier": self._frontier}
        if e.kind == "worker_kill":
            slot = self.slots[e.worker]
            rec["worker"] = e.worker
            if slot.terminal is not None or not slot.alive:
                rec["skipped"] = "victim not running"
                self.chaos_events.append(rec)
                return
            _sigkill(slot)
            self._chaos_pending.append(
                {"rec": rec, "t0": time.monotonic(), "kind": e.kind,
                 "worker": e.worker, "invocations": slot.invocations})
        elif e.kind in ("broker_kill", "wal_corrupt"):
            bs = self.shards[e.shard]
            rec["shard"] = e.shard
            if not bs.alive:
                rec["skipped"] = "shard not running"
                self.chaos_events.append(rec)
                return
            _sigkill(bs)
            if e.kind == "wal_corrupt":
                rec["flipped_offset"] = self._flip_wal_byte(e.shard, idx)
            self._chaos_pending.append(
                {"rec": rec, "t0": time.monotonic(), "kind": e.kind,
                 "shard": e.shard, "spawns": bs.spawns})
        elif e.kind == "supervisor_kill":
            # journal first (chaos_fired already holds this index, so the
            # successor will not re-fire it), then die for real — no
            # cleanup, no goodbye: the pool keeps running headless until
            # the next supervisor re-adopts it from the journal
            rec["killed_at_wall"] = time.time()
            self.chaos_events.append(rec)
            self._save_journal()
            os.kill(os.getpid(), signal.SIGKILL)

    def _flip_wal_byte(self, shard: int, idx: int) -> Optional[int]:
        """Flip one seeded byte in the tail third of a (just-killed)
        shard's WAL — the respawn's CRC check quarantines from there."""
        assert self.plan is not None
        path = os.path.join(self._broker_dir(), f"shard{shard:02d}.wal")
        try:
            size = os.path.getsize(path)
        except OSError:
            return None
        if size == 0:
            return None
        rng = random.Random((self.plan.seed << 8) ^ (0x5A5A + idx))
        pos = rng.randrange(size - max(size // 3, 1), size)
        with open(path, "r+b") as f:
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0xFF]))
            f.flush()
            os.fsync(f.fileno())
        return pos

    def _settle_chaos(self) -> None:
        still = []
        for p in self._chaos_pending:
            rec = p["rec"]
            if p["kind"] == "worker_kill":
                slot = self.slots[p["worker"]]
                done = slot.terminal is not None or (
                    slot.invocations > p["invocations"] and slot.alive)
            else:  # broker_kill / wal_corrupt: settled once rebound
                bs = self.shards[p["shard"]]
                done = bs.spawns > p["spawns"] and bs.alive
                if done and p["kind"] == "wal_corrupt":
                    rec["rollback"] = self._quarantine_rollback(p["shard"])
            if done:
                rec["recovery_s"] = time.monotonic() - p["t0"]
                self.chaos_events.append(rec)
            else:
                still.append(p)
        self._chaos_pending = still

    def _prune_checkpoints(self, worker: int, limit: int) -> list[int]:
        from repro.checkpoint import store as ckpt

        d = os.path.join(self.cfg.run_dir, "ckpt", f"w{worker:03d}")
        pruned = []
        for step in ckpt.all_steps(d):
            if step > limit:
                shutil.rmtree(os.path.join(d, f"step_{step:010d}"),
                              ignore_errors=True)
                pruned.append(step)
        return pruned

    def _quarantine_rollback(self, shard: int) -> list[dict]:
        """Reconcile the pool with a shard that lost a WAL suffix.

        The respawned shard's per-worker publish ``clocks`` are its
        durable frontier — anything a worker published past its clock on
        this shard is gone (quarantined, or silently torn off when the
        flip hit a length field of the final record, which is why this
        runs unconditionally after every wal_corrupt injection).  Roll
        every non-terminal worker back to that frontier: SIGKILL it and
        prune its checkpoints past the clock, so the normal crash-respawn
        path replays forward and re-publishes the lost records
        bit-identically (the other shards dup-check the duplicates)."""
        bs = self.shards[shard]
        try:
            resp, _ = protocol.request(
                bs.addr, {"t": "poll", "since": self.cfg.total_steps + 1},
                timeout=10.0,
            )
        except (ConnectionError, OSError, TimeoutError):
            return []  # shard died again; the next reap cycle recovers
        clocks = {int(k): v for k, v in (resp.get("clocks") or {}).items()}
        rolled = []
        for slot in self.slots:
            if slot.terminal is not None:
                continue
            limit = clocks.get(slot.worker, 0)
            pruned = self._prune_checkpoints(slot.worker, limit)
            if slot.alive:
                _sigkill(slot)
            rolled.append({"worker": slot.worker, "replay_from": limit,
                           "pruned_ckpts": pruned})
        return rolled

    # -- crash journal + re-adoption (DESIGN.md §17.4) -------------------------

    def _journal_path(self) -> str:
        return os.path.join(self.cfg.run_dir, "supervisor.journal.json")

    def _save_journal(self) -> None:
        """Atomically persist everything a successor supervisor needs to
        re-adopt the live pool: pids, ports, invocation counters and the
        billing/telemetry accumulators.  Monotonic timestamps are stored
        as wall-clock so the successor can rebase them onto its own
        monotonic domain."""
        if not self._journal_enabled:
            return
        now_m, now_w = time.monotonic(), time.time()
        state = {
            "version": 1,
            "t_job0_wall": now_w - (now_m - self._t_job0),
            "shm_token": self._shm_token,
            "topology": self.topology,
            "topo_gen": self.topo_gen,
            "max_brokers": self._max_brokers,
            "shards": [
                {"shard": bs.shard,
                 "addr": list(bs.addr) if bs.addr else None,
                 "pid": bs.pid, "spawns": bs.spawns}
                for bs in self.shards
            ],
            "slots": [
                {"worker": s.worker, "pid": s.pid,
                 "invocations": s.invocations, "terminal": s.terminal,
                 "inv_start": s.inv_start,
                 "spawned_wall": (
                     now_w - (now_m - s.spawned_at) if s.spawned_at else None
                 ),
                 "shm_segs": list(s.shm_segs),
                 "pre_pid": (
                     s.pre_proc.pid if s.pre_proc is not None else None
                 ),
                 "pre_shm_segs": list(s.pre_shm_segs),
                 "held": s.held}
                for s in self.slots
            ],
            "lifetimes": self.lifetimes,
            "evictions": self.evictions,
            "scale_events": self.scale_events,
            "respawns": self.respawns,
            "broker_respawns": self.broker_respawns,
            "cold_start_overlaps": self.cold_start_overlaps,
            "retired_shard_stats": self.retired_shard_stats,
            "topology_events": self.topology_events,
            "scripted_fired": self._scripted_fired,
            "chaos_fired": sorted(self._chaos_fired),
            "chaos_events": self.chaos_events,
            "resumed": self._resumed,
        }
        tmp = self._journal_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._journal_path())

    def _resume_from_journal(self) -> bool:
        """Re-adopt a previous supervisor's pool from its journal.

        Live brokers/workers are adopted by pid (they kept running
        headless and never noticed the change of management); dead ones
        respawn through the normal WAL-replay / checkpoint-replay paths.
        Telemetry is re-polled from step 1 — the coordinator retains the
        full history, so the resumed history is identical."""
        path = self._journal_path()
        if not self._resume or not os.path.exists(path):
            return False
        with open(path) as f:
            st = json.load(f)
        now_m, now_w = time.monotonic(), time.time()
        self._t_job0 = now_m - (now_w - st["t_job0_wall"])
        self._shm_token = st["shm_token"]
        self.topology = st["topology"]
        self.topo_gen = st["topo_gen"]
        self._max_brokers = st["max_brokers"]
        self.lifetimes = st["lifetimes"]
        self.evictions = {int(k): v for k, v in st["evictions"].items()}
        self.scale_events = st["scale_events"]
        self.respawns = st["respawns"]
        self.broker_respawns = st["broker_respawns"]
        self.cold_start_overlaps = st["cold_start_overlaps"]
        self.retired_shard_stats = st["retired_shard_stats"]
        self.topology_events = st["topology_events"]
        self._scripted_fired = st["scripted_fired"]
        self._chaos_fired = set(st["chaos_fired"])
        self.chaos_events = st["chaos_events"]
        self._resumed = st.get("resumed", 0) + 1
        adopted_b = adopted_w = 0
        self.shards = []
        self._conns = []
        for js in st["shards"]:
            bs = _BrokerShard(shard=js["shard"], spawns=js["spawns"])
            bs.addr = tuple(js["addr"]) if js["addr"] else None
            if _pid_alive(js["pid"]):
                bs.adopted_pid = js["pid"]
                adopted_b += 1
            self.shards.append(bs)
            self._conns.append(None)
        for bs in self.shards:
            if not bs.alive:  # spawns > 0: the WAL replays before binding
                self._spawn_broker(bs)
        self.slots = []
        for js in st["slots"]:
            s = _Slot(worker=js["worker"], invocations=js["invocations"],
                      terminal=js["terminal"], inv_start=js["inv_start"],
                      held=js["held"])
            s.shm_segs = list(js["shm_segs"])
            if js["spawned_wall"]:
                s.spawned_at = now_m - (now_w - js["spawned_wall"])
            if js["terminal"] is None and _pid_alive(js["pid"]):
                s.adopted_pid = js["pid"]
                adopted_w += 1
            # a pre-warmed successor gated by the dead supervisor: its
            # gate can never open from here — kill it and bill the
            # (real, live-function) seconds it ran
            if js["pre_pid"] and _pid_alive(js["pre_pid"]):
                try:
                    os.kill(js["pre_pid"], signal.SIGKILL)
                except OSError:
                    pass
            self.slots.append(s)
        # non-terminal slots that died alongside the supervisor respawn
        # through the normal crash path (restore newest ckpt + replay)
        for s in self.slots:
            if s.terminal is None and not s.alive and not s.held:
                self.respawns.append(
                    {"worker": s.worker, "exit_code": None,
                     "restored_step": self._restored_step(s),
                     "at_frontier": self._frontier,
                     "resume_orphan": True}
                )
                self._spawn(s)
        # stamp recovery on the kill event that took the predecessor down
        for rec in self.chaos_events:
            if rec.get("kind") == "supervisor_kill" \
                    and "recovery_s" not in rec:
                rec["recovery_s"] = now_w - rec["killed_at_wall"]
                rec["readopted"] = {"workers": adopted_w,
                                    "brokers": adopted_b}
        # the coordinator retains full telemetry: re-poll from step 1
        self._poll_since = 1
        self._frontier = 0
        return True

    # -- main loop ------------------------------------------------------------

    def run(self) -> dict:
        cfg = self.cfg
        os.makedirs(cfg.run_dir, exist_ok=True)
        self._t_job0 = time.monotonic()
        dump = None
        try:
            if not self._resume_from_journal():
                self._start_brokers()
                for slot in self.slots:
                    self._spawn(slot)
            self._save_journal()
            deadline = self._t_job0 + cfg.deadline_s
            while True:
                time.sleep(cfg.poll_interval_s)
                self._reap_brokers()
                resp = self._poll()
                statuses = resp["statuses"]

                # seeded fault injection (runtime/faults.py): SIGKILLs,
                # WAL corruption, supervisor suicide — the chaos plane
                # compiled from cfg.chaos + the legacy kill_* knobs
                self._chaos_step()

                for slot in self.slots:
                    exited = (
                        slot.proc.poll() is not None
                        if slot.proc is not None
                        else slot.adopted_pid is not None
                        and not _pid_alive(slot.adopted_pid)
                    )
                    if slot.terminal is None and exited:
                        # refresh statuses so a just-sent bye is not
                        # misread as a crash
                        statuses = self._poll()["statuses"]
                        self._reap(slot, statuses)

                self._maybe_prespawn()
                self._scan_prewarm_ready()

                # topology handover (DESIGN.md §16): every live worker
                # parked at the fence -> migrate the store and resume
                if self._handover is not None and all(
                    s.terminal is not None or s.held for s in self.slots
                ):
                    self._complete_handover()

                all_alive = all(
                    s.alive for s in self.slots if s.terminal is None
                )
                if all_alive and self._handover is None:
                    if self._scripted_fired < len(cfg.scripted_evict_steps):
                        nxt = cfg.scripted_evict_steps[self._scripted_fired]
                        if self._frontier >= nxt:
                            if self._evict_victim("scripted"):
                                self._scripted_fired += 1
                    if self.tuner is not None and self.history:
                        decision = self.tuner.decide()
                        if decision.remove_worker:
                            self._evict_victim(
                                decision.reason, decision.s_delta
                            )
                    if self._retunes_pending:
                        nxt, changes = self._retunes_pending[0]
                        if self._frontier >= nxt:
                            # settled either way: a past-end refusal is
                            # permanent, retrying it would spin forever
                            self._initiate_retune(changes)
                            self._retunes_pending.pop(0)
                    elif self.topo_tuner is not None and self.history:
                        last = self.history[-1]
                        p = max(int(last.get("p_active") or 1), 1)
                        # per-worker bytes/step for the cost-model
                        # tie-break — must be current BEFORE next_action
                        # picks a winner
                        self.topo_tuner.bytes_per_step = (
                            float(last.get("wire_bytes") or 0.0) / p
                        )
                        self.topo_tuner.n_workers = p
                        action = self.topo_tuner.next_action()
                        if action is not None:
                            _kind, cell = action
                            if not self._initiate_retune(cell):
                                self.topo_tuner.abandon()

                self._save_journal()

                if all(s.terminal is not None for s in self.slots):
                    self._poll()
                    break
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"FaaS job deadline ({cfg.deadline_s}s) exceeded at "
                        f"frontier {self._frontier}; statuses={statuses}; "
                        f"logs in {os.path.join(cfg.run_dir, 'logs')}"
                    )

            # a fault whose recovery the job's end beat to the punch
            for p in self._chaos_pending:
                p["rec"]["recovery_s"] = None
                self.chaos_events.append(p["rec"])
            self._chaos_pending = []
            if cfg.retain_updates:
                dump = self._dump_updates()
            self._stopping = True
            shard_stats = []
            for s in range(len(self.shards)):
                resp, _ = self._rpc({"t": "shutdown"}, shard=s)
                shard_stats.append(resp)
            # shards retired by a mid-job shrink already reported at
            # retirement; their socket stats belong in the same rollup
            shard_stats.extend(self.retired_shard_stats)
            # clean completion: the journal has nothing left to recover
            if self._journal_enabled:
                try:
                    os.unlink(self._journal_path())
                except OSError:
                    pass
        finally:
            for slot in self.slots:
                if slot.alive:
                    _sigkill(slot)
                if slot.pre_proc is not None and slot.pre_proc.poll() is None:
                    slot.pre_proc.kill()
            for conn in self._conns:
                if conn is not None:
                    conn.close()
            self._conns = [None] * len(self.shards)
            for bs in self.shards:
                if bs.proc is not None:
                    bs.proc.terminate()
                    try:
                        bs.proc.wait(timeout=5.0)
                    except subprocess.TimeoutExpired:
                        bs.proc.kill()
                elif bs.adopted_pid is not None and _pid_alive(bs.adopted_pid):
                    _terminate_pid(bs.adopted_pid)
            # the supervisor owns every shm segment: none may outlive the
            # job (they are named host-global resources, not fds)
            for seg in self._shm_segments.values():
                seg.unlink()
            self._shm_segments.clear()

        wall = time.monotonic() - self._t_job0
        # the topology bills what it runs: one Redis-analogue VM per shard
        # — the PEAK shard count under live re-sharding (a shard that ran
        # for part of the job still occupied a VM slot; honest upper bound)
        bill = faas_cost(self.lifetimes, wall, n_redis=self._max_brokers)
        return self._result(wall, bill, shard_stats, dump)

    # -- results --------------------------------------------------------------

    def _dump_updates(self) -> list[dict]:
        """Merge every shard's stored slices back into full update trees
        (``sharding.LeafBuffers`` reassembles split leaves too)."""
        import jax
        import numpy as np

        leaf_keys = protocol.tree_keys(self.wl.params0)
        treedef = jax.tree_util.tree_structure(self.wl.params0)
        from repro.runtime import sharding

        leaf_like = {
            k: (np.shape(leaf), np.asarray(leaf).dtype)
            for k, leaf in zip(
                leaf_keys, jax.tree_util.tree_leaves(self.wl.params0)
            )
        }
        acc: dict[tuple[int, int], sharding.LeafBuffers] = {}
        for s in range(len(self.shards)):
            resp, blob = self._rpc({"t": "dump"}, shard=s)
            for desc, m, leaf in sharding.iter_part_leaves(
                resp["parts"], blob
            ):
                key = (int(desc["worker"]), int(desc["step"]))
                if key not in acc:  # setdefault would zero-fill per leaf
                    acc[key] = sharding.LeafBuffers(leaf_like)
                acc[key].add(m, leaf)
        out = []
        for (worker, step) in sorted(acc):
            bufs = acc[(worker, step)]
            bufs.assert_complete(what=f"dump (worker {worker}, step {step})")
            out.append(
                {
                    "worker": worker,
                    "step": step,
                    "update": jax.tree_util.tree_unflatten(
                        treedef, [bufs[k] for k in leaf_keys]
                    ),
                }
            )
        return out

    def _final_eval(self) -> tuple[Optional[float], Optional[int]]:
        from repro.checkpoint import store as ckpt

        survivors = [s.worker for s in self.slots if s.terminal == "done"]
        if not survivors:
            return None, None
        w = min(survivors)
        d = os.path.join(self.cfg.run_dir, "ckpt", f"w{w:03d}")
        step = ckpt.latest_step(d)
        if step is None:
            return None, None
        import jax
        import jax.numpy as jnp

        from repro import optim as optim_lib

        optimizer = optim_lib.make(self.cfg.optimizer, self.cfg.lr)
        like = {
            "params": self.wl.params0,
            "opt": optimizer.init(self.wl.params0),
            "residual": jax.tree.map(jnp.zeros_like, self.wl.params0),
        }
        tree = ckpt.restore(d, step, like)
        return self.wl.eval_fn(tree["params"]), step

    def _result(self, wall, bill: FaaSBill, shard_stats, dump):
        final_eval, final_ckpt_step = self._final_eval()
        hist = self.history
        durs = [r["dur_s"] for r in hist if r.get("dur_s")]
        phases = [r["phase"] for r in hist if r.get("phase")]
        phase_s_mean = (
            {
                k: sum(p[k] for p in phases if p.get(k) is not None)
                / max(sum(1 for p in phases if p.get(k) is not None), 1)
                for k in phases[0]
            }
            if phases
            else {}
        )
        # aggregate per-message byte accounting across shards (the merged
        # view existing callers read), keep the per-shard split alongside
        stats: dict[str, dict[str, int]] = {}
        for resp in shard_stats:
            for kind, row in (resp.get("stats") or {}).items():
                agg = stats.setdefault(
                    kind, {"count": 0, "bytes_in": 0, "bytes_out": 0}
                )
                for k in agg:
                    agg[k] += row.get(k, 0)
        dup_mismatches = sum(
            int(r.get("dup_mismatches", 0)) for r in shard_stats
        )
        wal_quarantined = sum(
            int(r.get("wal_quarantined", 0)) for r in shard_stats
        )
        result = {
            "workload": self.wl.name,
            "n_workers": self.cfg.n_workers,
            # FINAL topology (== starting topology unless a live re-shard
            # happened; 'topology'/'topology_events' carry the full story)
            "n_brokers": self.topology["n_brokers"],
            "transport": self.topology["transport"],
            "topology": dict(self.topology),
            "topology_gen": self.topo_gen,
            "topology_events": self.topology_events,
            "topology_tuner": (
                None if self.topo_tuner is None else self.topo_tuner.summary()
            ),
            "steps": self._frontier,
            "final_pool": sum(1 for s in self.slots if s.terminal == "done"),
            "final_loss": hist[-1]["loss"] if hist else None,
            "final_eval": final_eval,
            "final_ckpt_step": final_ckpt_step,
            "history": hist,
            "measured_step_s": (sum(durs) / len(durs)) if durs else None,
            "phase_s_mean": phase_s_mean,
            "wire_scheme": self.cfg.wire_scheme,
            "wire_quant": self.cfg.wire_quant,
            "wire_impl": self.cfg.wire_impl,
            # what launch/hostperf.py actually applied (None when off, and
            # tcmalloc: None inside when the library is absent) — every
            # benchmark row states its own substrate
            "hostperf": self.hostperf_applied,
            "invariant_max_err": max(
                (r["inv_err"] for r in hist), default=0.0
            ),
            "wire_bytes_total": sum(r["wire_bytes"] for r in hist),
            "scale_events": self.scale_events,
            "respawns": self.respawns,
            "n_respawns": len(self.respawns),
            "broker_respawns": self.broker_respawns,
            # pre-warmed respawn telemetry (cfg.prewarm): measured seconds
            # of runtime/XLA init that overlapped the previous invocation
            "cold_start_overlaps": self.cold_start_overlaps,
            "n_invocations": len(self.lifetimes),
            "lifetimes_s": list(self.lifetimes),
            "dup_mismatches": dup_mismatches,
            # chaos plane (runtime/faults.py): what fired, how long each
            # recovery took, and what the WAL CRC check had to drop
            "chaos": None if self.plan is None else self.plan.to_spec(),
            "chaos_events": self.chaos_events,
            "wal_quarantined_bytes": wal_quarantined,
            "supervisor_resumed": self._resumed,
            "wall_s": wall,
            "bill": {
                "worker_seconds": bill.worker_seconds,
                "wall_seconds": bill.wall_seconds,
                "worker_cost": bill.worker_cost,
                "infra_cost": bill.infra_cost,
                "n_redis": bill.n_redis,
                "total": bill.total,
            },
            "broker_stats": stats,
            "broker_stats_per_shard": [
                r.get("stats") or {} for r in shard_stats
            ],
            # codec-accounted published-update bytes each shard measured —
            # the per-shard half of the §10 invariant (== what
            # runtime.sharding.predict_shard_nbytes accounts)
            "broker_update_bytes_per_shard": [
                int(r.get("update_bytes", 0)) for r in shard_stats
            ],
        }
        if dump is not None:
            result["updates"] = dump
        return result


def run_job(cfg: FaaSJobConfig) -> dict:
    """Run one FaaS training job to completion; returns the result dict."""
    return Supervisor(cfg).run()


def final_params_digest(cfg: FaaSJobConfig, worker: int = 0) -> str:
    """sha256 over one worker's final checkpointed parameters from a
    finished run — the bit-identity witness the transport/topology sweeps
    and the wire guard compare across ``{tcp, shm} x n_brokers``."""
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import optim as optim_lib
    from repro.checkpoint import store as ckpt

    wl = workload_lib.build(cfg.workload, cfg.workload_cfg)
    optimizer = optim_lib.make(cfg.optimizer, cfg.lr)
    like = {
        "params": wl.params0,
        "opt": optimizer.init(wl.params0),
        "residual": jax.tree.map(jnp.zeros_like, wl.params0),
    }
    d = os.path.join(cfg.run_dir, "ckpt", f"w{worker:03d}")
    step = ckpt.latest_step(d)
    if step is None:
        raise FileNotFoundError(f"no final checkpoint under {d}")
    tree = ckpt.restore(d, step, like)
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree["params"]):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


# the canonical quickstart job — examples/mlless_faas.py runs it and
# benchmarks/fig6_autotuner.py calibrates the simulator against the SAME
# configuration, so it lives in exactly one place
PMF_QUICKSTART_CFG = {
    "n_users": 200,
    "n_movies": 300,
    "n_ratings": 12_000,
    "rank": 8,
    "batch_size": 512,
}


def pmf_quickstart_config(
    run_dir: str, n_workers: int = 4, total_steps: int = 140,
    n_brokers: int = 1, transport: str = "tcp",
    consistency: str = "isp", slack: int = 3,
    wire_impl: str = "numpy", hostperf: bool = False,
) -> FaaSJobConfig:
    """PMF on 4 CPU workers with a live knee-driven scale-in (~1 min)."""
    return FaaSJobConfig(
        run_dir=run_dir,
        workload="pmf",
        workload_cfg=dict(PMF_QUICKSTART_CFG),
        n_workers=n_workers,
        total_steps=total_steps,
        invocation_steps=max(total_steps // 2, 1),  # >= 2 real invocations
        checkpoint_every=20,
        optimizer="nesterov",
        # stale peer corrections shrink the stable step size (classic
        # delayed-gradient result): Nesterov at lr 0.3 rides the momentum
        # oscillation into NaN under slack once it reaches the curved
        # region near the optimum; 0.05 converges through the whole slack
        # range the CLI exposes — slower time-to-loss than ISP at 0.3,
        # which is the paper's fig9 point, measured live
        lr=0.3 if consistency == "isp" else 0.05,
        isp_v=0.7,
        n_brokers=n_brokers,
        transport=transport,
        consistency=consistency,
        slack=slack,
        wire_impl=wire_impl,
        hostperf=hostperf,
        autotune=True,
        tuner=AutoTunerConfig(
            sched_interval_s=0.5,
            delta_s=0.25,
            knee_slope_threshold=0.3,
            min_points_for_fit=8,
        ),
        deadline_s=480.0,
    )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="pmf",
                    choices=workload_lib.WORKLOAD_NAMES)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--invocation-steps", type=int, default=1_000_000)
    ap.add_argument("--n-brokers", type=int, default=1)
    ap.add_argument("--transport", default="tcp", choices=("tcp", "shm"))
    ap.add_argument("--consistency", default="isp", choices=("isp", "ssp"))
    ap.add_argument("--slack", type=int, default=3)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--prewarm", action="store_true")
    ap.add_argument("--run-dir", default="/tmp/repro_faas")
    ap.add_argument("--out", default=None)
    ap.add_argument("--config", default=None,
                    help="JSON FaaSJobConfig (from_dict); overrides the "
                         "per-field job flags")
    ap.add_argument("--resume", action="store_true",
                    help="re-adopt a previous supervisor's pool from its "
                         "journal when one exists in run_dir")
    ap.add_argument("--allow-self-kill", action="store_true",
                    help="permit a supervisor_kill fault event (only safe "
                         "under an external driver that re-executes us)")
    args = ap.parse_args()
    if args.config:
        with open(args.config) as f:
            cfg = FaaSJobConfig.from_dict(json.load(f))
    else:
        cfg = FaaSJobConfig(
            run_dir=args.run_dir,
            workload=args.workload,
            n_workers=args.workers,
            total_steps=args.steps,
            invocation_steps=args.invocation_steps,
            n_brokers=args.n_brokers,
            transport=args.transport,
            consistency=args.consistency,
            slack=args.slack,
            autotune=args.autotune,
            prewarm=args.prewarm,
        )
    res = Supervisor(
        cfg, allow_self_kill=args.allow_self_kill, resume=args.resume
    ).run()
    slim = {k: v for k, v in res.items() if k not in ("history", "updates")}
    print(json.dumps(slim, indent=1, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
