"""Pallas TPU kernels: fused wire-pack encode + unpack/apply decode
(DESIGN.md §15).

``wire.codec`` historically re-scanned every significance-filtered update
on the host: numpy walks the flat leaf once for ``flatnonzero``, again for
``packbits``, again for the quantizing ``astype``, and once more for the
error-feedback residual — four host passes over a tensor the Pallas
significance kernel just produced on device.  This module fuses that
whole encode into ONE device pass per tile:

    mask   = sig != 0                          (significance support)
    bytes  = packbits(mask, 'little')          (bitmap wire mask)
    qvals  = sig.astype(wire_dtype)            (fp16/bf16 quantization)
    nnz    = sum(mask)                         (per-tile, summed on host)
    resid  = f32(sig) - f32(qvals)             (error-feedback residual)

The bit-packing rides the MXU: a (LANES, LANES) constant weight matrix
``W[l, k] = (l // 8 == k) * 2**(l % 8)`` turns ``mask @ W`` into exactly
numpy's ``packbits(bitorder='little')`` — byte ``k`` of a 128-lane row
collects lanes ``8k .. 8k+7``, each weighted by its power of two (byte
values <= 255, exact in f32).  Compaction of the significant values (and
their flat indices, for the sparse scheme) is a fixed-shape
cumsum-scatter epilogue in the same jit: dynamic output shapes don't
exist on TPU, so the kernel emits full-length arrays and the HOST slices
the first ``nnz`` elements — the only bytes that ever leave the device
boundary are final wire bytes.

Decode is the mirror image: ``_unpack_kernel`` broadcasts each packed
byte to its 8 lanes with the transpose trick (``bytes @ E`` where
``E[k, l] = (l // 8 == k)``), shifts out the lane's bit, and the gather +
fused add scatters the received ``(mask, values)`` pair straight into the
target leaf (``wire_unpack_add``) — the accumulate the worker's decode
phase performs per peer, without materializing the intermediate dense
update on the host.

Everything here is bit-identical to the numpy codec by construction
(quantization commutes with compaction; both sides round-to-nearest-even)
and property-tested in ``tests/test_wire_pack.py``.  ``interpret=True``
runs the kernels on CPU (the CI validation mode, auto-selected by
``wire.codec`` off the jax backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128  # TPU vector lane width
SUBLANES = 8  # fp32 sublane height
BYTES_PER_ROW = LANES // 8  # packed mask bytes per 128-lane row
DEFAULT_BLOCK_ROWS = 256  # (256, 128) fp32 tile = 128 KiB/operand in VMEM


def pick_block_rows(n: int) -> int:
    """Smallest legal row-block covering an ``n``-element flat leaf:
    full tiles for big leaves, one (8*k, 128) tile for small ones so a
    4 KiB leaf doesn't pad out to 128 KiB."""
    rows = -(-max(n, 1) // LANES)
    return min(DEFAULT_BLOCK_ROWS, -(-rows // SUBLANES) * SUBLANES)


def _pad_to_tiles(flat: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    n = flat.shape[0]
    tile = block_rows * LANES
    pad = (-n) % tile
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


def _pack_weights() -> jax.Array:
    """(LANES, LANES) bit-pack matrix: ``W[l, k] = (l//8 == k) * 2**(l%8)``
    — ``mask_f32 @ W`` is numpy's little-endian packbits per row (bytes
    land in lanes 0..15, the rest are zero)."""
    src = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 0)  # lane l
    dst = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 1)  # byte k
    return jnp.where(
        src // 8 == dst, jnp.exp2((src % 8).astype(jnp.float32)), 0.0
    )


def _pack_kernel(x_ref, q_ref, bits_ref, cnt_ref, res_ref):
    """One (block_rows, LANES) tile: quantize, pack mask bits, count,
    and fold the error-feedback residual — one read, four writes."""
    x = x_ref[...]
    mask = x != 0
    q = x.astype(q_ref.dtype)
    q_ref[...] = q
    res_ref[...] = x.astype(jnp.float32) - q.astype(jnp.float32)
    bytes_f = jnp.dot(
        mask.astype(jnp.float32), _pack_weights(),
        preferred_element_type=jnp.float32,
    )
    bits_ref[...] = bytes_f.astype(jnp.int32)
    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("vdt", "block_rows", "interpret")
)
def wire_pack(
    flat: jax.Array,
    *,
    vdt,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Fused encode of one flat leaf (n >= 1 elements).

    Args:
      flat: 1-D significance-filtered update (zeros are insignificant).
      vdt: wire value dtype (``wire.codec.quant_dtype`` result).
      interpret: run the kernel body on CPU (validation mode).

    Returns ``(mask_bytes, qdense, cvals, cidx, nnz, residual)``:
      mask_bytes: uint8[ceil(n/8)] — little-endian packed significance mask;
      qdense: vdt[n] — the dense-scheme wire values (every element quantized);
      cvals: vdt[n] — significant values compacted to the front (host
        slices ``[:nnz]``);
      cidx: int32[n] — their flat indices, same compaction (sparse scheme);
      nnz: int32 scalar — significant-element count;
      residual: f32[n] — error-feedback quantization residual, zero off
        the support (and everywhere when vdt preserves the leaf dtype).
    """
    n = flat.shape[0]
    x2, _ = _pad_to_tiles(flat, block_rows)
    rows = x2.shape[0]
    grid = (rows // block_rows,)
    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    q2, bits2, cnt, res2 = pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[block],
        out_specs=[
            block,
            block,
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            block,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), vdt),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    nnz = jnp.sum(cnt)
    mask_bytes = (
        bits2[:, :BYTES_PER_ROW].astype(jnp.uint8).reshape(-1)[: (n + 7) // 8]
    )
    qdense = q2.reshape(-1)[:n]
    res = res2.reshape(-1)[:n]
    # fixed-shape compaction: ascending cumsum positions preserve flat
    # order, the insignificant lanes scatter out of bounds and drop
    mask = x2.reshape(-1)[:n] != 0
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    tgt = jnp.where(mask, pos, n)
    cvals = jnp.zeros((n,), vdt).at[tgt].set(qdense, mode="drop")
    cidx = (
        jnp.zeros((n,), jnp.int32)
        .at[tgt]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    )
    return mask_bytes, qdense, cvals, cidx, nnz, res


def _nnz_kernel(x_ref, cnt_ref):
    cnt_ref[0, 0] = jnp.sum((x_ref[...] != 0).astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def wire_nnz(
    flat: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Significant-element count of a flat tensor as ONE kernel pass —
    the hit counter the pod collectives' byte accounting rides when the
    fused path is on (same tiling as ``wire_pack``, so the count and the
    packed bytes can never disagree)."""
    x2, _ = _pad_to_tiles(flat, block_rows)
    rows = x2.shape[0]
    grid = (rows // block_rows,)
    cnt = pl.pallas_call(
        _nnz_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        interpret=interpret,
    )(x2)
    return jnp.sum(cnt)


def _unpack_kernel(b_ref, bits_ref):
    """Bytes (lanes 0..15) -> 0/1 mask bits (all 128 lanes) for one tile:
    broadcast byte ``l // 8`` to lane ``l`` via the transpose of the pack
    matrix, then shift out bit ``l % 8``."""
    src = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 0)  # byte k
    dst = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 1)  # lane l
    spread = (dst // 8 == src).astype(jnp.float32)
    byte_per_lane = jnp.dot(
        b_ref[...], spread, preferred_element_type=jnp.float32
    ).astype(jnp.int32)
    shift = jax.lax.broadcasted_iota(jnp.int32, byte_per_lane.shape, 1) % 8
    bits_ref[...] = jax.lax.shift_right_logical(byte_per_lane, shift) & 1


def _add_kernel(t_ref, u_ref, o_ref):
    o_ref[...] = t_ref[...] + u_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def wire_unpack_add(
    target: jax.Array,
    mask_bytes: jax.Array,
    cvals: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Fused decode/apply: scatter a received ``(mask, values)`` pair
    straight into ``target`` (the parameter leaf or a peer-sum
    accumulator).

    Args:
      target: 1-D accumulation target of the leaf dtype (n elements).
      mask_bytes: uint8[ceil(n/8)] little-endian packed significance mask.
      cvals: wire-dtype significant values, front-packed and padded to a
        static capacity >= nnz (the pad is never gathered: every masked
        lane's cumsum position is < nnz).

    Returns ``target + decoded`` — identical to numpy's
    ``target += decode_leaf(...)`` including the unconditional ``+ 0``
    off the support (so a stray ``-0.0`` in the target normalizes the
    same way on both paths).
    """
    n = target.shape[0]
    # embed the packed bytes at their rows' first 16 lanes, as f32 (the
    # unpack kernel broadcasts them over the MXU; values <= 255, exact)
    b2, _ = _pad_to_tiles(
        jnp.zeros((n,), jnp.float32), block_rows
    )  # row layout template
    rows = b2.shape[0]
    mb = mask_bytes.shape[0]
    bpad = jnp.pad(
        mask_bytes.astype(jnp.float32), (0, rows * BYTES_PER_ROW - mb)
    ).reshape(rows, BYTES_PER_ROW)
    b = jnp.zeros((rows, LANES), jnp.float32).at[:, :BYTES_PER_ROW].set(bpad)
    grid = (rows // block_rows,)
    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    bits2 = pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(b)
    mask = bits2.reshape(-1)[:n] == 1
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    cap = cvals.shape[0]
    gathered = cvals[jnp.clip(jnp.where(mask, pos, 0), 0, cap - 1)]
    upd = jnp.where(mask, gathered, jnp.zeros_like(gathered)).astype(
        target.dtype
    )
    t2, _ = _pad_to_tiles(target, block_rows)
    u2, _ = _pad_to_tiles(upd, block_rows)
    out2 = pl.pallas_call(
        _add_kernel,
        grid=grid,
        in_specs=[block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), target.dtype),
        interpret=interpret,
    )(t2, u2)
    return out2.reshape(-1)[:n]


@functools.partial(
    jax.jit, static_argnames=("n", "dtype", "block_rows", "interpret")
)
def wire_unpack(
    mask_bytes: jax.Array,
    cvals: jax.Array,
    *,
    n: int,
    dtype,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Decode-only form: the fused scatter into a zero leaf."""
    return wire_unpack_add(
        jnp.zeros((n,), dtype), mask_bytes, cvals,
        block_rows=block_rows, interpret=interpret,
    )
