"""Pallas TPU kernel: fused sLSTM time scan (xlstm-1.3b's sequential path).

The sLSTM cell is inherently sequential (recurrent h->gate connections), so
the XLA fallback lowers it as a 4096-iteration `lax.scan` whose every step
round-trips the (B, 4d) gate tensors through HBM — the dominant memory term
of the xlstm train cell (EXPERIMENTS.md §Perf cell (a)). This kernel keeps
the recurrent state (c, n, h) in VMEM scratch across the whole sequence and
streams xg in (block_t, 4d) tiles:

    HBM traffic = read xg once + write h once + stream R once per tile
                ~ S*5d*4B per layer-pass, vs the fallback's ~20 tensors
                  of (B,4d) per STEP.

This is the TPU adaptation of xLSTM's fused CUDA kernel (DESIGN.md §8).

Grid: (B, S/block_t); the time dimension is the innermost (sequential on
TPU) grid axis; scratch persists across it. The recurrent matmul runs
per-head as one (d x 4d) block-diagonal matmul materialized at kernel-build
time (R is small: heads x dh x 4dh).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 128


def _slstm_kernel(xg_ref, r_ref, out_ref, c_ref, n_ref, h_ref, *,
                  d: int, n_heads: int, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    r = r_ref[...].astype(jnp.float32)  # (d, 4d) block-diagonal

    def step(t, carry):
        c, n, h = carry
        # recurrent gates: h (1, d) @ R (d, 4d); R is block-diagonal per
        # head, materialized dense (zeros elsewhere) for one MXU matmul
        rh = h @ r  # (1, 4d)
        g = xg_ref[0, t][None, :] + rh
        i = jnp.exp(jnp.minimum(g[:, 0 * d:1 * d], 8.0))
        f = jax.nn.sigmoid(g[:, 1 * d:2 * d])
        z = jnp.tanh(g[:, 2 * d:3 * d])
        o = jax.nn.sigmoid(g[:, 3 * d:4 * d])
        c1 = f * c + i * z
        n1 = f * n + i
        h1 = o * (c1 / jnp.maximum(jnp.abs(n1), 1.0))
        out_ref[0, t] = h1[0].astype(out_ref.dtype)
        return c1, n1, h1

    carry = (c_ref[...], n_ref[...], h_ref[...])
    c, n, h = jax.lax.fori_loop(0, block_t, step, carry)
    c_ref[...] = c
    n_ref[...] = n
    h_ref[...] = h


def block_diag_r(r: jax.Array) -> jax.Array:
    """(H, dh, 4*dh) per-head recurrent weights -> dense (d, 4d) block-
    diagonal matrix in the fused w_in gate layout (i|f|z|o interleave as
    produced by slstm_apply's reorder)."""
    hh, dh, four_dh = r.shape
    d = hh * dh
    dense = jnp.zeros((d, 4 * d), r.dtype)
    for head in range(hh):
        rows = slice(head * dh, (head + 1) * dh)
        blk = r[head].reshape(dh, 4, dh)  # per-head gates contiguous
        for gate in range(4):
            cols = slice(gate * d + head * dh, gate * d + (head + 1) * dh)
            dense = dense.at[rows, cols].set(blk[:, gate])
    return dense


@functools.partial(
    jax.jit, static_argnames=("n_heads", "block_t", "interpret")
)
def slstm_scan(
    xg: jax.Array,  # (B, S, 4d) fp32 pre-computed input gates
    r: jax.Array,  # (H, dh, 4*dh) recurrent weights
    *,
    n_heads: int,
    block_t: int = DEFAULT_BLOCK_T,
    interpret: bool = False,
) -> jax.Array:
    """Returns h (B, S, d). Zero initial state (training entry point)."""
    b, s, four_d = xg.shape
    d = four_d // 4
    assert s % block_t == 0, (s, block_t)
    r_dense = block_diag_r(r)

    kern = functools.partial(
        _slstm_kernel, d=d, n_heads=n_heads, block_t=block_t
    )
    return pl.pallas_call(
        kern,
        grid=(b, s // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, 4 * d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((d, 4 * d), lambda bi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(xg, r_dense)
