"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is swept against
(tests/test_kernels.py: shapes x dtypes, assert_allclose). They are also
usable directly — the drivers fall back to these on platforms without
Pallas TPU support.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ---- significance filter (the paper's hot path) -------------------------------


def significance_ref(
    u: jax.Array,
    x: jax.Array,
    r: jax.Array,
    v_t: jax.Array | float,
    floor: float = 1e-8,
) -> tuple[jax.Array, jax.Array]:
    """Fused ISP filter step: acc = r + u; split by |acc| > v_t * max(|x|, floor).

    Returns (sig, new_residual) with sig + new_residual == acc exactly.
    Matches core.isp.significance_split applied to acc = r + u.
    """
    acc = r.astype(jnp.float32) + u.astype(jnp.float32)
    denom = jnp.maximum(jnp.abs(x.astype(jnp.float32)), floor)
    mask = jnp.abs(acc) > jnp.asarray(v_t, jnp.float32) * denom
    sig = jnp.where(mask, acc, 0.0)
    res = jnp.where(mask, 0.0, acc)
    return sig.astype(u.dtype), res.astype(r.dtype)


# ---- flash attention -----------------------------------------------------------


def mha_ref(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, H, Dh)
    v: jax.Array,  # (B, Skv, H, Dh)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Dense masked attention in fp32 — the flash kernel's oracle.

    ``q_offset`` is the absolute position of q[0] (needed when Sq != Skv,
    e.g. chunked prefill against a longer KV).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    allow = jnp.ones((sq, skv), bool)
    if causal:
        allow &= k_pos <= q_pos
    if window is not None:
        allow &= q_pos - k_pos < window
    logits = jnp.where(allow[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---- fused Adam ------------------------------------------------------------------


def adam_ref(
    p: jax.Array,
    g: jax.Array,
    mu: jax.Array,
    nu: jax.Array,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    step: int = 1,
    weight_decay: float = 0.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One Adam update; returns (new_p, new_mu, new_nu).

    Matches optim.optimizers.adam's per-leaf math (bias-corrected, optional
    decoupled weight decay) so the kernel can replace the optimizer's inner
    loop verbatim.
    """
    gf = g.astype(jnp.float32)
    mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
    nu2 = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
    t = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    upd = -lr * (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + eps)
    if weight_decay:
        upd = upd - lr * weight_decay * p.astype(jnp.float32)
    return (
        (p.astype(jnp.float32) + upd).astype(p.dtype),
        mu2.astype(mu.dtype),
        nu2.astype(nu.dtype),
    )


# ---- fused Adam + significance (ISP hot path, beyond-paper fusion) ---------------


def adam_sig_ref(
    p: jax.Array,
    g: jax.Array,
    mu: jax.Array,
    nu: jax.Array,
    r: jax.Array,
    v_t: jax.Array | float,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    step: int = 1,
    floor: float = 1e-8,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Adam update -> residual accumulate -> significance split, one pass.

    Returns (sig, new_mu, new_nu, new_residual). The caller exchanges
    ``sig`` and applies it: this fuses the paper's entire per-step worker
    arithmetic (optimizer + filter) into one read of 5 operands.
    """
    gf = g.astype(jnp.float32)
    mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
    nu2 = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
    t = jnp.asarray(step, jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    u = -lr * (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + eps)
    acc = r.astype(jnp.float32) + u
    denom = jnp.maximum(jnp.abs(p.astype(jnp.float32)), floor)
    mask = jnp.abs(acc) > jnp.asarray(v_t, jnp.float32) * denom
    sig = jnp.where(mask, acc, 0.0)
    res = jnp.where(mask, 0.0, acc)
    return (
        sig.astype(p.dtype),
        mu2.astype(mu.dtype),
        nu2.astype(nu.dtype),
        res.astype(r.dtype),
    )
