"""Pallas TPU kernels: fused Adam update, and fused Adam + ISP filter.

The paper's workers run `optimizer step -> significance filter` every
iteration on every parameter (MLLess §5 Cythonizes exactly this loop). A
jnp composition makes ~10 HBM round-trips over the parameter set (mu, nu,
update, residual-accumulate, |x| test, split); these kernels do it in one
VMEM pass per tile:

* ``adam_update``  — p,g,mu,nu  -> p',mu',nu'            (3 reads+3 writes)
* ``adam_sig``     — p,g,mu,nu,r -> sig,mu',nu',r'       (the full ISP
  worker arithmetic; ``sig`` is what the pod exchanges — beyond-paper
  fusion, EXPERIMENTS.md §Perf)

Scalars (lr, betas, eps, bias corrections, v_t) arrive via a single (1, 8)
fp32 block so one compiled kernel serves every step of the decaying
schedules.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 256


def _adam_kernel(s_ref, p_ref, g_ref, mu_ref, nu_ref,
                 p_out, mu_out, nu_out):
    lr, b1, b2, eps, bc1, bc2, wd = (
        s_ref[0, 0], s_ref[0, 1], s_ref[0, 2], s_ref[0, 3],
        s_ref[0, 4], s_ref[0, 5], s_ref[0, 6],
    )
    g = g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    nu = b2 * nu_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32)
    upd = -lr * (mu / bc1) / (jnp.sqrt(nu / bc2) + eps) - lr * wd * p
    p_out[...] = (p + upd).astype(p_out.dtype)
    mu_out[...] = mu.astype(mu_out.dtype)
    nu_out[...] = nu.astype(nu_out.dtype)


def _adam_sig_kernel(s_ref, p_ref, g_ref, mu_ref, nu_ref, r_ref,
                     sig_out, mu_out, nu_out, res_out, *, floor):
    lr, b1, b2, eps, bc1, bc2, v_t = (
        s_ref[0, 0], s_ref[0, 1], s_ref[0, 2], s_ref[0, 3],
        s_ref[0, 4], s_ref[0, 5], s_ref[0, 6],
    )
    g = g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    nu = b2 * nu_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32)
    u = -lr * (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
    acc = r_ref[...].astype(jnp.float32) + u
    denom = jnp.maximum(jnp.abs(p), floor)
    mask = jnp.abs(acc) > v_t * denom
    sig_out[...] = jnp.where(mask, acc, 0.0).astype(sig_out.dtype)
    res_out[...] = jnp.where(mask, 0.0, acc).astype(res_out.dtype)
    mu_out[...] = mu.astype(mu_out.dtype)
    nu_out[...] = nu.astype(nu_out.dtype)


def _tile(x: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % (block_rows * LANES)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


def _untile(t: jax.Array, n: int, shape) -> jax.Array:
    return t.reshape(-1)[:n].reshape(shape)


def _scalars(lr, b1, b2, eps, step, last) -> jax.Array:
    t = jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
    bc1 = 1.0 - jnp.power(jnp.asarray(b1, jnp.float32), t)
    bc2 = 1.0 - jnp.power(jnp.asarray(b2, jnp.float32), t)
    return jnp.stack(
        [jnp.asarray(v, jnp.float32)
         for v in (lr, b1, b2, eps, bc1, bc2, last, 0.0)]
    ).reshape(1, 8)


@functools.partial(
    jax.jit,
    static_argnames=("b1", "b2", "eps", "weight_decay", "block_rows",
                     "interpret"),
)
def adam_update(
    p: jax.Array,
    g: jax.Array,
    mu: jax.Array,
    nu: jax.Array,
    lr: jax.Array | float,
    step: jax.Array | int,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Adam step on one tensor. Returns (new_p, new_mu, new_nu)."""
    shape = p.shape
    p2, n = _tile(p, block_rows)
    g2, _ = _tile(g, block_rows)
    mu2, _ = _tile(mu, block_rows)
    nu2, _ = _tile(nu, block_rows)
    rows = p2.shape[0]
    s = _scalars(lr, b1, b2, eps, step, weight_decay)
    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        _adam_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  block, block, block, block],
        out_specs=[block, block, block],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), p.dtype),
            jax.ShapeDtypeStruct((rows, LANES), mu.dtype),
            jax.ShapeDtypeStruct((rows, LANES), nu.dtype),
        ],
        interpret=interpret,
    )(s, p2, g2, mu2, nu2)
    return tuple(_untile(o, n, shape) for o in outs)  # type: ignore


@functools.partial(
    jax.jit,
    static_argnames=("b1", "b2", "eps", "floor", "block_rows", "interpret"),
)
def adam_sig_update(
    p: jax.Array,
    g: jax.Array,
    mu: jax.Array,
    nu: jax.Array,
    r: jax.Array,
    lr: jax.Array | float,
    step: jax.Array | int,
    v_t: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    floor: float = 1e-8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused Adam + ISP filter. Returns (sig, new_mu, new_nu, new_residual)."""
    shape = p.shape
    p2, n = _tile(p, block_rows)
    g2, _ = _tile(g, block_rows)
    mu2, _ = _tile(mu, block_rows)
    nu2, _ = _tile(nu, block_rows)
    r2, _ = _tile(r, block_rows)
    rows = p2.shape[0]
    s = _scalars(lr, b1, b2, eps, step, v_t)
    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_adam_sig_kernel, floor=floor),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  block, block, block, block, block],
        out_specs=[block, block, block, block],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), p.dtype),
            jax.ShapeDtypeStruct((rows, LANES), mu.dtype),
            jax.ShapeDtypeStruct((rows, LANES), nu.dtype),
            jax.ShapeDtypeStruct((rows, LANES), r.dtype),
        ],
        interpret=interpret,
    )(s, p2, g2, mu2, nu2, r2)
    return tuple(_untile(o, n, shape) for o in outs)  # type: ignore
