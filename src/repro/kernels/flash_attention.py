"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Needed by every train/prefill cell and by the sliding-window layers of
gemma3 / mixtral / recurrentgemma. The XLA fallback in models/attention.py
(`_chunked_core`) cannot skip fully-masked causal tiles — this kernel does,
via the innermost grid dimension + @pl.when, so causal attention performs
~S^2/2 work and sliding-window attention O(S * window).

Grid: (batch*heads, n_q_blocks, n_kv_blocks), innermost (kv) sequential on
TPU. Scratch (m, l, acc) persists across the kv dimension in VMEM; the
output tile is written once, on the last contributing kv block. Tiles are
MXU-aligned: (block_q, head_dim) x (block_k, head_dim) with head_dim padded
to a multiple of 128 by the wrapper (ops.flash_attention).

Masking: positions are derived from block indices (q_offset supports
prefill-against-cache); the mask is applied only on DIAGONAL blocks —
interior blocks are mask-free (this is what makes flash fast on TPU, where
branch-free full tiles hit the MXU at full rate).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, bq, dh), (1, bk, dh), (1, bk, dh)
    o_ref,  # (1, bq, dh)
    m_ref, l_ref, acc_ref,  # VMEM scratch: (bq, 1), (bq, 1), (bq, dh)
    *,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    seq_k: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    sm_scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k

    # -- does this kv block contribute at all? (static per (qi, ki) shape,
    #    dynamic value — pl.when guards the compute)
    first_q = q_start
    last_q = q_start + block_q - 1
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= last_q  # block not entirely in the future
    if window is not None:
        relevant &= k_start + block_k - 1 > first_q - window  # not all stale
    relevant &= k_start < seq_k  # not entirely padding

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T * sm_scale  # (bq, bk)

        # mask only where the block straddles a boundary
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        allow = k_pos < seq_k  # tail padding
        if causal:
            allow &= k_pos <= q_pos
        if window is not None:
            allow &= q_pos - k_pos < window
        s = jnp.where(allow, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + p @ v

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "sm_scale",
        "interpret",
    ),
)
def flash_attention_bhsd(
    q: jax.Array,  # (BH, Sq, Dh) — batch*heads flattened, Dh % 128 == 0
    k: jax.Array,  # (BH, Skv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    sm_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call on pre-flattened, pre-padded operands.

    Use ops.flash_attention for the (B, S, H, Dh) convenience wrapper that
    pads Dh/Sq/Skv and restores shapes.
    """
    bh, sq, dh = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    n_q, n_k = sq // block_q, skv // block_k
    if sm_scale is None:
        sm_scale = 1.0 / float(dh) ** 0.5

    kern = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        q_offset=q_offset,
        seq_k=skv,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
        sm_scale=sm_scale,
    )
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=_scratch(block_q, dh),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q: int, dh: int):
    """Online-softmax carry (m, l, acc) in VMEM, persistent across the
    innermost (kv) grid dimension."""
    from jax.experimental.pallas import tpu as pltpu

    return [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, dh), jnp.float32),
    ]
