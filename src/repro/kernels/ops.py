"""Public jit'd wrappers around the Pallas kernels.

Platform dispatch: on TPU the real kernels run; elsewhere they execute in
``interpret=True`` mode (the body runs in Python on CPU — this is how the
sweep tests validate them) or, for the convenience entry points, fall back
to the pure-jnp ``ref`` oracles when ``interpret`` would be too slow at the
call site's scale.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.fused_adam import adam_sig_update, adam_update
from repro.kernels.significance import significance_filter


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    return (not on_tpu()) if interpret is None else interpret


# ---- significance ---------------------------------------------------------------


def significance(
    u: jax.Array,
    x: jax.Array,
    r: jax.Array,
    v_t,
    floor: float = 1e-8,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused ISP filter on one tensor: (sig, new_residual)."""
    return significance_filter(
        u, x, r, jnp.asarray(v_t, jnp.float32), floor=floor,
        interpret=_auto_interpret(interpret),
    )


def significance_tree(updates, params, residual, v_t, floor: float = 1e-8):
    """Pytree version (what the ISP train step calls on TPU)."""
    if on_tpu():
        out = jax.tree.map(
            lambda u, x, r: significance(u, x, r, v_t, floor),
            updates, params, residual,
        )
    else:  # pure-jnp oracle: interpret-mode is too slow for full models
        out = jax.tree.map(
            lambda u, x, r: ref.significance_ref(u, x, r, v_t, floor),
            updates, params, residual,
        )
    treedef = jax.tree.structure(params)
    leaves = treedef.flatten_up_to(out)
    sig = treedef.unflatten([l[0] for l in leaves])
    res = treedef.unflatten([l[1] for l in leaves])
    return sig, res


# ---- flash attention -------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, H, Dh)  (repeat GQA KV to H before calling)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(B, S, H, Dh) flash attention; pads Dh to 128 and Sq/Skv to blocks."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    dh_pad = (-dh) % 128
    sq_pad = (-sq) % block_q
    sk_pad = (-skv) % block_k

    def pad(t, s_pad):
        return jnp.pad(t, ((0, 0), (0, s_pad), (0, 0), (0, dh_pad)))

    qp, kp, vp = pad(q, sq_pad), pad(k, sk_pad), pad(v, sk_pad)
    # (B, S, H, D) -> (B*H, S, D)
    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(
            b * h, t.shape[1], dh + dh_pad
        )

    out = flash_attention_bhsd(
        fold(qp), fold(kp), fold(vp),
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        sm_scale=1.0 / float(dh) ** 0.5,  # true (pre-padding) head dim
        interpret=_auto_interpret(interpret),
    )
    out = out.reshape(b, h, sq + sq_pad, dh + dh_pad).transpose(0, 2, 1, 3)
    return out[:, :sq, :, :dh]


# ---- fused optimizers --------------------------------------------------------------


def fused_adam(
    p, g, mu, nu, lr, step,
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
    interpret: Optional[bool] = None,
):
    return adam_update(
        p, g, mu, nu, lr, step, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, interpret=_auto_interpret(interpret),
    )


def fused_adam_sig(
    p, g, mu, nu, r, lr, step, v_t,
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    floor: float = 1e-8,
    interpret: Optional[bool] = None,
):
    return adam_sig_update(
        p, g, mu, nu, r, lr, step, v_t, b1=b1, b2=b2, eps=eps, floor=floor,
        interpret=_auto_interpret(interpret),
    )
