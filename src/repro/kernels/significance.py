"""Pallas TPU kernel: fused ISP significance filter (the paper's hot path).

MLLess hand-Cythonized exactly this per-parameter loop (§5 of the paper:
"we reimplemented part of PyWren-IBM's runtime ... in Cython"). The TPU
adaptation is a single VMEM pass:

    acc  = r + u                      (residual accumulate)
    mask = |acc| > v_t * max(|x|, f)  (significance test, Theorem 1 form)
    sig  = acc * mask                 (communicated part)
    r'   = acc * (1 - mask)           (error-feedback residual)

A naive jnp composition reads/writes each of the three operands into HBM
per intermediate (acc, |x|, mask, sig, r': >= 8 tensor passes); the fused
kernel streams one (block_rows, 128*k) tile of u/x/r through VMEM and
writes sig/r' — 3 reads + 2 writes total, the elementwise-roofline minimum.

Layout: inputs are flattened and padded to (rows, LANES) tiles; the grid
walks row blocks. v_t arrives as a (1, 1) scalar block so the same compiled
kernel serves every step of the decaying v_t = v / sqrt(t) schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128  # TPU vector lane width
SUBLANES = 8  # fp32 sublane height
DEFAULT_BLOCK_ROWS = 256  # (256, 128) fp32 tile = 128 KiB/operand in VMEM


def _sig_kernel(vt_ref, u_ref, x_ref, r_ref, sig_ref, res_ref, *, floor):
    """One (block_rows, LANES) tile: accumulate, test, split."""
    v_t = vt_ref[0, 0]
    acc = r_ref[...].astype(jnp.float32) + u_ref[...].astype(jnp.float32)
    denom = jnp.maximum(jnp.abs(x_ref[...].astype(jnp.float32)), floor)
    mask = jnp.abs(acc) > v_t * denom
    sig_ref[...] = jnp.where(mask, acc, 0.0).astype(sig_ref.dtype)
    res_ref[...] = jnp.where(mask, 0.0, acc).astype(res_ref.dtype)


def _pad_to_tiles(flat: jax.Array, block_rows: int) -> tuple[jax.Array, int]:
    n = flat.shape[0]
    tile = block_rows * LANES
    pad = (-n) % tile
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(
    jax.jit, static_argnames=("floor", "block_rows", "interpret")
)
def significance_filter(
    u: jax.Array,
    x: jax.Array,
    r: jax.Array,
    v_t: jax.Array,
    *,
    floor: float = 1e-8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused filter over an arbitrary-shaped tensor.

    Args:
      u: this step's update (any shape).
      x: current parameter values (same shape).
      r: carried residual (same shape).
      v_t: scalar significance threshold.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      (sig, new_residual) with sig + new_residual == r + u.
    """
    shape, dtype = u.shape, u.dtype
    u2, n = _pad_to_tiles(u.reshape(-1), block_rows)
    x2, _ = _pad_to_tiles(x.reshape(-1), block_rows)
    r2, _ = _pad_to_tiles(r.reshape(-1), block_rows)
    rows = u2.shape[0]
    grid = (rows // block_rows,)
    vt_arr = jnp.asarray(v_t, jnp.float32).reshape(1, 1)

    out_shape = [
        jax.ShapeDtypeStruct((rows, LANES), dtype),
        jax.ShapeDtypeStruct((rows, LANES), r.dtype),
    ]
    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sig2, res2 = pl.pallas_call(
        functools.partial(_sig_kernel, floor=floor),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # v_t scalar tile
            block,
            block,
            block,
        ],
        out_specs=[block, block],
        out_shape=out_shape,
        interpret=interpret,
    )(vt_arr, u2, x2, r2)
    sig = sig2.reshape(-1)[:n].reshape(shape)
    res = res2.reshape(-1)[:n].reshape(shape)
    return sig, res
