"""repro.wire — the single update-encoding codec layer (DESIGN.md §10).

One codec registry (dense / sparse-index / bitmap, optional fp16/bf16
value quantization with fp32 error-feedback residual), zero-copy
memoryview framing, persistent connections, and exact per-leaf byte
accounting.  ``dist.compression``, ``runtime.protocol`` and the
simulator's cost model (``core.simulator`` / ``core.billing``) all read
bytes through here, so simulated bytes == measured bytes by construction.

    codec   — leaf/tree encode/decode, sizing formulas, quantization
    framing — length-prefixed messages, vectored send, the Transport
              seam (make_transport) and its TCP Connection
    shm     — shared-memory ring-buffer Transport (same-host zero-copy
              update path, DESIGN.md §12)
"""

from repro.wire.codec import (  # noqa: F401
    AUTO,
    IMPLS,
    INT32_MAX,
    PALLAS_AUTO_MIN_N,
    QUANTS,
    SCHEMES,
    best_scheme,
    decode_add_leaf,
    decode_leaf,
    decode_tree,
    encode_leaf,
    encode_tree,
    encode_tree_parts,
    index_dtype,
    index_itemsize,
    leaf_nbytes,
    mask_nbytes,
    pallas_ok,
    predict_leaf_nbytes,
    predict_tree_nbytes,
    quant_dtype,
    resolve_impl,
    tree_keys,
    tree_nbytes,
)
from repro.wire.framing import (  # noqa: F401
    MAX_MSG_BYTES,
    TRANSPORTS,
    Connection,
    Transport,
    make_transport,
    pack_parts,
    pipelined,
    recv_msg,
    request,
    send_msg,
    unpack_parts,
)
