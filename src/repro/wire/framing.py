"""Message framing + persistent connections (DESIGN.md §10).

Every message on the FaaS data path is::

    uint32 header_len | uint32 payload_len | header JSON (utf-8) | payload

The payload may be handed to ``send_msg`` as bytes OR as a list of buffer
views (what ``wire.codec.encode_tree_parts`` produces): the vectored form
goes out through one ``socket.sendmsg`` scatter-gather call — the encoded
leaf arrays are never copied into a joined blob.

``Connection`` is the persistent client channel that replaced the
one-shot connect-per-RPC pattern: a worker opens ONE socket to the broker
for the life of its invocation and runs every request/response round trip
over it (the broker's handler loops on the same socket).  A broken
connection reconnects transparently and retries once — every broker
operation is idempotent (publishes are dup-checked by digest, pulls are
reads), so an ambiguous failure mid-round-trip is safe to replay.
"""

from __future__ import annotations

import json
import os
import socket
import struct
from typing import Optional, Protocol, Union, runtime_checkable

_HDR = struct.Struct("<II")
MAX_MSG_BYTES = 1 << 31  # sanity bound on a single message

Payload = Union[bytes, bytearray, memoryview, list]

# -- fault-injection seam (runtime/faults.py, DESIGN.md §17) ------------------
#
# A process-global hook called at the client-side transport boundary:
# ``hook(side, header)`` with side in {"send", "recv"} immediately before
# the corresponding half of a round trip.  The hook may sleep (frame
# delay / stall) or raise ConnectionError (connection reset) — raising
# lands inside the transports' existing reconnect-and-replay path, so an
# injected reset exercises the REAL recovery machinery.  ``None`` (the
# default) costs one attribute load per call and nothing else: the
# default path stays byte-identical with the hook dormant.

_chaos_hook = None


def install_chaos_hook(fn) -> None:
    global _chaos_hook
    _chaos_hook = fn


def clear_chaos_hook() -> None:
    global _chaos_hook
    _chaos_hook = None


def chaos(side: str, header: dict) -> None:
    hook = _chaos_hook
    if hook is not None:
        hook(side, header)


def _as_views(payload: Payload) -> list[memoryview]:
    parts = payload if isinstance(payload, list) else [payload]
    return [memoryview(p).cast("B") for p in parts if len(p)]


try:
    _iov = int(os.sysconf("SC_IOV_MAX"))  # -1 = indeterminate (POSIX)
    _IOV_MAX = min(_iov, 1024) if _iov > 0 else 1024
except (AttributeError, ValueError, OSError):  # pragma: no cover
    _IOV_MAX = 1024


def _sendall_vectored(sock: socket.socket, bufs: list[memoryview]) -> None:
    """sendall over a list of buffers without joining them.

    Chunked to the kernel's IOV_MAX — one sendmsg over a deep pytree's
    thousands of leaf views would fail with EMSGSIZE.
    """
    bufs = list(bufs)
    while bufs:
        try:
            n = sock.sendmsg(bufs[:_IOV_MAX])
        except AttributeError:  # pragma: no cover - platforms without sendmsg
            sock.sendall(b"".join(bufs))
            return
        while n:
            if n >= len(bufs[0]):
                n -= len(bufs[0])
                bufs.pop(0)
            else:
                bufs[0] = bufs[0][n:]
                n = 0


def send_msg(sock: socket.socket, header: dict, payload: Payload = b"") -> int:
    """Write one framed message; returns total bytes on the wire."""
    views = _as_views(payload)
    plen = sum(len(v) for v in views)
    raw = json.dumps(header, separators=(",", ":")).encode("utf-8")
    _sendall_vectored(
        sock, [memoryview(_HDR.pack(len(raw), plen)), memoryview(raw), *views]
    )
    return _HDR.size + len(raw) + plen


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-message")
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[dict, bytes]:
    """Read one framed message → (header, payload)."""
    hlen, plen = _HDR.unpack(_recv_exact(sock, _HDR.size))
    if hlen > MAX_MSG_BYTES or plen > MAX_MSG_BYTES:
        raise ValueError(f"oversized message header ({hlen}, {plen})")
    header = json.loads(_recv_exact(sock, hlen).decode("utf-8"))
    payload = _recv_exact(sock, plen) if plen else b""
    return header, payload


def request(
    addr: tuple[str, int],
    header: dict,
    payload: Payload = b"",
    timeout: float = 30.0,
) -> tuple[dict, bytes]:
    """One-shot RPC round trip: connect, send, receive, close.

    Kept for rare, cold callers (CLI debugging); the hot path uses
    ``Connection``.
    """
    with socket.create_connection(addr, timeout=timeout) as sock:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_msg(sock, header, payload)
        return recv_msg(sock)


@runtime_checkable
class Transport(Protocol):
    """The pluggable client channel of the update path (DESIGN.md §12.1).

    One persistent request/response stream to one broker shard; strictly
    one outstanding request.  ``Connection`` (TCP) and ``shm.
    ShmConnection`` (shared memory) both implement it, and everything
    above the seam — ``pipelined``, the workers' retry loops, the
    supervisor's RPC — is written against this surface only.  The
    contract every implementation honours:

    * ``request`` retries once through a transparent reconnect; all
      broker ops are idempotent, so an ambiguous mid-round-trip failure
      is safe to replay;
    * ``send_only``/``recv_response`` split one round trip for the
      multi-shard fan-out;
    * failures surface as ``ConnectionError``/``OSError``/
      ``TimeoutError`` — never transport-specific types — so callers'
      retry windows are transport-agnostic.
    """

    def request(self, header: dict, payload: Payload = b"",
                timeout: Optional[float] = None) -> tuple[dict, bytes]: ...

    def send_only(self, header: dict, payload: Payload = b"",
                  timeout: Optional[float] = None) -> None: ...

    def recv_response(self, timeout: Optional[float] = None
                      ) -> tuple[dict, bytes]: ...

    def close(self) -> None: ...


TRANSPORTS = ("tcp", "shm")


def make_transport(
    kind: str,
    addr: Optional[tuple[str, int]] = None,
    shm_name: Optional[str] = None,
    timeout: float = 30.0,
) -> "Transport":
    """Transport factory: the ONE place a transport name becomes a
    channel.  ``tcp`` needs ``addr``; ``shm`` needs ``shm_name`` (the
    per-(worker, shard) segment the supervisor allocated)."""
    if kind == "tcp":
        if addr is None:
            raise ValueError("tcp transport requires addr=(host, port)")
        return Connection(addr, timeout=timeout)
    if kind == "shm":
        if shm_name is None:
            raise ValueError("shm transport requires shm_name")
        from repro.wire.shm import ShmConnection  # lazy: Linux-only bits

        return ShmConnection(shm_name, timeout=timeout)
    raise ValueError(f"unknown transport {kind!r}; known: {TRANSPORTS}")


class Connection:
    """Persistent framed request/response channel (client side, TCP).

    One TCP connection, any number of sequential round trips.  On a
    connection failure the request is retried once over a fresh socket
    (idempotent server ops make the replay safe); a second failure
    propagates to the caller.
    """

    def __init__(self, addr: tuple[str, int], timeout: float = 30.0):
        self.addr = (addr[0], int(addr[1]))
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.addr, timeout=self.timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        return sock

    def request(
        self,
        header: dict,
        payload: Payload = b"",
        timeout: Optional[float] = None,
    ) -> tuple[dict, bytes]:
        last: Optional[Exception] = None
        for attempt in range(2):
            sock = self._sock
            try:
                if sock is None:
                    sock = self._connect()
                sock.settimeout(timeout if timeout is not None
                                else self.timeout)
                chaos("send", header)
                send_msg(sock, header, payload)
                chaos("recv", header)
                return recv_msg(sock)
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
                self.close()
        assert last is not None
        raise last

    # -- pipelined half-operations (multi-shard fan-out) ----------------------
    #
    # ``send_only`` + ``recv_response`` split one round trip so a client
    # talking to N servers can send N requests before waiting for any
    # response — per-server latency (scheduling wakeups, WAL flushes, long
    # polls) then overlaps instead of summing.  Strictly one outstanding
    # request per connection; ``pipelined`` is the safe composition.

    def send_only(
        self, header: dict, payload: Payload = b"",
        timeout: Optional[float] = None,
    ) -> None:
        """Write one request without reading the response (reconnects and
        resends once on failure — server ops are idempotent)."""
        for attempt in range(2):
            sock = self._sock
            try:
                if sock is None:
                    sock = self._connect()
                sock.settimeout(timeout if timeout is not None
                                else self.timeout)
                chaos("send", header)
                send_msg(sock, header, payload)
                return
            except (ConnectionError, OSError, TimeoutError):
                self.close()
                if attempt:
                    raise

    def recv_response(
        self, timeout: Optional[float] = None
    ) -> tuple[dict, bytes]:
        """Read the response of the request ``send_only`` put in flight."""
        if self._sock is None:
            raise ConnectionError("no in-flight request on this connection")
        self._sock.settimeout(timeout if timeout is not None
                              else self.timeout)
        try:
            chaos("recv", {})
            return recv_msg(self._sock)
        except (ConnectionError, OSError, TimeoutError):
            self.close()  # never leave a half-read stream behind
            raise

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self) -> "Connection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def pipelined(
    conns: list["Transport"],
    messages: list[tuple[dict, Payload]],
    timeout: Optional[float] = None,
) -> list[tuple[dict, bytes]]:
    """One round trip to N servers, overlapped: send every request, then
    collect every response.  Works over ANY ``Transport`` mix.  A channel
    that fails either half falls back to a fresh sequential ``request``
    (idempotent servers make the replay safe), so the result is
    positionally complete or raises.
    """
    results: list[Optional[tuple[dict, bytes]]] = [None] * len(conns)
    failed: list[int] = []
    for i, (conn, (header, payload)) in enumerate(zip(conns, messages)):
        try:
            conn.send_only(header, payload, timeout=timeout)
        except (ConnectionError, OSError, TimeoutError):
            failed.append(i)
    for i, conn in enumerate(conns):
        if i in failed:
            continue
        try:
            results[i] = conn.recv_response(timeout=timeout)
        except (ConnectionError, OSError, TimeoutError):
            failed.append(i)
    for i in failed:
        conns[i].close()  # force a clean socket for the replay
        header, payload = messages[i]
        results[i] = conns[i].request(header, payload, timeout=timeout)
    return results  # type: ignore[return-value]


# -- multi-part payloads (coalesced pull responses) ---------------------------


def pack_parts(parts: list[tuple[dict, Payload]]) -> tuple[list[dict], list]:
    """Coalesce several (descriptor, payload) pairs into one message.

    Returns (descriptors, flat buffer list) — the buffer list feeds
    ``send_msg`` directly (no join).  Each descriptor gains an ``nbytes``
    so the peer can slice the concatenated payload back apart.
    """
    descs = []
    bufs: list = []
    for desc, blob in parts:
        views = _as_views(blob)
        d = dict(desc)
        d["nbytes"] = sum(len(v) for v in views)
        descs.append(d)
        bufs.extend(views)
    return descs, bufs


def unpack_parts(
    descs: list[dict], payload: Payload
) -> list[tuple[dict, memoryview]]:
    view = memoryview(payload if not isinstance(payload, list)
                      else b"".join(payload)).cast("B")
    out = []
    off = 0
    for d in descs:
        n = int(d["nbytes"])
        out.append((d, view[off : off + n]))
        off += n
    if off != len(view):
        raise ValueError(
            f"trailing bytes in multi-part payload: {len(view) - off}"
        )
    return out
