"""Unified update-encoding codec — ONE byte-accounting truth (DESIGN.md §10).

Every layer that moves a significance-filtered update — the live FaaS data
path (``runtime.protocol``), the compressed pod collectives
(``dist.compression``), and the simulator's communication cost model
(``core.simulator`` / ``core.billing``) — encodes and *accounts* through
this module.  The invariant the whole cost story rests on:

    simulated bytes == measured bytes, by construction.

``leaf_nbytes`` is the single sizing formula; ``encode_leaf`` asserts its
output length against it on every call, so the auto-tuner can never again
tune against a cost model the runtime doesn't obey.

Schemes (per leaf):

* ``dense``  — raw value bytes, ``n * itemsize``;
* ``sparse`` — flat indices + values, ``nnz * (idx_itemsize + itemsize)``
  (int32 indices, int64 when the leaf has >= 2**31 elements);
* ``bitmap`` — little-endian packed significance mask + values,
  ``ceil(n/8) + nnz * itemsize`` — the paper's Redis sparse encoding;
* ``auto``   — whichever of the three is smallest for this leaf
  (ties prefer sparse, then bitmap).

Value quantization (``quant``): ``fp16`` / ``bf16`` halve the value bytes
of floating leaves; the quantization error is returned as an fp32
error-feedback residual (``encode_leaf(..., with_residual=True)``) so no
update mass is lost — the same conservation discipline as the ISP filter
itself.  Non-float leaves pass through unquantized.

Decode is bit-exact: ``decode(encode(x)) == x`` without quantization, and
``decode(encode(x)) == dequant(quant(x))`` with it (asserted by
``tests/test_wire_codec.py`` across schemes x dtypes x edge shapes).

Only numpy at module import — jax is imported lazily inside the tree
helpers so worker cold-start (a measured FaaS cost) stays light.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np

try:  # bf16 rides ml_dtypes (a jax dependency); degrade gracefully without
    import ml_dtypes

    _BF16: Optional[np.dtype] = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - ml_dtypes ships with jax
    _BF16 = None

PyTree = Any

SCHEMES = ("dense", "sparse", "bitmap")
AUTO = "auto"
QUANTS = ("none", "fp16", "bf16")

INT32_MAX = 2**31 - 1  # flat-index overflow bound (satellite guard)

# -- encoder implementations (DESIGN.md §15) ----------------------------------
# 'numpy' is the reference (and the default: tiny leaves lose to kernel
# dispatch overhead); 'pallas' routes through the fused wire-pack kernel
# (kernels.wire_pack — one device pass: mask-pack + quantize + compact +
# residual); 'auto' picks pallas for leaves big enough to amortize the
# launch.  Every impl produces BIT-IDENTICAL wire bytes, metas and
# residuals (property-tested in tests/test_wire_pack.py and gated live by
# benchmarks/wire_guard.py --impl pallas), so impl is a pure perf knob —
# accounting, digests and replay never depend on it.
IMPLS = ("numpy", "pallas", "auto")
PALLAS_AUTO_MIN_N = 1 << 15  # one full (256, 128) tile
# dtypes the kernel path accepts: jax must round-trip them losslessly
# (int64/f64 would be silently downcast under the default x64=off)
_PALLAS_DTYPES = frozenset(("float32", "float16", "bfloat16", "int32"))
# fused decode/apply additionally requires the accumulate to round
# identically to numpy's += — exact dtypes only (f16 adds may double-round
# differently between BLAS paths, so they take the decode-then-add route)
_PALLAS_ADD_DTYPES = frozenset(("float32", "int32"))


def _interpret() -> bool:
    """Pallas interpret mode: on for every backend without a real TPU
    (the CPU CI path); computed lazily so importing the codec never
    initializes a jax backend."""
    import jax

    return jax.default_backend() != "tpu"


def pallas_ok(n: int, dtype: Any, quant: str = "none") -> bool:
    """Can the fused kernel path encode this leaf bit-identically?"""
    dt = np.dtype(dtype)
    return (
        0 < n <= INT32_MAX
        and dt.name in _PALLAS_DTYPES
        and quant_dtype(dt, quant).name in _PALLAS_DTYPES
    )


def resolve_impl(impl: str, n: int, dtype: Any, quant: str = "none") -> str:
    """'auto'/'pallas' -> the impl actually used for this leaf (falls back
    to numpy when the kernel can't hold bit-identity for it)."""
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "numpy" or not pallas_ok(n, dtype, quant):
        return "numpy"
    if impl == "auto" and (n < PALLAS_AUTO_MIN_N or _interpret()):
        # auto is a PERF policy: small leaves lose to numpy on fixed
        # dispatch cost, and interpret-mode kernels (no TPU attached) lose
        # at every size (measured: benchmarks/encode_bench.py) — explicit
        # impl='pallas' still runs them, as the bit-identity validation leg
        return "numpy"
    return "pallas"


# -- sizing: the one formula every layer reads --------------------------------


def index_itemsize(n: int) -> int:
    """Bytes per flat index for an ``n``-element leaf (int32 until 2**31)."""
    return 4 if n <= INT32_MAX else 8


def index_dtype(n: int) -> np.dtype:
    """int32 flat indices, widened to int64 for leaves with >= 2**31
    elements — int32 would wrap silently and scatter updates into the
    wrong coordinates."""
    return np.dtype(np.int32 if n <= INT32_MAX else np.int64)


def mask_nbytes(n: int) -> int:
    """Bytes of the packed significance bitmap for an ``n``-element leaf."""
    return (n + 7) // 8


def quant_dtype(dtype: Any, quant: str = "none") -> np.dtype:
    """Wire value dtype for a leaf dtype under a quantization mode.

    Only floating leaves quantize; integer/bool leaves pass through.
    """
    dt = np.dtype(dtype)
    if quant not in QUANTS:
        raise ValueError(f"quant must be one of {QUANTS}, got {quant!r}")
    if quant == "none" or dt.kind != "f":
        return dt
    if quant == "fp16":
        return np.dtype(np.float16)
    if _BF16 is None:  # pragma: no cover
        raise RuntimeError("bf16 quantization requires ml_dtypes")
    return _BF16


def leaf_nbytes(scheme: str, n: int, nnz, itemsize: int = 4):
    """Wire bytes of one encoded leaf. THE sizing formula.

    ``nnz`` may be a python number or a traced jax scalar (the compressed
    pod collective accounts inside jit) — only ``+``/``*`` touch it.
    """
    if scheme == "dense":
        return n * itemsize
    if scheme == "sparse":
        return nnz * (index_itemsize(n) + itemsize)
    if scheme == "bitmap":
        return mask_nbytes(n) + nnz * itemsize
    raise ValueError(f"scheme must be one of {SCHEMES}, got {scheme!r}")


def best_scheme(n: int, nnz: int, itemsize: int = 4) -> str:
    """The ``auto`` resolution: smallest encoding for this leaf
    (ties prefer sparse, then bitmap — sparse decodes cheapest)."""
    order = ("sparse", "bitmap", "dense")
    sizes = {s: leaf_nbytes(s, n, nnz, itemsize) for s in order}
    return min(order, key=lambda s: sizes[s])


# -- leaf encode / decode -----------------------------------------------------


def encode_leaf(
    arr: Any,
    scheme: str = AUTO,
    quant: str = "none",
    key: Optional[str] = None,
    with_residual: bool = False,
    impl: str = "numpy",
) -> tuple[dict, list, Optional[np.ndarray]]:
    """Encode one array -> (meta, buffer parts, optional fp32 residual).

    ``parts`` is a list of read-only byte views over freshly materialized
    arrays (zero extra copies; the views keep their bases alive) — hand it
    straight to the vectored framing layer, or ``b"".join`` it.

    ``meta``: k, shape, dtype, enc, nnz, nbytes (+ ``q`` when values are
    quantized, ``idx: 'int64'`` when indices widened).  ``nbytes`` is
    asserted equal to ``leaf_nbytes`` — accounting can never drift from
    the bytes actually produced.

    With ``with_residual=True`` the third element is the fp32
    quantization error (``arr - decode(encode(arr))``), zeros when
    nothing was lost.

    ``impl`` selects the encoder implementation (module constants above):
    'numpy' (reference, default), 'pallas' (fused kernel), or 'auto'
    (kernel for leaves past ``PALLAS_AUTO_MIN_N``).  Bytes, meta and
    residual are bit-identical across impls.
    """
    a = np.asarray(arr)
    dt = a.dtype
    vdt = quant_dtype(dt, quant)
    flat = np.ascontiguousarray(a).reshape(-1)
    n = int(flat.size)
    if resolve_impl(impl, n, dt, quant) == "pallas":
        return _encode_leaf_pallas(
            a, flat, scheme=scheme, quant=quant, key=key,
            with_residual=with_residual,
        )
    nz = np.flatnonzero(flat)
    nnz = int(nz.size)
    if scheme == AUTO:
        scheme = best_scheme(n, nnz, vdt.itemsize)
    meta: dict = {
        "k": key,
        "shape": list(a.shape),
        "dtype": str(dt),
        "enc": scheme,
        "nnz": nnz,
    }
    if vdt != dt:
        meta["q"] = quant
    parts: list = []
    if scheme == "dense":
        qvals = flat if vdt == dt else flat.astype(vdt)
        parts = [_byte_view(qvals)]
    elif scheme == "sparse":
        idt = index_dtype(n)
        if idt != np.int32:
            meta["idx"] = str(idt)
        qvals = flat[nz].astype(vdt)
        parts = [_byte_view(nz.astype(idt)), _byte_view(qvals)]
    elif scheme == "bitmap":
        mask = np.packbits(flat != 0, bitorder="little")
        qvals = flat[nz].astype(vdt)
        parts = [_byte_view(mask), _byte_view(qvals)]
    else:
        raise ValueError(f"scheme must be one of {SCHEMES}, got {scheme!r}")
    nbytes = sum(len(p) for p in parts)
    expect = leaf_nbytes(scheme, n, nnz, vdt.itemsize)
    assert nbytes == expect, (nbytes, expect, meta)  # the §10 invariant
    meta["nbytes"] = nbytes
    residual = None
    if with_residual:
        # quantization error directly from the materialized wire values —
        # zero off the nnz support, so no decode round trip is needed
        if vdt == dt:
            residual = np.zeros(a.shape, np.float32)
        elif scheme == "dense":
            residual = (
                flat.astype(np.float32) - qvals.astype(np.float32)
            ).reshape(a.shape)
        else:
            rflat = np.zeros(n, np.float32)
            rflat[nz] = (
                flat[nz].astype(np.float32) - qvals.astype(np.float32)
            )
            residual = rflat.reshape(a.shape)
    return meta, parts, residual


def _encode_leaf_pallas(
    a: np.ndarray,
    flat: np.ndarray,
    scheme: str,
    quant: str,
    key: Optional[str],
    with_residual: bool,
) -> tuple[dict, list, Optional[np.ndarray]]:
    """Fused-kernel encode: ONE device pass emits the packed mask bytes,
    quantized values (dense + front-compacted), flat indices, nnz and the
    error-feedback residual (kernels.wire_pack); this host shim only
    slices the first ``nnz`` wire values and builds the same meta/parts
    the numpy body produces — asserted against the same ``leaf_nbytes``
    formula, byte-for-byte interchangeable with it."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import wire_pack as _wp

    dt = a.dtype
    vdt = quant_dtype(dt, quant)
    n = int(flat.size)
    out = _wp.wire_pack(
        jnp.asarray(flat),
        vdt=np.dtype(vdt),
        block_rows=_wp.pick_block_rows(n),
        interpret=_interpret(),
    )
    mask, qdense, cvals, cidx, nnz_a, res = jax.device_get(out)
    nnz = int(nnz_a)
    if scheme == AUTO:
        scheme = best_scheme(n, nnz, vdt.itemsize)
    meta: dict = {
        "k": key,
        "shape": list(a.shape),
        "dtype": str(dt),
        "enc": scheme,
        "nnz": nnz,
    }
    if vdt != dt:
        meta["q"] = quant
    if scheme == "dense":
        parts = [_byte_view(qdense)]
    elif scheme == "sparse":
        # the kernel path is gated to n <= INT32_MAX, so indices are int32
        parts = [_byte_view(cidx[:nnz]), _byte_view(cvals[:nnz])]
    elif scheme == "bitmap":
        parts = [_byte_view(mask), _byte_view(cvals[:nnz])]
    else:
        raise ValueError(f"scheme must be one of {SCHEMES}, got {scheme!r}")
    nbytes = sum(len(p) for p in parts)
    expect = leaf_nbytes(scheme, n, nnz, vdt.itemsize)
    assert nbytes == expect, (nbytes, expect, meta)  # the §10 invariant
    meta["nbytes"] = nbytes
    residual = None
    if with_residual:
        # the kernel's residual is f32(x) - f32(quant(x)), which is zero
        # off the nnz support by IEEE subtraction — identical to the numpy
        # body for every scheme; vdt == dt short-circuits to exact zeros
        # (inf - inf in the kernel form would manufacture NaNs)
        if vdt == dt:
            residual = np.zeros(a.shape, np.float32)
        else:
            residual = np.asarray(res).reshape(a.shape)
    return meta, parts, residual


def _byte_view(arr: np.ndarray):
    """Read-only byte view over a C-contiguous array (keeps it alive).

    Views through uint8 because extension dtypes (ml_dtypes bf16) don't
    export the buffer protocol directly.
    """
    a = np.ascontiguousarray(arr)
    return a.view(np.uint8).reshape(-1).data.cast("B")


def decode_leaf(meta: dict, blob, impl: str = "numpy") -> np.ndarray:
    """Decode one leaf's bytes back into an array of its original dtype.

    Quantized values are widened back (``dequant(quant(x))`` — bit-exact
    against what the encoder saw post-quantization).

    ``impl='pallas'``/'auto' routes bitmap-encoded leaves through the
    fused unpack kernel (dense/sparse stay numpy: they are already a
    single ``frombuffer``/scatter pass).  Bit-identical across impls.
    """
    shape = tuple(meta["shape"])
    dt = np.dtype(meta["dtype"])
    vdt = quant_dtype(dt, meta.get("q", "none"))
    n = int(np.prod(shape)) if shape else 1
    enc = meta["enc"]
    nnz = int(meta["nnz"])
    if enc == "bitmap" and resolve_impl(
        impl, n, dt, meta.get("q", "none")
    ) == "pallas":
        return _unpack_pallas(None, meta, blob).reshape(shape)
    if enc == "dense":
        vals = np.frombuffer(blob, dtype=vdt, count=n)
        return (vals if vdt == dt else vals.astype(dt)).reshape(shape)
    if enc == "sparse":
        idt = np.dtype(meta.get("idx", "int32"))
        idx = np.frombuffer(blob, dtype=idt, count=nnz)
        vals = np.frombuffer(
            blob, dtype=vdt, offset=nnz * idt.itemsize, count=nnz
        )
        out = np.zeros(n, dtype=dt)
        out[idx] = vals.astype(dt)
        return out.reshape(shape)
    if enc == "bitmap":
        mb = mask_nbytes(n)
        mask = np.unpackbits(
            np.frombuffer(blob, dtype=np.uint8, count=mb),
            count=n,
            bitorder="little",
        ).astype(bool)
        vals = np.frombuffer(blob, dtype=vdt, offset=mb, count=nnz)
        out = np.zeros(n, dtype=dt)
        out[mask] = vals.astype(dt)
        return out.reshape(shape)
    raise ValueError(f"unknown leaf encoding {enc!r}")


def _unpack_pallas(
    target: Optional[np.ndarray], meta: dict, blob
) -> np.ndarray:
    """Fused bitmap decode(+apply): one kernel scatter of the received
    ``(mask, values)`` pair into ``target`` (zeros when decoding only).

    The compact values are padded host-side to a power-of-two capacity so
    the gather shape is static — a step whose nnz drifts reuses the same
    compiled kernel instead of paying a recompile per nnz."""
    import jax.numpy as jnp

    from repro.kernels import wire_pack as _wp

    shape = tuple(meta["shape"])
    dt = np.dtype(meta["dtype"])
    vdt = quant_dtype(dt, meta.get("q", "none"))
    n = int(np.prod(shape)) if shape else 1
    nnz = int(meta["nnz"])
    mb = mask_nbytes(n)
    mask = np.frombuffer(blob, dtype=np.uint8, count=mb)
    vals = np.frombuffer(blob, dtype=vdt, offset=mb, count=nnz)
    cap = 1 << max(nnz - 1, 0).bit_length()
    cpad = np.zeros(cap, vdt)
    cpad[:nnz] = vals
    if target is None:
        target = np.zeros(n, dt)
    out = _wp.wire_unpack_add(
        jnp.asarray(np.ascontiguousarray(target).reshape(-1)),
        jnp.asarray(mask),
        jnp.asarray(cpad),
        block_rows=_wp.pick_block_rows(n),
        interpret=_interpret(),
    )
    return np.asarray(out)


def decode_add_leaf(
    target: np.ndarray, meta: dict, blob, impl: str = "numpy"
) -> np.ndarray:
    """Decode one leaf and ADD it into ``target`` (flat, leaf dtype) —
    the worker decode phase's per-peer accumulate, returned as a new
    array.  Under ``impl='pallas'``/'auto' a bitmap leaf takes the fused
    unpack-apply kernel (one pass: mask bits -> gather -> add), which is
    bit-identical to ``target + decode_leaf(...)`` — the f32 adds round
    the same way and the off-support lanes still add an explicit +0.0.
    Every other case decodes and adds in numpy."""
    dt = np.dtype(meta["dtype"])
    n = int(np.prod(meta["shape"])) if meta["shape"] else 1
    if (
        meta["enc"] == "bitmap"
        and dt.name in _PALLAS_ADD_DTYPES
        and resolve_impl(impl, n, dt, meta.get("q", "none")) == "pallas"
    ):
        return _unpack_pallas(np.asarray(target).reshape(-1), meta, blob)
    return (
        np.asarray(target).reshape(-1)
        + decode_leaf(meta, blob).reshape(-1)
    )


# -- pytree encode / decode ---------------------------------------------------


def tree_keys(tree: PyTree) -> list[str]:
    """Stable '/'-joined path keys — ``checkpoint.store.path_key``'s scheme
    (imported, not copied, so wire metadata and checkpoint manifests can
    never drift apart)."""
    import jax

    from repro.checkpoint.store import path_key

    return [
        path_key(path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def encode_tree_parts(
    tree: PyTree,
    scheme: str = AUTO,
    quant: str = "none",
    with_residual: bool = False,
) -> tuple[list[dict], list, Optional[PyTree]]:
    """Encode a pytree -> (per-leaf meta, flat buffer list, residual tree).

    The buffer list is framing-ready (vectored send, no join); the
    residual tree is None unless ``with_residual`` and carries the fp32
    quantization error per leaf for error feedback.
    """
    import jax

    keys = tree_keys(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    meta: list[dict] = []
    parts: list = []
    residuals: list = []
    for key, leaf in zip(keys, leaves):
        m, p, r = encode_leaf(
            leaf, scheme=scheme, quant=quant, key=key,
            with_residual=with_residual,
        )
        meta.append(m)
        parts.extend(p)
        residuals.append(r)
    res_tree = None
    if with_residual:
        treedef = jax.tree_util.tree_structure(tree)
        res_tree = jax.tree_util.tree_unflatten(treedef, residuals)
    return meta, parts, res_tree


def encode_tree(
    tree: PyTree, scheme: str = AUTO, quant: str = "none"
) -> tuple[list[dict], bytes]:
    """Joined-payload form of ``encode_tree_parts`` (RPC-compatible)."""
    meta, parts, _ = encode_tree_parts(tree, scheme=scheme, quant=quant)
    return meta, b"".join(bytes(p) for p in parts)


def decode_tree(meta: list[dict], payload, like: PyTree) -> PyTree:
    """Decode bytes back into numpy leaves shaped like ``like``."""
    import jax

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(like_leaves) != len(meta):
        raise ValueError(
            f"template has {len(like_leaves)} leaves, message {len(meta)}"
        )
    view = memoryview(payload)
    out = []
    off = 0
    for m in meta:
        nb = int(m["nbytes"])
        out.append(decode_leaf(m, view[off : off + nb]))
        off += nb
    if off != len(view):
        raise ValueError(f"trailing bytes in payload: {len(view) - off}")
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_nbytes(meta: list[dict]) -> int:
    """Payload bytes a meta list accounts for (the broker's unit of record)."""
    return int(sum(m["nbytes"] for m in meta))


def predict_leaf_nbytes(
    leaf: Any, scheme: str = AUTO, quant: str = "none"
) -> int:
    """Accounting for ONE leaf: wire bytes it WOULD cost, from its nnz
    through the same ``leaf_nbytes`` formula (and ``auto`` resolution)
    the encoder asserts against.  Every predictor — whole-tree
    (``predict_tree_nbytes``) and per-shard
    (``runtime.sharding.predict_shard_nbytes``) — sums THIS function, so
    the accountants cannot drift from each other or from the encoder."""
    a = np.asarray(leaf)
    n = int(a.size)
    nnz = int(np.count_nonzero(a))
    isz = quant_dtype(a.dtype, quant).itemsize
    s = best_scheme(n, nnz, isz) if scheme == AUTO else scheme
    return int(leaf_nbytes(s, n, nnz, isz))


def predict_tree_nbytes(
    tree: PyTree, scheme: str = AUTO, quant: str = "none"
) -> int:
    """Simulator-side accounting: wire bytes this tree WOULD cost, computed
    from nnz counts through the same ``leaf_nbytes`` formula the encoder
    asserts against — equal to the encoded size by construction (the
    cross-check test in ``tests/test_wire_codec.py`` holds this line)."""
    import jax

    return sum(
        predict_leaf_nbytes(leaf, scheme, quant)
        for leaf in jax.tree_util.tree_leaves(tree)
    )
