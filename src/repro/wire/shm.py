"""Shared-memory transport for the same-host update path (DESIGN.md §12).

Workers and broker shards are processes on ONE host, yet until this
module every update byte crossed the kernel twice through a loopback TCP
socket.  Here the persistent ``Connection`` seam of ``wire.framing`` is
re-implemented over a ``multiprocessing.shared_memory`` segment per
(worker, shard) pair: publishes and pulls are a single userspace memcpy
into an mmap'd ring — no socket, no syscall per byte — while the message
framing, the codec, and every byte-accounting number stay bit-identical
to the TCP transport.

Segment layout (one per worker↔shard channel, created by the supervisor)::

    SegHdr   | RingHdr req | RingHdr rsp | req data [N] | rsp data [N]

Each ring is a single-producer single-consumer byte stream:

* ``head``/``tail`` are monotonically-increasing uint64 byte cursors
  published through a **seqlock** (odd/even sequence word around each
  store) so the peer never acts on a torn 8-byte read;
* the producer copies payload bytes FIRST and publishes ``head`` after —
  the head store is the commit point, so a reader can never observe a
  partially-written frame (SIGKILL mid-publish leaves the bytes beyond
  ``head`` invisible; every decoded frame additionally carries a trailer
  word as a torn-write tripwire);
* frames larger than the ring stream through it in chunks — the producer
  commits as space frees, the consumer drains as bytes commit, so the
  ring size bounds memory, not message size;
* a full ring is **backpressure**: the producer waits on the consumer's
  space futex; an empty ring parks the consumer on the producer's data
  futex (Linux ``futex(2)`` on words inside the segment — the same
  zero-syscall-until-contended wakeup the ISP barrier long-poll needs;
  non-Linux falls back to adaptive sleep polling).

Liveness and respawn are generation-based: the serving broker resets the
rings and bumps the segment ``generation`` word when it (re)attaches, so
a worker whose in-flight request was wiped by a broker respawn sees the
generation move, raises ``ConnectionError``, and replays through the same
idempotent-RPC retry path the TCP transport uses.  A SIGKILLed *worker*
is detected by pid liveness; its segments are torn down and recreated by
the supervisor before the respawned invocation attaches (DESIGN.md §12.3
failure matrix).
"""

from __future__ import annotations

import ctypes
import json
import os
import platform
import struct
import sys
import time
from multiprocessing import shared_memory
from typing import Callable, Optional

from repro.wire import framing
from repro.wire.framing import Payload, _as_views

# -- futex(2) wakeup (Linux) with portable polling fallback -------------------

_FUTEX_WAIT = 0
_FUTEX_WAKE = 1
_INT_MAX = 2**31 - 1
_SYS_FUTEX = {
    "x86_64": 202,
    "i686": 240,
    "i386": 240,
}.get(platform.machine())

# the ring commit protocol (payload stores before the head publish, and
# the seqlock around the 64-bit cursors) relies on total-store-order —
# ctypes emits no memory barriers, so weakly-ordered machines (aarch64,
# power, ...) could surface uncommitted bytes.  The transport refuses to
# start anywhere the assumption does not hold rather than corrupting
# quietly (DESIGN.md §12.2).
SHM_MACHINES = ("x86_64", "i686", "i386", "AMD64")


def _require_supported() -> None:
    m = platform.machine()
    if m not in SHM_MACHINES or not sys.platform.startswith("linux"):
        raise ConnectionError(
            f"shm transport requires Linux on a TSO machine "
            f"({SHM_MACHINES}); this host is {sys.platform}/{m} — use the "
            "tcp transport"
        )

_libc = None
if _SYS_FUTEX is not None and os.name == "posix":
    try:  # pragma: no branch
        _libc = ctypes.CDLL(None, use_errno=True)
    except OSError:  # pragma: no cover
        _libc = None

HAVE_FUTEX = _libc is not None

# polling fallback (and the inter-check slice of futex waits): short
# enough that peer-death/generation checks stay responsive
_WAIT_SLICE_S = 0.05
_POLL_SLEEP_S = 0.0002
# adaptive spin-then-futex: before parking in the kernel, spin up to a
# budget tuned from the MEASURED wait times of this word (2x the EWMA,
# capped) — barrier wakeups that historically arrive within microseconds
# are caught without paying the ~5-10 us futex syscall + thread switch,
# while words that historically park for milliseconds skip straight to
# the futex.  The cap bounds the cpu burned per wait and is overridable
# for oversubscribed hosts (REPRO_SHM_SPIN_US=0 disables spinning).
_SPIN_MAX_S = max(float(os.environ.get("REPRO_SHM_SPIN_US", "200")), 0.0) * 1e-6


class _AdaptiveWaiter:
    """Per-futex-word spin budget learned from measured wait durations."""

    __slots__ = ("ewma_s",)
    _ALPHA = 0.2  # EWMA smoothing of observed wait times

    def __init__(self) -> None:
        self.ewma_s = 0.0

    def budget_s(self) -> float:
        return min(2.0 * self.ewma_s, _SPIN_MAX_S)

    def record(self, waited_s: float) -> None:
        self.ewma_s += self._ALPHA * (waited_s - self.ewma_s)
# producer commit granularity: one head-publish + wake per frame for
# small messages, every _COMMIT_CHUNK bytes for large ones — small
# frames pay ONE wakeup, large frames stream (the consumer's copy-out
# overlaps the producer's copy-in, like kernel socket buffering does)
_COMMIT_CHUNK = 256 << 10
# copies at or above this size go through numpy, which drops the GIL for
# large contiguous copies — a broker thread pushing a MB-scale pull
# response must not serialize every OTHER worker's ack behind it (TCP
# gets this for free: sendmsg releases the GIL during the kernel copy)
_NP_COPY_MIN = 16 << 10


class _timespec(ctypes.Structure):
    _fields_ = [("tv_sec", ctypes.c_long), ("tv_nsec", ctypes.c_long)]


def _futex_wait(addr: int, expected: int, timeout_s: float) -> None:
    """Sleep until *addr != expected (best effort) or timeout."""
    if _libc is None:  # pragma: no cover - non-Linux fallback
        time.sleep(min(timeout_s, _POLL_SLEEP_S * 16))
        return
    sec = int(timeout_s)
    ts = _timespec(sec, int((timeout_s - sec) * 1e9))
    _libc.syscall(
        _SYS_FUTEX,
        ctypes.c_void_p(addr),
        ctypes.c_int(_FUTEX_WAIT),  # NOT private: waiters cross processes
        ctypes.c_uint32(expected),
        ctypes.byref(ts),
        ctypes.c_void_p(0),
        ctypes.c_uint32(0),
    )  # EAGAIN/ETIMEDOUT/EINTR are all "go re-check"


def _futex_wake(addr: int) -> None:
    if _libc is None:  # pragma: no cover - non-Linux fallback
        return
    _libc.syscall(
        _SYS_FUTEX,
        ctypes.c_void_p(addr),
        ctypes.c_int(_FUTEX_WAKE),
        ctypes.c_uint32(_INT_MAX),
        ctypes.c_void_p(0),
        ctypes.c_void_p(0),
        ctypes.c_uint32(0),
    )


# -- segment layout -----------------------------------------------------------

MAGIC = 0x4D4C5348  # "MLSH"
VERSION = 1

# segment header field offsets (all uint32 unless noted)
_OFF_MAGIC = 0
_OFF_VERSION = 4
_OFF_RING_BYTES = 8
_OFF_GENERATION = 12  # futex word; even = serving, odd = resetting
_OFF_SERVER_PID = 16
_OFF_CLIENT_PID = 20
_OFF_CLOSED = 24  # server's clean-shutdown flag
_OFF_CLIENT_BUSY = 28  # client-inside-ring-mutation flag (reset handshake)
_SEG_HDR = 64

# ring header field offsets (relative to the ring header base)
_R_HEAD_SEQ = 0
_R_HEAD = 8  # uint64
_R_TAIL_SEQ = 16
_R_TAIL = 24  # uint64
_R_DATA_FUTEX = 32  # producer bumps after head advances
_R_SPACE_FUTEX = 36  # consumer bumps after tail advances
_RING_HDR = 64

_REQ_HDR = _SEG_HDR
_RSP_HDR = _SEG_HDR + _RING_HDR
_DATA0 = _SEG_HDR + 2 * _RING_HDR

DEFAULT_RING_BYTES = 4 << 20

# shm frame: uint32 rid | uint32 hlen | uint32 plen | header | payload |
# uint32 trailer.  rid matches responses to requests across timeouts (the
# TCP transport gets this for free by closing the socket); the trailer is
# the torn-write tripwire — a frame whose trailer does not check out is
# NEVER surfaced to the codec.
_FRAME = struct.Struct("<III")
_TRAILER = struct.Struct("<I")
_TRAILER_SALT = 0xA5C35A3C


def _trailer_word(rid: int, hlen: int, plen: int) -> int:
    return (rid ^ hlen ^ (plen << 1) ^ _TRAILER_SALT) & 0xFFFFFFFF


def segment_nbytes(ring_bytes: int) -> int:
    return _DATA0 + 2 * ring_bytes


class TornFrameError(ConnectionError):
    """A committed frame failed its trailer check — protocol corruption.

    Raised instead of ever handing the bytes to the codec."""


def _attach_raw(name: str) -> shared_memory.SharedMemory:
    """Attach WITHOUT leaving a resource-tracker registration behind.

    CPython (up to 3.12) registers a POSIX segment with the resource
    tracker on ATTACH as well as create, and the tracker UNLINKS every
    registered segment when its owning process dies.  Attaching
    processes here die mid-job by design — a SIGKILLed broker shard, an
    invocation-bounded worker — and their trackers would yank the live
    segment out from under every peer (the respawned shard then finds
    no segment and the pool wedges).  Only the creating supervisor owns
    unlink; ``Segment.unlink`` re-registers first so the bookkeeping
    stays balanced."""
    seg = shared_memory.SharedMemory(name=name)
    try:  # pragma: no branch
        from multiprocessing import resource_tracker

        resource_tracker.unregister(seg._name, "shared_memory")  # type: ignore[attr-defined]
    except Exception:  # pragma: no cover - tracker API moved
        pass
    return seg


class Segment:
    """One worker↔shard shm channel: header words + two rings.

    All cross-process words are accessed through ``ctypes`` objects bound
    directly into the mapping (single aligned stores).  Publication
    ordering relies on x86-TSO/total-store-order semantics plus the
    seqlock around the 64-bit cursors; DESIGN.md §12.2 records the
    assumption.
    """

    def __init__(self, seg: shared_memory.SharedMemory, owner: bool):
        self._seg = seg
        self.owner = owner
        self.name = seg.name
        buf = seg.buf
        self._u32 = {
            off: ctypes.c_uint32.from_buffer(buf, off)
            for off in (
                _OFF_MAGIC, _OFF_VERSION, _OFF_RING_BYTES, _OFF_GENERATION,
                _OFF_SERVER_PID, _OFF_CLIENT_PID, _OFF_CLOSED,
                _OFF_CLIENT_BUSY,
            )
        }
        self._ring_u32: dict[int, ctypes.c_uint32] = {}
        self._ring_u64: dict[int, ctypes.c_uint64] = {}
        for base in (_REQ_HDR, _RSP_HDR):
            for off in (_R_HEAD_SEQ, _R_TAIL_SEQ, _R_DATA_FUTEX,
                        _R_SPACE_FUTEX):
                self._ring_u32[base + off] = ctypes.c_uint32.from_buffer(
                    buf, base + off
                )
            for off in (_R_HEAD, _R_TAIL):
                self._ring_u64[base + off] = ctypes.c_uint64.from_buffer(
                    buf, base + off
                )
        # per-word adaptive spin budgets — process-local state (each side
        # measures the waits IT experiences), not part of the shared layout
        self._waiters: dict[int, _AdaptiveWaiter] = {}

    # -- lifecycle ------------------------------------------------------------

    @classmethod
    def create(cls, name: str, ring_bytes: int = DEFAULT_RING_BYTES
               ) -> "Segment":
        _require_supported()  # every channel flows from a created segment
        seg = shared_memory.SharedMemory(
            name=name, create=True, size=segment_nbytes(ring_bytes)
        )
        seg.buf[: segment_nbytes(ring_bytes)] = bytes(
            segment_nbytes(ring_bytes)
        )
        self = cls(seg, owner=True)
        self._u32[_OFF_RING_BYTES].value = ring_bytes
        self._u32[_OFF_VERSION].value = VERSION
        self._u32[_OFF_MAGIC].value = MAGIC  # magic last: readers gate on it
        return self

    @classmethod
    def attach(cls, name: str) -> "Segment":
        self = cls(_attach_raw(name), owner=False)
        if self._u32[_OFF_MAGIC].value != MAGIC:
            self.close()
            raise ConnectionError(f"shm segment {name!r}: bad magic")
        if self._u32[_OFF_VERSION].value != VERSION:
            v = self._u32[_OFF_VERSION].value
            self.close()
            raise ConnectionError(
                f"shm segment {name!r}: version {v} != {VERSION}"
            )
        return self

    def close(self) -> None:
        # ctypes objects exported from the buffer pin it: drop them first
        self._u32.clear()
        self._ring_u32.clear()
        self._ring_u64.clear()
        try:
            self._seg.close()
        except (OSError, BufferError):  # pragma: no cover
            pass

    def unlink(self) -> None:
        self.close()
        Segment.unlink_by_name(self.name)

    @staticmethod
    def unlink_by_name(name: str) -> None:
        # a plain attach RE-registers the name (see _attach_raw), so the
        # unregister inside SharedMemory.unlink always finds its entry —
        # balanced bookkeeping whatever mix of create/attach/unregister
        # this process did before
        try:
            seg = shared_memory.SharedMemory(name=name)
        except FileNotFoundError:
            return
        try:
            seg.unlink()
        finally:
            seg.close()

    # -- header words ---------------------------------------------------------

    @property
    def ring_bytes(self) -> int:
        return self._u32[_OFF_RING_BYTES].value

    @property
    def generation(self) -> int:
        return self._u32[_OFF_GENERATION].value

    def _word_addr(self, off: int) -> int:
        return ctypes.addressof(self._u32[off])

    def wait_generation(
        self,
        not_equal_to: int,
        timeout_s: float,
        check: Optional[Callable[[], None]] = None,
    ) -> int:
        """Block until ``generation`` is even and differs from
        ``not_equal_to``; returns the new generation."""
        deadline = time.monotonic() + timeout_s
        while True:
            g = self.generation
            if g != not_equal_to and g % 2 == 0 and g > 0:
                return g
            if check is not None:
                check()
            if time.monotonic() > deadline:
                raise ConnectionError(
                    f"shm segment {self.name!r}: no serving peer "
                    f"(generation stuck at {g})"
                )
            if HAVE_FUTEX:
                _futex_wait(
                    self._word_addr(_OFF_GENERATION), g, _WAIT_SLICE_S
                )
            else:  # pragma: no cover
                time.sleep(_POLL_SLEEP_S)

    def set_server(self, pid: int) -> None:
        self._u32[_OFF_SERVER_PID].value = pid

    def set_client(self, pid: int) -> None:
        self._u32[_OFF_CLIENT_PID].value = pid

    @property
    def server_pid(self) -> int:
        return self._u32[_OFF_SERVER_PID].value

    @property
    def client_pid(self) -> int:
        return self._u32[_OFF_CLIENT_PID].value

    @property
    def closed_flag(self) -> bool:
        return bool(self._u32[_OFF_CLOSED].value)

    def set_closed(self) -> None:
        self._u32[_OFF_CLOSED].value = 1
        self._wake_all()

    def _set_busy(self, val: int) -> None:
        self._u32[_OFF_CLIENT_BUSY].value = val

    def _wake_all(self) -> None:
        for base in (_REQ_HDR, _RSP_HDR):
            _futex_wake(ctypes.addressof(
                self._ring_u32[base + _R_DATA_FUTEX]))
            _futex_wake(ctypes.addressof(
                self._ring_u32[base + _R_SPACE_FUTEX]))
        _futex_wake(self._word_addr(_OFF_GENERATION))

    def reset_rings(self, quiesce_s: float = 2.0) -> int:
        """Server-side (re)attach: invalidate, quiesce the client, zero
        both rings, publish a new even generation.  Returns it.

        The odd intermediate generation tells a mid-operation client to
        abort (its in-flight request is gone); the ``client_busy`` word
        is the handshake that keeps the reset from racing a client chunk
        copy that was already past its generation check.
        """
        g = self.generation
        self._u32[_OFF_GENERATION].value = g + 1 if g % 2 == 0 else g
        _futex_wake(self._word_addr(_OFF_GENERATION))
        deadline = time.monotonic() + quiesce_s
        while self._u32[_OFF_CLIENT_BUSY].value:
            pid = self.client_pid
            if pid and not _pid_alive(pid):
                break  # dead client cannot be mid-copy
            if time.monotonic() > deadline:
                break  # crashed-but-undetectable client; proceed
            time.sleep(0.001)
        for base in (_REQ_HDR, _RSP_HDR):
            for off in (_R_HEAD_SEQ, _R_TAIL_SEQ, _R_DATA_FUTEX,
                        _R_SPACE_FUTEX):
                self._ring_u32[base + off].value = 0
            for off in (_R_HEAD, _R_TAIL):
                self._ring_u64[base + off].value = 0
        self._u32[_OFF_CLOSED].value = 0
        self.set_server(os.getpid())
        newg = (self.generation // 2) * 2 + 2
        self._u32[_OFF_GENERATION].value = newg
        self._wake_all()
        return newg

    # -- seqlock cursors ------------------------------------------------------

    def _try_load_cursor(
        self, base: int, seq_off: int, val_off: int, tries: int = 3
    ) -> Optional[int]:
        """Bounded, non-blocking cursor read: None when the seqlock stays
        torn — the liveness checks use this so they never recurse into
        the spinning loads they guard."""
        seq = self._ring_u32[base + seq_off]
        val = self._ring_u64[base + val_off]
        for _ in range(tries):
            s1 = seq.value
            v = val.value
            s2 = seq.value
            if s1 == s2 and s1 % 2 == 0:
                return v
        return None

    def _load_cursor(
        self, base: int, seq_off: int, val_off: int,
        check: Optional[Callable[[], None]] = None,
    ) -> int:
        seq = self._ring_u32[base + seq_off]
        val = self._ring_u64[base + val_off]
        spins = 0
        while True:
            s1 = seq.value
            v = val.value
            s2 = seq.value
            if s1 == s2 and s1 % 2 == 0:
                return v
            spins += 1
            if spins % 1000 == 0:
                # a writer SIGKILLed between the two seqlock increments
                # leaves the word odd FOREVER — without this, the reader
                # spins at 100% cpu with its peer-death detection
                # unreachable
                if check is not None:
                    check()
                time.sleep(_POLL_SLEEP_S)

    def _store_cursor(self, base: int, seq_off: int, val_off: int,
                      value: int) -> None:
        seq = self._ring_u32[base + seq_off]
        seq.value += 1
        self._ring_u64[base + val_off].value = value
        seq.value += 1

    def _bump(self, base: int, futex_off: int) -> None:
        w = self._ring_u32[base + futex_off]
        w.value = (w.value + 1) & 0xFFFFFFFF
        _futex_wake(ctypes.addressof(w))

    def _word_value(self, base: int, futex_off: int) -> int:
        return self._ring_u32[base + futex_off].value

    def _wait_word(self, base: int, futex_off: int, captured: int) -> None:
        """Park until the word moves past ``captured`` — the caller MUST
        have captured the value BEFORE re-checking its wait condition, or
        a bump landing between check and wait is a lost wakeup (a
        50 ms-slice stall per message, not a correctness bug)."""
        w = self._ring_u32[base + futex_off]
        if w.value != captured:
            return  # already moved: don't sleep at all
        waiter = self._waiters.get(base + futex_off)
        if waiter is None:
            waiter = self._waiters[base + futex_off] = _AdaptiveWaiter()
        t0 = time.monotonic()
        budget = waiter.budget_s()
        spins = 0
        while budget > 0.0:
            if w.value != captured:
                waiter.record(time.monotonic() - t0)
                return
            spins += 1
            # monotonic() costs ~50 ns — amortize it across a batch of
            # word loads so the spin actually spins
            if spins % 64 == 0 and time.monotonic() - t0 >= budget:
                break
        if HAVE_FUTEX:
            _futex_wait(ctypes.addressof(w), captured, _WAIT_SLICE_S)
        else:  # pragma: no cover
            time.sleep(_POLL_SLEEP_S)
        # futex-path waits feed the EWMA too: a word that keeps parking
        # for milliseconds drags its budget toward the cap ONLY (bounded
        # spin), one that wakes in microseconds shrinks it back
        waiter.record(time.monotonic() - t0)


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # pragma: no cover - pid reused by another user
        return True
    return True


class _PidProbe:
    """Rate-limited liveness probe: generation/closed words are read on
    every wait iteration (ctypes loads, ~ns), but the ``os.kill`` syscall
    only every ``interval_s`` — peer death is a slow path, the probe must
    not tax the fast one."""

    def __init__(self, interval_s: float = 0.01):
        self.interval_s = interval_s
        self._last = 0.0

    def dead(self, pid: int) -> bool:
        if not pid:
            return False
        now = time.monotonic()
        if now - self._last < self.interval_s:
            return False
        self._last = now
        return not _pid_alive(pid)


class Ring:
    """One direction of a segment as a SPSC byte stream.

    ``role`` is 'producer' or 'consumer' — a ``Ring`` object only ever
    mutates the cursor its role owns, which is what makes the
    single-writer seqlocks sound.
    """

    def __init__(self, seg: Segment, base: int, role: str,
                 check: Optional[Callable[[], None]] = None):
        self.seg = seg
        self.base = base
        self.role = role
        self.check = check
        self.cap = seg.ring_bytes
        data0 = _DATA0 if base == _REQ_HDR else _DATA0 + self.cap
        self.data = seg._seg.buf[data0: data0 + self.cap]

    @staticmethod
    def _copy(dst, src) -> None:
        """memcpy that drops the GIL for big chunks (numpy) and skips the
        numpy overhead for small ones (plain buffer assignment)."""
        if len(src) >= _NP_COPY_MIN:
            import numpy as np

            np.copyto(
                np.frombuffer(dst, dtype=np.uint8),
                np.frombuffer(src, dtype=np.uint8),
            )
        else:
            dst[:] = src

    def release(self) -> None:
        if self.data is not None:
            self.data.release()
            self.data = None  # type: ignore[assignment]

    def _head(self) -> int:
        # the producer's cursor: only the peer can leave its seqlock torn,
        # so the consumer's liveness check guards the retry loop (and
        # symmetrically below) — never the cursor's own writer
        check = self.check if self.role == "consumer" else None
        return self.seg._load_cursor(self.base, _R_HEAD_SEQ, _R_HEAD, check)

    def _tail(self) -> int:
        check = self.check if self.role == "producer" else None
        return self.seg._load_cursor(self.base, _R_TAIL_SEQ, _R_TAIL, check)

    def _run_checks(self) -> None:
        if self.check is not None:
            self.check()

    # -- producer -------------------------------------------------------------

    def write_bytes(self, views: list, deadline: float) -> int:
        """Stream the buffer views into the ring; returns bytes written.

        The head cursor is published (and the peer woken) ONCE at the
        end for small frames — one wakeup per frame, not one per buffer
        view, which is the difference between a ~100 us and a multi-ms
        round trip when each wake is a thread switch.  Large frames
        commit every ``_COMMIT_CHUNK`` bytes (and whenever the ring
        fills), so the consumer's copy-out overlaps the producer's
        copy-in the way kernel socket buffering overlaps a ``sendmsg``
        with the peer's ``recv`` — and a frame larger than the ring
        still streams through.
        """
        assert self.role == "producer"
        head = self._head()
        committed = head
        total = 0

        def publish() -> None:
            nonlocal committed
            if head != committed:
                self.seg._store_cursor(self.base, _R_HEAD_SEQ, _R_HEAD, head)
                self.seg._bump(self.base, _R_DATA_FUTEX)
                committed = head

        for v in views:
            mv = memoryview(v).cast("B")
            off = 0
            n = len(mv)
            while off < n:
                self._run_checks()  # prompt generation/peer-death detection
                seq = self.seg._word_value(self.base, _R_SPACE_FUTEX)
                free = self.cap - (head - self._tail())
                if free == 0:
                    publish()  # let the consumer drain what we copied
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"shm ring {self.seg.name!r}: full for too long "
                            "(consumer stalled)"
                        )
                    self.seg._wait_word(self.base, _R_SPACE_FUTEX, seq)
                    continue
                pos = head % self.cap
                take = min(n - off, free, self.cap - pos)
                self._copy(self.data[pos: pos + take], mv[off: off + take])
                head += take
                off += take
                total += take
                if head - committed >= _COMMIT_CHUNK:
                    publish()
        publish()
        return total

    # -- consumer -------------------------------------------------------------

    def read_exact(self, n: int, deadline: float) -> bytes:
        assert self.role == "consumer"
        out = bytearray(n)
        got = 0
        tail = self._tail()
        while got < n:
            self._run_checks()
            seq = self.seg._word_value(self.base, _R_DATA_FUTEX)
            avail = self._head() - tail
            if avail == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"shm ring {self.seg.name!r}: timed out waiting "
                        f"for {n - got} bytes"
                    )
                self.seg._wait_word(self.base, _R_DATA_FUTEX, seq)
                continue
            was_full = avail == self.cap
            pos = tail % self.cap
            take = min(n - got, avail, self.cap - pos)
            self._copy(
                memoryview(out)[got: got + take],
                self.data[pos: pos + take],
            )
            tail += take
            got += take
            self.seg._store_cursor(self.base, _R_TAIL_SEQ, _R_TAIL, tail)
            if was_full:
                # the producer only ever parks on the space futex after
                # publishing a FULL ring — waking on any other drain is a
                # wasted syscall on the per-message fast path
                self.seg._bump(self.base, _R_SPACE_FUTEX)
        return bytes(out)

    def poll_available(self) -> Optional[int]:
        """Committed-but-unread bytes; None when a cursor seqlock is torn
        (a peer died mid-store).  Non-blocking — safe to call from the
        liveness checks that guard the blocking loads."""
        head = self.seg._try_load_cursor(self.base, _R_HEAD_SEQ, _R_HEAD)
        tail = self.seg._try_load_cursor(self.base, _R_TAIL_SEQ, _R_TAIL)
        if head is None or tail is None:
            return None
        return head - tail


# -- framed messages over a ring pair ----------------------------------------


def send_frame(ring: Ring, rid: int, header: dict, payload: Payload,
               deadline: float) -> int:
    """Write one framed message; returns the bytes a TCP ``send_msg`` of
    the same message would report (rid + trailer are transport overhead,
    uncounted — byte accounting must be transport-invariant)."""
    views = _as_views(payload)
    plen = sum(len(v) for v in views)
    raw = json.dumps(header, separators=(",", ":")).encode("utf-8")
    ring.write_bytes(
        [
            memoryview(_FRAME.pack(rid, len(raw), plen)),
            memoryview(raw),
            *views,
            memoryview(_TRAILER.pack(_trailer_word(rid, len(raw), plen))),
        ],
        deadline,
    )
    return 8 + len(raw) + plen


def recv_frame(
    ring: Ring, deadline: float, frame_timeout_s: Optional[float] = None
) -> tuple[int, dict, bytes]:
    """Read one framed message → (rid, header, payload).

    The trailer word is verified before anything is surfaced: a frame
    that fails it (torn write, desynced stream) raises
    ``TornFrameError`` and is never decoded.

    ``frame_timeout_s`` (server side) bounds the body reads separately
    from the idle wait for the header: a frame whose header landed but
    whose body never completes is an ABANDONED half-frame (the client
    gave up mid-send and is waiting for a ring reset), surfaced as
    ``TornFrameError`` so the serving loop re-serves instead of blocking
    both sides against each other.
    """
    rid, hlen, plen = _FRAME.unpack(ring.read_exact(_FRAME.size, deadline))
    if hlen > (1 << 31) or plen > (1 << 31):
        raise TornFrameError(
            f"shm ring {ring.seg.name!r}: implausible frame ({hlen}, {plen})"
        )
    if frame_timeout_s is not None:
        deadline = min(deadline, time.monotonic() + frame_timeout_s)
    try:
        raw = ring.read_exact(hlen, deadline)
        payload = ring.read_exact(plen, deadline) if plen else b""
        (tw,) = _TRAILER.unpack(ring.read_exact(_TRAILER.size, deadline))
    except TimeoutError as e:
        if frame_timeout_s is None:
            raise
        raise TornFrameError(
            f"shm ring {ring.seg.name!r}: frame body stalled "
            f"(rid={rid}, hlen={hlen}, plen={plen}) — abandoned half-frame"
        ) from e
    if tw != _trailer_word(rid, hlen, plen):
        raise TornFrameError(
            f"shm ring {ring.seg.name!r}: frame trailer mismatch "
            f"(rid={rid}, hlen={hlen}, plen={plen})"
        )
    return rid, json.loads(raw.decode("utf-8")), payload


# -- client side: the Transport implementation --------------------------------


class ShmConnection:
    """Persistent framed request/response channel over one shm segment —
    the shared-memory twin of ``framing.Connection`` (same ``request`` /
    ``send_only`` / ``recv_response`` / ``close`` surface, so
    ``framing.pipelined`` and every retry loop work unchanged).

    'Reconnecting' means waiting for the serving broker to publish a NEW
    even generation (it resets the rings when it attaches), then
    replaying the request — the same idempotent-replay contract the TCP
    transport relies on.
    """

    def __init__(self, name: str, timeout: float = 30.0,
                 connect_wait_s: float = 5.0):
        self.name = name
        self.timeout = timeout
        self.connect_wait_s = connect_wait_s
        self._seg: Optional[Segment] = None
        self._req: Optional[Ring] = None
        self._rsp: Optional[Ring] = None
        self._gen = 0  # generation this client is attached under
        self._dead_gen = 0  # generation seen when the last failure hit
        self._rid = 0
        self._inflight = False
        self._probe = _PidProbe()

    # -- liveness checks ------------------------------------------------------

    def _check(self) -> None:
        seg = self._seg
        assert seg is not None
        if seg.closed_flag:
            raise ConnectionError(
                f"shm segment {self.name!r}: server closed"
            )
        g = seg.generation
        if g != self._gen:
            raise ConnectionError(
                f"shm segment {self.name!r}: server reset "
                f"(generation {self._gen} -> {g})"
            )
        if self._probe.dead(seg.server_pid):
            raise ConnectionError(
                f"shm segment {self.name!r}: server pid "
                f"{seg.server_pid} died"
            )

    # -- attach ---------------------------------------------------------------

    def _connect(self) -> None:
        deadline = time.monotonic() + self.connect_wait_s
        seg: Optional[Segment] = None
        while seg is None:
            try:
                seg = Segment.attach(self.name)
            except FileNotFoundError:
                if time.monotonic() > deadline:
                    raise ConnectionError(
                        f"shm segment {self.name!r} does not exist"
                    ) from None
                time.sleep(0.01)
        try:
            gen = seg.wait_generation(
                self._dead_gen, max(deadline - time.monotonic(), 0.05)
            )
        except ConnectionError:
            seg.close()
            raise
        seg.set_client(os.getpid())
        self._seg = seg
        self._gen = gen
        self._req = Ring(seg, _REQ_HDR, "producer", check=self._check)
        self._rsp = Ring(seg, _RSP_HDR, "consumer", check=self._check)
        self._inflight = False

    def _ensure(self) -> None:
        if self._seg is None:
            self._connect()

    # -- request/response -----------------------------------------------------

    def send_only(self, header: dict, payload: Payload = b"",
                  timeout: Optional[float] = None) -> None:
        t = timeout if timeout is not None else self.timeout
        for attempt in range(2):
            try:
                framing.chaos("send", header)
                self._ensure()
                seg = self._seg
                assert seg is not None
                rid = self._rid + 1
                deadline = time.monotonic() + t
                # busy-word handshake: a server-side ring reset must not
                # race a chunk copy in flight (reads need no guard — a
                # reset mid-read is caught by the generation check or the
                # frame trailer)
                seg._set_busy(1)
                try:
                    self._check()
                    send_frame(self._req, rid, header, payload, deadline)  # type: ignore[arg-type]
                finally:
                    seg._set_busy(0)
                self._rid = rid
                self._inflight = True
                return
            except (ConnectionError, OSError, TimeoutError):
                # a failed send may have committed a PARTIAL frame — the
                # stream is only trustworthy again after the server resets
                # the rings, so always demand a new generation here
                self.close(failed=True, force_stale=True)
                if attempt:
                    raise

    def recv_response(self, timeout: Optional[float] = None
                      ) -> tuple[dict, bytes]:
        if self._seg is None or not self._inflight:
            raise ConnectionError("no in-flight request on this channel")
        t = timeout if timeout is not None else self.timeout
        deadline = time.monotonic() + t
        try:
            framing.chaos("recv", {})
            while True:
                rid, hdr, payload = recv_frame(self._rsp, deadline)  # type: ignore[arg-type]
                if rid == self._rid:
                    self._inflight = False
                    return hdr, payload
                if rid > self._rid:
                    raise TornFrameError(
                        f"shm segment {self.name!r}: response rid {rid} "
                        f"from the future (expected {self._rid})"
                    )
                # rid < expected: the answer to a request we already gave
                # up on (timeout + replay) — drain and keep waiting
        except (ConnectionError, OSError, TimeoutError):
            self.close(failed=True)
            raise

    def request(self, header: dict, payload: Payload = b"",
                timeout: Optional[float] = None) -> tuple[dict, bytes]:
        last: Optional[Exception] = None
        for attempt in range(2):
            try:
                self.send_only(header, payload, timeout=timeout)
                return self.recv_response(timeout=timeout)
            except (ConnectionError, OSError, TimeoutError) as e:
                last = e
        assert last is not None
        raise last

    def close(self, failed: bool = False, force_stale: bool = False) -> None:
        if self._seg is not None:
            if failed:
                # only demand a NEW generation when the server side
                # actually went away or reset — a plain recv timeout with
                # a live, same-generation server may simply reattach (the
                # rid filter discards whatever late response still lands)
                stale = force_stale
                if not stale:
                    try:
                        stale = (
                            self._seg.generation != self._gen
                            or self._seg.closed_flag
                            or (self._seg.server_pid
                                and not _pid_alive(self._seg.server_pid))
                        )
                    except Exception:  # pragma: no cover - segment unmapped
                        stale = True
                if stale:
                    self._dead_gen = self._gen
            for ring in (self._req, self._rsp):
                if ring is not None:
                    ring.release()
            self._req = self._rsp = None
            self._seg.close()
            self._seg = None
        self._inflight = False

    def __enter__(self) -> "ShmConnection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -- server side --------------------------------------------------------------


class ShmServerChannel:
    """The broker-side end of one segment: recv requests, send responses.

    ``serve`` resets the rings and publishes a fresh generation — the
    listen()+accept() of this transport.  The handler loop shape matches
    a TCP socket handler: ``recv()`` blocks until a request or raises
    ``ConnectionError`` when the peer dies / the server is asked down.
    """

    def __init__(self, name: str,
                 stop: Optional[Callable[[], bool]] = None):
        self.name = name
        self.seg = Segment.attach(name)
        self.stop = stop
        self.gen = self.seg.reset_rings()
        self._probe = _PidProbe()
        self._req = Ring(self.seg, _REQ_HDR, "consumer", check=self._check)
        self._rsp = Ring(self.seg, _RSP_HDR, "producer", check=self._check)

    def _check(self) -> None:
        if self.stop is not None and self.stop():
            raise ConnectionError(
                f"shm segment {self.name!r}: server shutting down"
            )
        if self._probe.dead(self.seg.client_pid):
            # only fail if there is nothing left to consume: the client
            # may have published a full frame and exited cleanly.  A
            # torn cursor (None) from a mid-store death is equally dead.
            avail = self._req.poll_available()
            if avail is None or avail == 0:
                raise ConnectionError(
                    f"shm segment {self.name!r}: client pid "
                    f"{self.seg.client_pid} died"
                )

    def recv(self, timeout_s: float = 3600.0,
             frame_timeout_s: float = 60.0) -> tuple[int, dict, bytes]:
        return recv_frame(
            self._req, time.monotonic() + timeout_s,
            frame_timeout_s=frame_timeout_s,
        )

    def send(self, rid: int, header: dict, payload: Payload = b"",
             timeout_s: float = 60.0) -> int:
        return send_frame(
            self._rsp, rid, header, payload, time.monotonic() + timeout_s
        )

    def close(self, mark_closed: bool = False) -> None:
        if mark_closed:
            try:
                self.seg.set_closed()
            except Exception:  # pragma: no cover - segment already gone
                pass
        self._req.release()
        self._rsp.release()
        self.seg.close()
