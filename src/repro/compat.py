"""jax version-compat surface (0.4.x <-> 0.5+), one place only.

The repo targets the jax>=0.5 spellings; this module backfills them on
0.4.x so the same code runs on both. Mesh axis_types compat lives in
``launch.mesh`` (it must not import jax device state at module load).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5/0.6: top-level export, axis_names/check_vma kwargs
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x: experimental home, auto/check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, **kw):
        if axis_names is not None:
            # 0.4 spells partial-manual as the COMPLEMENT: the axes that
            # stay automatic
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map_04(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

__all__ = ["shard_map"]
