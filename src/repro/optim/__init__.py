"""Optimizers (paper Table 1): SGD, SGD + Nesterov momentum, Adam.

Deliberately optax-shaped but self-contained (the container is offline):
``init(params) -> state``; ``update(grads, state, params) -> (updates, state)``
where *updates are the deltas to be ADDED to the parameters* (u_t in the
paper: x_t = x_{t-1} + u_t). Returning updates rather than new params is what
lets the consistency layer (BSP/SSP/ISP) intercept and filter them.
"""

from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    OptState,
    adam,
    sgd,
    nesterov,
    make,
    apply_updates,
    global_norm,
    clip_by_global_norm,
)
