"""Self-contained pytree optimizers returning additive updates u_t."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array  # int32, 1-indexed
    mu: PyTree  # first moment / momentum buffer (zeros pytree when unused)
    nu: PyTree  # second moment (zeros pytree when unused)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A (init, update) pair. ``update`` returns (updates, new_state)."""

    name: str
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def _zeros_like_tree(params: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, params)


def _lr_at(lr: float, step: jax.Array, decay: bool) -> jax.Array:
    """Paper (Theorem 1): eta_t = eta / sqrt(t)."""
    t = jnp.maximum(step.astype(jnp.float32), 1.0)
    base = jnp.asarray(lr, jnp.float32)
    return base / jnp.sqrt(t) if decay else base


def sgd(lr: float, lr_decay: bool = False) -> Optimizer:
    """Plain SGD: u_t = -eta_t * g_t."""

    def init(params: PyTree) -> OptState:
        z = _zeros_like_tree(params)
        return OptState(jnp.asarray(1, jnp.int32), z, z)

    def update(grads: PyTree, state: OptState, params: PyTree):
        eta = _lr_at(lr, state.step, lr_decay)
        updates = jax.tree.map(lambda g: (-eta * g).astype(g.dtype), grads)
        return updates, OptState(state.step + 1, state.mu, state.nu)

    return Optimizer("sgd", init, update)


def nesterov(lr: float, momentum: float = 0.9, lr_decay: bool = False) -> Optimizer:
    """SGD with Nesterov momentum (paper Table 1, PMF jobs).

    m_t = beta*m_{t-1} + g_t ;  u_t = -eta * (g_t + beta*m_t)
    """

    def init(params: PyTree) -> OptState:
        z = _zeros_like_tree(params)
        return OptState(jnp.asarray(1, jnp.int32), z, z)

    def update(grads: PyTree, state: OptState, params: PyTree):
        eta = _lr_at(lr, state.step, lr_decay)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        updates = jax.tree.map(
            lambda g, m: (-eta * (g + momentum * m)).astype(g.dtype), grads, mu
        )
        return updates, OptState(state.step + 1, mu, state.nu)

    return Optimizer("nesterov", init, update)


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    lr_decay: bool = False,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam (paper Table 1, LR jobs) with optional decoupled weight decay."""

    def init(params: PyTree) -> OptState:
        return OptState(
            jnp.asarray(1, jnp.int32),
            _zeros_like_tree(params),
            _zeros_like_tree(params),
        )

    def update(grads: PyTree, state: OptState, params: PyTree):
        t = state.step.astype(jnp.float32)
        eta = _lr_at(lr, state.step, lr_decay)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads
        )
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)

        def leaf(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -eta * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u - eta * weight_decay * p
            return u.astype(p.dtype)

        updates = jax.tree.map(leaf, mu, nu, params)
        return updates, OptState(state.step + 1, mu, nu)

    return Optimizer("adam", init, update)


_REGISTRY: dict[str, Callable[..., Optimizer]] = {
    "sgd": sgd,
    "nesterov": nesterov,
    "adam": adam,
}


def make(name: str, lr: float, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](lr, **kwargs)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """x_t = x_{t-1} + u_t."""
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
